"""Burst == single-step conformance for the device-resident decode loop.

The engine's decode burst runs up to K fused megasteps per host
round-trip inside one ``lax.while_loop`` whose bound is a *traced*
scalar — every K executes the identical compiled loop body, so burst
output must be **bit-identical** to single-stepping, across the whole
serving-family matrix (transformer / mamba / xLSTM / hybrid):

  * tokens AND logit traces for K in {1, 4, 8} match element-for-element
    (the engines share one ``max_burst`` so all runs execute the same
    compiled functions);
  * a mid-decode join forces the burst back to K = 1 while the queue is
    non-empty (join latency unchanged) and the joiner still decodes the
    fresh-run oracle sequence;
  * the all-done early-out cuts the final burst short instead of
    spinning no-op device steps;
  * the megasteps compile exactly once per (engine, T-bucket) — the CI
    job pins this to catch silent recompile regressions;
  * steady-state decode performs **zero** host->device slot-state
    uploads (the device-resident mirror replaces the per-step
    ``jnp.asarray(page_table/lengths/...)`` re-upload).

The dense engine runs the same burst machinery (shared position
scalar), checked via its own K-sweep.
"""
import jax
import numpy as np
import pytest

from conftest import FAMILY_CFGS
from repro.serving import ServeEngine


def _serve_with_burst(model, params, prompts, k, *, trace=False, **kw):
    """Serve ``prompts`` with burst bound ``k`` on a max_burst=8 engine
    (shared ring-buffer shape: every K runs the same compiled loop)."""
    kw.setdefault("batch_size", len(prompts))
    kw.setdefault("capacity", 32)
    kw.setdefault("max_new_tokens", 8)
    kw.setdefault("block_size", 4)
    kw.setdefault("prefill_chunk", 8)
    eng = ServeEngine(model, params, burst=8, trace_logits=trace, **kw)
    eng.burst = k
    res = eng.serve([p.copy() for p in prompts])
    toks = {r.request_id: list(r.tokens) for r in res}
    return eng, toks


def _fresh_dense_tokens(model, params, prompt, max_new):
    import jax.numpy as jnp
    logits, cache = model.prefill(params, jnp.asarray(prompt)[None],
                                  capacity=64, cache_dtype=jnp.float32)
    toks = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    while len(toks) < max_new:
        tok = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, cache, tok, jnp.int32(pos))
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1
    return toks


def test_burst_tokens_and_traces_bit_identical(family_model):
    """K in {1, 4, 8}: same tokens, same logit traces, bit for bit.

    All requests are submitted up front so every run schedules the same
    sequence of jit shapes (same admissions, same prefill chunks); from
    then on every T=1 step goes through the shared burst body, so any
    divergence is semantic, not numeric noise."""
    family, model, params = family_model
    rng = np.random.default_rng(61)
    prompts = [rng.integers(1, 64, n).astype(np.int32) for n in (5, 9, 3)]
    runs = {}
    for k in (1, 4, 8):
        eng, toks = _serve_with_burst(model, params, prompts, k, trace=True)
        runs[k] = (eng, toks)
    base_eng, base_toks = runs[1]
    for k in (4, 8):
        eng, toks = runs[k]
        assert toks == base_toks, f"{family}: K={k} tokens diverged from K=1"
        assert set(eng.logit_trace) == set(base_eng.logit_trace)
        for rid, base_trace in base_eng.logit_trace.items():
            trace = eng.logit_trace[rid]
            assert len(trace) == len(base_trace), (family, k, rid)
            for step, (a, b) in enumerate(zip(trace, base_trace)):
                assert np.array_equal(a, b), \
                    f"{family}: K={k} logits diverged (rid {rid}, step {step})"
    # burst mode actually batched host round-trips: fewer syncs, same steps
    eng8 = runs[8][0]
    assert eng8.n_device_steps == base_eng.n_device_steps
    assert eng8.n_host_syncs < base_eng.n_host_syncs


def test_burst_matches_greedy_oracle(family_model):
    """K=8 burst output equals a fresh dense greedy run per request."""
    family, model, params = family_model
    rng = np.random.default_rng(67)
    prompts = [rng.integers(1, 64, n).astype(np.int32) for n in (6, 4)]
    _, toks = _serve_with_burst(model, params, prompts, 8)
    for rid, p in enumerate(prompts):
        assert toks[rid] == _fresh_dense_tokens(model, params, p, 8), family


def test_midjoin_forces_single_step_then_burst_resumes(family_model):
    """A request queued mid-decode (both slots busy) degrades the loop
    to K=1 — so the very next eviction admits it — and every request
    still decodes its fresh-run oracle sequence."""
    family, model, params = family_model
    rng = np.random.default_rng(71)
    a = rng.integers(1, 64, 5).astype(np.int32)
    # b's prompt spans two prefill chunks, so b finishes one tick after
    # a — the late request then joins while b is still in flight
    b = rng.integers(1, 64, 9).astype(np.int32)
    late = rng.integers(1, 64, 7).astype(np.int32)
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=12, block_size=4, prefill_chunk=8,
                      burst=8)
    assert eng.paged, family
    eng.submit(a)
    eng.submit(b)
    results = []
    while eng.n_prefills < 2:          # consume both prompts (mixed steps)
        results += eng.step()
    results += eng.step()              # one full-K burst, queue empty
    assert eng.n_bursts == 1
    steps_before = eng.n_device_steps
    assert eng.n_device_steps - eng.n_prefill_chunks > 1  # really burst
    eng.submit(late)                   # queued: both slots busy
    results += eng.step()              # burst must degrade to K=1
    assert eng.n_device_steps == steps_before + 1, \
        f"{family}: engine kept bursting with a request queued"
    assert eng.n_active == 2           # the joiner is still waiting
    while eng.has_work:
        results += eng.step()
    assert eng.n_joins >= 1            # late joined once a slot freed
    by_id = {r.request_id: list(r.tokens) for r in results}
    for rid, prompt in ((0, a), (1, b), (2, late)):
        assert by_id[rid] == _fresh_dense_tokens(model, params, prompt, 12), \
            (family, rid)
    # after the joiner finished prefilling, full bursts resumed
    assert eng.n_bursts >= 2
    assert eng.n_device_steps > eng.n_bursts  # not all ticks were K=1


def test_burst_early_exit_on_all_done(family_model):
    """When every slot finishes mid-burst the while_loop exits instead
    of running no-op device steps to the K bound."""
    family, model, params = family_model
    rng = np.random.default_rng(73)
    prompts = [rng.integers(1, 64, 5).astype(np.int32)]
    # max_new=6: after prefill emits token 1, exactly 5 decode steps
    # remain — an 8-bound burst must exit early at 5
    eng, toks = _serve_with_burst(model, params, prompts, 8,
                                  max_new_tokens=6)
    assert len(toks[0]) == 6
    assert eng.n_burst_early_exits >= 1, family
    assert eng.n_device_steps < eng.n_bursts * 8


def test_megasteps_compile_once_across_k(family_model):
    """One engine, K swept over {1, 4, 8} with joins in between: the
    burst megastep must compile exactly once (its K bound is traced)
    and the mixed megastep once (T pinned to prefill_chunk)."""
    family, model, params = family_model
    rng = np.random.default_rng(79)
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=6, block_size=4, prefill_chunk=8,
                      burst=8)
    for k in (1, 4, 8):
        eng.burst = k
        eng.serve([rng.integers(1, 64, n).astype(np.int32)
                   for n in (5, 9, 3)])   # 3 reqs / 2 slots: joins happen
    stats = eng.compile_stats()
    assert stats["megastep_burst"] == 1, stats
    assert stats["megastep_mixed"] == 1, stats


def test_steady_state_decode_uploads_nothing(family_model):
    """The device-resident mirror: once a slot is decoding (and no
    structural event — admission, eviction, extension, fork — occurs),
    repeated decode bursts must not re-upload any slot state."""
    family, model, params = family_model
    prompt = np.arange(1, 5, dtype=np.int32)
    # block_size 16 >> prompt+max_new: no block extension mid-decode
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=10, block_size=16, prefill_chunk=8,
                      burst=1)
    eng.submit(prompt)
    while eng.n_prefills < 1:
        eng.step()
    uploads = eng._dev.n_uploads
    for _ in range(5):                 # pure steady-state decode ticks
        eng.step()
    assert eng._dev.n_uploads == uploads, \
        f"{family}: steady-state decode re-uploaded slot state"
    assert eng.n_device_steps >= 5


def test_dense_burst_matches_single_step():
    """The dense engine shares the burst machinery: K sweep on a dense
    (paged=False) transformer must be token- and trace-identical."""
    model_cfg = FAMILY_CFGS["transformer"]
    from repro.models import build_model
    model = build_model(model_cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(83)
    # equal lengths: one un-padded prefill wave, pure decode after
    prompts = [rng.integers(1, 64, 6).astype(np.int32) for _ in range(3)]
    runs = {}
    for k in (1, 4, 8):
        eng = ServeEngine(model, params, batch_size=3, capacity=32,
                          max_new_tokens=8, paged=False, burst=8,
                          trace_logits=True)
        assert not eng.paged
        eng.burst = k
        res = eng.serve([p.copy() for p in prompts])
        runs[k] = (eng, {r.request_id: list(r.tokens) for r in res})
    base_eng, base_toks = runs[1]
    for k in (4, 8):
        eng, toks = runs[k]
        assert toks == base_toks, f"dense K={k} tokens diverged"
        for rid, base_trace in base_eng.logit_trace.items():
            for step, (a, b) in enumerate(zip(eng.logit_trace[rid],
                                              base_trace)):
                assert np.array_equal(a, b), (k, rid, step)
    assert runs[8][0].n_host_syncs < base_eng.n_host_syncs


def test_dense_burst_respects_eos_and_capacity():
    """Dense bursts stop at eos per slot and never write past the cache
    strip (the host caps K at capacity - pos)."""
    from repro.models import build_model
    model = build_model(FAMILY_CFGS["transformer"])
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(89)
    prompts = [rng.integers(1, 64, 6).astype(np.int32) for _ in range(2)]
    oracle = _fresh_dense_tokens(model, params, prompts[0], 24)
    eos = oracle[3]                    # forces an early per-slot stop
    eng = ServeEngine(model, params, batch_size=2, capacity=16,
                      max_new_tokens=24, paged=False, burst=8,
                      eos_id=eos)
    res = eng.serve([p.copy() for p in prompts])
    by_id = {r.request_id: list(r.tokens) for r in res}
    expected = oracle[:oracle.index(eos) + 1]
    assert by_id[0] == expected        # stopped at eos inside a burst
    # capacity 16, prompts len 6: at most 10 decode positions — every
    # request is truncated there even though max_new is 24
    assert all(len(t) <= 11 for t in by_id.values())
    assert eng._pos <= 16


def test_burst_rejects_bad_config():
    from repro.models import build_model
    model = build_model(FAMILY_CFGS["transformer"])
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="burst"):
        ServeEngine(model, params, burst=0)
