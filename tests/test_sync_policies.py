"""Mux/Merge synchronization policies (paper §III)."""
import numpy as np

from repro.core.stream import Buffer
from repro.core.sync import SyncCollector, SyncPolicy


def _buf(v, pts):
    return Buffer(np.array([v], np.float32), pts=pts)


def test_parse():
    assert SyncPolicy.parse("slowest") == ("slowest", 0)
    assert SyncPolicy.parse("fastest") == ("fastest", 0)
    assert SyncPolicy.parse("base:1") == ("base", 1)


def test_slowest_drops_fast_source_frames():
    c = SyncCollector(2, policy=SyncPolicy.SLOWEST)
    # source 0 at 10 Hz (0.0,0.1,0.2,...), source 1 at 5 Hz (0.0,0.2,...)
    got = []
    for i in range(6):
        r = c.offer(0, _buf(i, i * 0.1))
        if r:
            got.append([b.data[0] for b in r])
        if i % 2 == 0:
            r = c.offer(1, _buf(i, i * 0.1))
            if r:
                got.append([b.data[0] for b in r])
    # every emit pairs one frame of each; fast source's stale frames drop
    assert all(len(g) == 2 for g in got)
    assert len(got) == 3  # rate of the slowest source


def test_fastest_duplicates_slow_source():
    c = SyncCollector(2, policy=SyncPolicy.FASTEST)
    c.offer(0, _buf(0, 0.0))
    r = c.offer(1, _buf(100, 0.0))
    assert r is not None
    emitted = 1
    for i in range(1, 5):
        r = c.offer(0, _buf(i, i * 0.1))
        if r is not None:
            emitted += 1
            assert r[1].data[0] == 100  # slow source duplicated
    assert emitted == 5


def test_base_locks_to_designated_source():
    c = SyncCollector(2, policy=SyncPolicy.BASE, base_index=1)
    c.offer(0, _buf(1, 0.0))
    c.offer(0, _buf(2, 0.1))
    c.offer(0, _buf(3, 0.2))
    r = c.offer(1, _buf(99, 0.19))
    assert r is not None
    assert r[1].data[0] == 99
    assert r[0].data[0] == 3  # nearest to base pts


def test_eos_tracking():
    c = SyncCollector(2)
    c.offer(0, Buffer.eos_buffer())
    assert not c.all_eos()
    c.offer(1, Buffer.eos_buffer())
    assert c.all_eos()
