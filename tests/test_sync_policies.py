"""Mux/Merge synchronization policies (paper §III)."""
import numpy as np

from repro.core.stream import Buffer
from repro.core.sync import SyncCollector, SyncPolicy


def _buf(v, pts):
    return Buffer(np.array([v], np.float32), pts=pts)


def test_parse():
    assert SyncPolicy.parse("slowest") == ("slowest", 0)
    assert SyncPolicy.parse("fastest") == ("fastest", 0)
    assert SyncPolicy.parse("base:1") == ("base", 1)


def test_slowest_drops_fast_source_frames():
    c = SyncCollector(2, policy=SyncPolicy.SLOWEST)
    # source 0 at 10 Hz (0.0,0.1,0.2,...), source 1 at 5 Hz (0.0,0.2,...)
    got = []
    for i in range(6):
        r = c.offer(0, _buf(i, i * 0.1))
        if r:
            got.append([b.data[0] for b in r])
        if i % 2 == 0:
            r = c.offer(1, _buf(i, i * 0.1))
            if r:
                got.append([b.data[0] for b in r])
    # every emit pairs one frame of each; fast source's stale frames drop
    assert all(len(g) == 2 for g in got)
    assert len(got) == 3  # rate of the slowest source


def test_fastest_duplicates_slow_source():
    c = SyncCollector(2, policy=SyncPolicy.FASTEST)
    c.offer(0, _buf(0, 0.0))
    r = c.offer(1, _buf(100, 0.0))
    assert r is not None
    emitted = 1
    for i in range(1, 5):
        r = c.offer(0, _buf(i, i * 0.1))
        if r is not None:
            emitted += 1
            assert r[1].data[0] == 100  # slow source duplicated
    assert emitted == 5


def test_base_locks_to_designated_source():
    c = SyncCollector(2, policy=SyncPolicy.BASE, base_index=1)
    c.offer(0, _buf(1, 0.0))
    c.offer(0, _buf(2, 0.1))
    c.offer(0, _buf(3, 0.2))
    r = c.offer(1, _buf(99, 0.19))
    assert r is not None
    assert r[1].data[0] == 99
    assert r[0].data[0] == 3  # nearest to base pts


def test_eos_tracking():
    c = SyncCollector(2)
    c.offer(0, Buffer.eos_buffer())
    assert not c.all_eos()
    c.offer(1, Buffer.eos_buffer())
    assert c.all_eos()


def test_base_pad_eos_exhausts_collector():
    """EOS on the base pad under base:<idx>: nothing can emit anymore,
    even though the other pad is still live."""
    c = SyncCollector(2, policy=SyncPolicy.BASE, base_index=1)
    c.offer(0, _buf(1, 0.0))
    assert not c.exhausted()
    c.offer(1, Buffer.eos_buffer())
    assert c.exhausted()          # base gone -> no future frame sets
    assert not c.all_eos()        # pad 0 still live
    assert c.offer(0, _buf(2, 0.1)) is None  # live pad alone can't emit


def test_base_pad_eos_drains_queue_before_exhaustion():
    """Base frames queued before EOS still pair up; exhaustion only
    once the base queue drains."""
    c = SyncCollector(2, policy=SyncPolicy.BASE, base_index=0)
    # pad 1 silent, so base frames queue up instead of emitting
    c.offer(0, _buf(1, 0.0))
    c.offer(0, _buf(2, 0.1))
    c.offer(0, Buffer.eos_buffer())
    assert not c.exhausted()      # base frames still queued
    r = c.offer(1, _buf(8, 0.05))
    assert r is not None and r[0].data[0] in (1, 2)
    while not c.exhausted():
        r = c.offer(1, _buf(9, 0.2))
        assert r is not None      # queued base frames keep pairing up
    assert c.exhausted() and not c.all_eos()


def test_base_eos_forwards_eos_downstream_early():
    """A mux locked to a base pad must forward EOS as soon as the base
    ends — not wait for the other (possibly infinite) source."""
    from repro.core.elements.routing import TensorMux
    from repro.core.elements.sinks import TensorSink
    mux = TensorMux("m", num_sinks=2, sync="base:0")
    sink = TensorSink("s", keep=True)
    mux.link(sink)
    mux.chain(mux.sinkpads["sink_1"], _buf(9, 0.0))
    mux.chain(mux.sinkpads["sink_0"], _buf(1, 0.0))
    assert sink.n_received == 1
    mux.chain(mux.sinkpads["sink_0"], Buffer.eos_buffer())
    assert sink.eos_seen.is_set()  # other pad never sent EOS
    # stray frames after base EOS are dropped, not emitted
    mux.chain(mux.sinkpads["sink_1"], _buf(10, 0.1))
    assert sink.n_received == 1


def test_fastest_silent_source_gates_until_first_frame():
    """fastest: a source that has produced nothing (and not ended) gates
    emission — there is no latest frame to duplicate yet."""
    c = SyncCollector(2, policy=SyncPolicy.FASTEST)
    for i in range(4):
        assert c.offer(0, _buf(i, i * 0.1)) is None
    assert not c.exhausted()
    # first (and only) frame from the slow source unblocks everything
    r = c.offer(1, _buf(42, 0.4))
    assert r is not None


def test_fastest_duplicates_latest_after_source_eos():
    """fastest: a source that produced once then ended keeps being
    duplicated from its latest frame (duplicate-latest path)."""
    c = SyncCollector(2, policy=SyncPolicy.FASTEST)
    c.offer(1, _buf(42, 0.0))
    c.offer(0, _buf(0, 0.0))      # first emission consumes both queues
    c.offer(1, Buffer.eos_buffer())
    assert not c.exhausted()      # latest frame remains available
    for i in range(1, 4):
        r = c.offer(0, _buf(i, i * 0.1))
        assert r is not None
        assert r[1].data[0] == 42  # ended source's latest is duplicated
        assert r[0].data[0] == i


def test_fastest_source_eos_without_frames_exhausts():
    """fastest: a source that ends having produced nothing can never be
    duplicated -> the collector is exhausted."""
    c = SyncCollector(2, policy=SyncPolicy.FASTEST)
    c.offer(0, _buf(0, 0.0))
    c.offer(1, Buffer.eos_buffer())
    assert c.exhausted()
    assert c.offer(0, _buf(1, 0.1)) is None
