"""Sharding-rule unit tests + a small-mesh dry-run smoke (subprocess:
the host device count flag must precede jax init)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import build_model
from repro.models.sharding import cache_specs, paged_cache_specs, param_specs


def _leaves_with_paths(tree):
    return {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path): leaf
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]}


def test_param_specs_match_rank_and_rules():
    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, dp=("data",))
    shape_leaves = _leaves_with_paths(shapes)
    spec_leaves = _leaves_with_paths(specs)
    for path, spec in spec_leaves.items():
        assert len(spec) <= shape_leaves[path].ndim, path
    # spot checks
    assert spec_leaves["embed"] == P("model", "data")
    assert spec_leaves["blocks/s0/attn/wq"] == P(None, "data", "model")
    assert spec_leaves["blocks/s0/mlp/w_down"] == P(None, "model", "data")


@pytest.mark.parametrize("arch", ["glm4-9b", "qwen2.5-32b", "dbrx-132b",
                                  "xlstm-350m"])
def test_param_specs_rank_invariant_across_configs(arch):
    """Every config's spec tree must stay within leaf ranks (eval_shape
    only — no compilation), so new architectures can't silently ship
    rules that over-index their parameters."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, dp=("data",))
    shape_leaves = _leaves_with_paths(shapes)
    for path, spec in _leaves_with_paths(specs).items():
        assert len(spec) <= shape_leaves[path].ndim, path


def test_param_specs_divisibility_filter():
    cfg = get_config("whisper-tiny", smoke=False)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, dp=("data",),
                        axis_sizes={"data": 16, "model": 16})
    leaves = _leaves_with_paths(specs)
    # vocab 51865 is not divisible by 16 -> model axis dropped from embed
    assert leaves["embed"][0] is None


def test_moe_expert_parallel_specs():
    cfg = get_config("dbrx-132b", smoke=True)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, dp=("data",))
    leaves = _leaves_with_paths(specs)
    assert leaves["blocks/s0/moe/w_gate"][1] == "model"  # experts on TP axis


def test_cache_specs_batch1_shards_sequence():
    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(1, 512))
    specs = cache_specs(cache, dp=("data",), shard_seq_when_batch1=True)
    k_spec = specs["blocks"]["s0"]["k"]
    assert k_spec[2] == "data"  # sequence dim sharded for batch-1


def test_cache_specs_batched_decode_shards_batch():
    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 512))
    specs = cache_specs(cache, dp=("data",), shard_seq_when_batch1=False)
    k_spec = specs["blocks"]["s0"]["k"]
    assert k_spec[1] == "data"


def _paged_struct(family):
    from conftest import FAMILY_CFGS
    model = build_model(FAMILY_CFGS[family])
    return jax.eval_shape(
        lambda: model.init_paged_cache(8, 4, num_state_slots=4))


@pytest.mark.parametrize("family",
                         ["transformer", "mamba", "xlstm", "hybrid"])
def test_paged_cache_specs_pool_axis_replicated(family):
    """The serving pool's block/slot axis must never shard: pages are
    addressed by host-side tables, so every device needs every block
    resident.  TP lives on feature dims only."""
    cache = _paged_struct(family)
    shape_leaves = _leaves_with_paths(cache)
    for path, spec in _leaves_with_paths(
            paged_cache_specs(cache)).items():
        assert len(spec) <= shape_leaves[path].ndim, path
        lead = 1 if path.startswith("blocks") or "blocks/" in path else 0
        if shape_leaves[path].ndim > lead:
            assert spec[lead] is None, \
                f"{family}:{path} shards the block/slot axis"


def test_paged_cache_specs_kv_sharded_on_head_dim():
    cache = _paged_struct("transformer")
    leaves = _leaves_with_paths(paged_cache_specs(cache))
    k = next(v for p, v in leaves.items() if p.endswith("/k"))
    assert k[-1] == "model"  # (nb, bs, KV, hd): head_dim on TP axis


def test_paged_cache_specs_divisibility_filter():
    # TINY_SERVE head_dim is 8: a 16-way model axis can't divide it, so
    # the filter must drop the axis rather than emit an invalid layout
    cache = _paged_struct("transformer")
    leaves = _leaves_with_paths(
        paged_cache_specs(cache, axis_sizes={"model": 16}))
    k = next(v for p, v in leaves.items() if p.endswith("/k"))
    assert all(a is None for a in k)


def test_paged_cache_structs_and_shardings_helper():
    """The launch-layer helper mirrors the pool struct one-to-one with
    NamedShardings (works on any device count — (1,1) mesh here)."""
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.specs import paged_cache_structs_and_shardings
    from conftest import FAMILY_CFGS
    model = build_model(FAMILY_CFGS["hybrid"])
    mesh = make_serving_mesh(model=1)
    struct, shardings = paged_cache_structs_and_shardings(
        model, mesh, num_blocks=8, block_size=4, num_state_slots=4)
    assert (jax.tree_util.tree_structure(struct)
            == jax.tree_util.tree_structure(shardings))
    from jax.sharding import NamedSharding
    assert all(isinstance(s, NamedSharding)
               for s in jax.tree_util.tree_leaves(shardings))


DRYRUN_SMOKE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import build_model
from repro.models.sharding import param_specs
from repro.training import TrainState, make_train_step
from repro.optim import adamw_init

dp, tp = {mesh}
mesh = jax.make_mesh((dp, tp), ("data", "model"))
cfg = get_config("{arch}", smoke=True)
model = build_model(cfg, remat=True)
params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
pspecs = param_specs(params_s, dp=("data",),
                     axis_sizes={{"data": dp, "model": tp}})
state_s = jax.eval_shape(lambda p: TrainState(p, adamw_init(p)), params_s)
state_specs = TrainState(params=pspecs,
                         opt=type(state_s.opt)(step=P(), m=pspecs, v=pspecs))
state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs)
batch = {{"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
          "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}}
batch_sh = {{k: NamedSharding(mesh, P("data", None)) for k in batch}}
step = make_train_step(model)
with mesh:
    lowered = jax.jit(step, in_shardings=(state_sh, batch_sh)).lower(state_s, batch)
    compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):  # older jax returns one dict per device
    ca = ca[0]
print("COMPILED_OK", ca.get("flops", 0) > 0)
"""


def _run_dryrun(n_dev, mesh, arch):
    env = dict(os.environ, PYTHONPATH="src")
    src = DRYRUN_SMOKE.format(n_dev=n_dev, mesh=mesh, arch=arch)
    out = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "COMPILED_OK True" in out.stdout, out.stdout + out.stderr


def test_dryrun_smoke_on_4_host_devices():
    _run_dryrun(4, (2, 2), "smollm-360m")


@pytest.mark.parametrize("n_dev,mesh,arch", [
    (1, (1, 1), "smollm-360m"),    # degenerate mesh must still compile
    (2, (1, 2), "smollm-360m"),    # pure tensor parallel
    (2, (2, 1), "glm4-9b"),        # pure data parallel, second config
    (8, (2, 4), "smollm-360m"),    # 8-host mixed
    (8, (4, 2), "qwen2.5-32b"),    # 8-host, dp-heavy, third config
])
def test_dryrun_mesh_sweep(n_dev, mesh, arch):
    _run_dryrun(n_dev, mesh, arch)
