"""Chaos suite: fault-injected serving must degrade request-by-request.

Every scenario threads a :class:`FaultPlan` through the serving seams
(``server_send``, ``engine_step``, ``admit``, ``worker``, ``submit``)
and asserts the blast radius of each injected failure: exactly the
affected requests reach a terminal frame with the right status, the
server keeps accepting and answering, and the block pool's invariant

    ``n_free + n_live == num_blocks`` and ``n_reserved == 0``

holds once the dust settles — nothing leaks, nothing wedges.

Network scenarios run the deterministic ToyModel (closed-form expected
tokens); pool-accounting scenarios run the tiny paged transformer so
real block/slab accounting is exercised.  The frame-parser fuzz tests
degrade to deterministic examples when hypothesis is not installed
(same pattern as test_kv_paged).
"""
import contextlib
import importlib.util
import socket
import struct
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.elements.query import (CONN_QID, HDR, MAGIC, MAX_PAYLOAD,
                                       MSG_CANCEL, MSG_DONE, MSG_ERROR,
                                       MSG_REQUEST, MSG_TOKENS,
                                       ProtocolError, QueryConnection,
                                       STATUS_CODES, STATUS_NAMES, VERSION,
                                       pack_frame, pack_tensor, read_frame,
                                       unpack_tensor)
from repro.models import build_model
from repro.serving import (CacheFullError, Fault, FaultPlan, ServeEngine,
                           TensorQueryClient, TensorQueryServer)

from test_kv_paged import TINY, _fresh_dense_tokens
from test_serve_continuous import ToyModel, _expected

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


@pytest.fixture(scope="module")
def tiny_model():
    model = build_model(TINY)
    return model, model.init(jax.random.PRNGKey(0))


def _wait_until(cond, timeout=10.0, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return
        time.sleep(0.01)
    raise AssertionError(f"timed out waiting for {what}")


def _assert_pool_clean(eng):
    """The acceptance invariant: after a drained workload no block is
    leaked (free incl. retained + live == pool) and no reservation is
    left dangling."""
    stats = eng.pool_stats()
    if stats is None:                       # dense engine: no pool
        return
    assert stats["n_free"] + stats["n_live"] == stats["num_blocks"], stats
    assert stats["n_reserved"] == 0, stats


def _run(eng, timeout=60.0):
    out = []
    deadline = time.monotonic() + timeout
    while eng.has_work and time.monotonic() < deadline:
        out.extend(eng.step())
    assert not eng.has_work, "engine did not drain in time"
    return out


@contextlib.contextmanager
def _toy_server(plan=None, *, max_new=6, pause_limit=64, batch_size=4,
                workers=4):
    eng = ServeEngine(ToyModel(), params={}, batch_size=batch_size,
                      capacity=16 + max_new + 2, max_new_tokens=max_new,
                      fault_plan=plan)
    srv = TensorQueryServer(eng, max_wait_ms=5.0, pad_to=16, workers=workers,
                            pause_limit=pause_limit, fault_plan=plan).start()
    try:
        yield eng, srv
    finally:
        srv.stop()


def _paged_engine(model, params, plan=None, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("capacity", 32)
    kw.setdefault("max_new_tokens", 4)
    kw.setdefault("block_size", 4)
    kw.setdefault("num_blocks", 8)
    kw.setdefault("prefill_chunk", 16)
    return ServeEngine(model, params, fault_plan=plan, **kw)


def _rng_prompt(rng, n):
    return rng.integers(1, TINY.vocab_size, n).astype(np.int32)


# =====================================================================
# credit flow control: unit level (socketpair, no engine)
# =====================================================================

@contextlib.contextmanager
def _conn_pair(**kw):
    a, b = socket.socketpair()
    conn = QueryConnection(a, ("unit", 0), **kw)
    try:
        yield conn, b
    finally:
        conn.close()
        b.close()


def _tok(v):
    return pack_tensor(np.asarray([v], np.int32))


def test_credit_pauses_at_zero_and_grant_flushes_in_order():
    with _conn_pair() as (conn, peer):
        conn.grant_credit(5, 1)                       # credited route, 1 frame
        assert conn.send_tokens(5, _tok(10)) is True
        assert conn.send_tokens(5, _tok(11)) == "paused"
        assert conn.send_tokens(5, _tok(12)) == "paused"
        assert conn.n_paused_for(5) == 2
        assert conn.n_dropped == 0                    # paused, never dropped
        conn.grant_credit(5, 10)
        assert conn.n_paused_for(5) == 0
        got = [unpack_tensor(read_frame(peer)[5])[0] for _ in range(3)]
        assert got == [10, 11, 12]                    # order preserved


def test_credit_pause_buffer_overflow_reports_overrun():
    with _conn_pair(pause_limit=2) as (conn, peer):
        conn.grant_credit(7, 0)                       # credited, zero credit
        assert conn.send_tokens(7, _tok(1)) == "paused"
        assert conn.send_tokens(7, _tok(2)) == "paused"
        assert conn.send_tokens(7, _tok(3)) == "overrun"
        assert conn.n_overruns == 1
        assert conn.n_paused_for(7) == 2              # buffer kept, not grown


def test_terminal_done_flushes_paused_tokens_ahead_of_itself():
    with _conn_pair() as (conn, peer):
        conn.grant_credit(3, 0)
        assert conn.send_tokens(3, _tok(40)) == "paused"
        assert conn.send_tokens(3, _tok(41)) == "paused"
        conn.send_frame(MSG_DONE, 3, pack_tensor(np.asarray([40, 41],
                                                            np.int32)))
        frames = [read_frame(peer) for _ in range(3)]
        assert [f[0] for f in frames] == [MSG_TOKENS, MSG_TOKENS, MSG_DONE]
        assert [unpack_tensor(f[5])[0] for f in frames[:2]] == [40, 41]
        # route state retired with the terminal frame
        assert conn.n_paused_for(3) == 0


def test_legacy_route_still_best_effort_drop():
    """A route that never sent CREDIT keeps the old contract: TOKENS
    drop on overflow instead of pausing (DONE stays authoritative)."""
    with _conn_pair() as (conn, peer):
        assert conn.send_tokens(9, _tok(1)) is True   # no credit state at all
        assert conn.n_paused_for(9) == 0
        assert conn.n_paused == 0


# =====================================================================
# frame parser fuzz (satellite: hardening against malformed bytes)
# =====================================================================

class _ByteSock:
    """In-memory socket feeding at most ``chunk`` bytes per recv."""

    def __init__(self, data, chunk=1 << 20):
        self.data, self.off, self.chunk = data, 0, chunk

    def recv(self, n):
        part = self.data[self.off:self.off + min(n, self.chunk)]
        self.off += len(part)
        return part


def test_read_frame_eof_and_truncated_header():
    assert read_frame(_ByteSock(b"")) is None          # orderly EOF
    frame = pack_frame(MSG_TOKENS, 1, _tok(5))
    for cut in range(1, HDR.size):                     # EOF mid-header
        with pytest.raises(ConnectionError, match="mid-frame"):
            read_frame(_ByteSock(frame[:cut]))
    with pytest.raises(ConnectionError, match="mid-frame"):
        read_frame(_ByteSock(frame[:-1]))              # EOF mid-payload


def test_read_frame_rejects_bad_magic_version_and_length():
    with pytest.raises(ProtocolError, match="magic"):
        read_frame(_ByteSock(b"XX" + pack_frame(MSG_TOKENS, 1)[2:]))
    bad_ver = HDR.pack(MAGIC, VERSION + 1, MSG_REQUEST, 0, 0, 0, 0.0, 0)
    with pytest.raises(ProtocolError, match="version"):
        read_frame(_ByteSock(bad_ver))
    absurd = HDR.pack(MAGIC, VERSION, MSG_TOKENS, 0, 0, 0, 0.0,
                      MAX_PAYLOAD + 1)
    with pytest.raises(ProtocolError, match="exceeds"):
        read_frame(_ByteSock(absurd))


def test_read_frame_byte_at_a_time_roundtrip():
    arr = np.arange(7, dtype=np.int32)
    frame = pack_frame(MSG_DONE, 42, pack_tensor(arr), status=3)
    msg, qid, lane, status, deadline, payload = \
        read_frame(_ByteSock(frame, chunk=1))
    assert (msg, qid, status) == (MSG_DONE, 42, 3)
    assert np.array_equal(unpack_tensor(payload), arr)


def _parser_never_hangs(data):
    """The parser's full contract on arbitrary bytes: a tuple, None, or
    ProtocolError/ConnectionError — never any other exception."""
    try:
        out = read_frame(_ByteSock(bytes(data), chunk=3))
    except (ProtocolError, ConnectionError):
        return
    assert out is None or (isinstance(out, tuple) and len(out) == 6)


if HAVE_HYPOTHESIS:
    from hypothesis import given, settings as hyp_settings
    from hypothesis import strategies as st

    @given(st.binary(max_size=3 * HDR.size))
    @hyp_settings(deadline=None)
    def test_read_frame_fuzz_arbitrary_bytes(data):
        _parser_never_hangs(data)

    @given(st.binary(min_size=HDR.size, max_size=HDR.size))
    @hyp_settings(deadline=None)
    def test_read_frame_fuzz_header_mutations(hdr):
        _parser_never_hangs(bytes(hdr))
else:
    def test_read_frame_fuzz_arbitrary_bytes():
        rng = np.random.default_rng(0)
        for n in (0, 1, HDR.size - 1, HDR.size, HDR.size + 5, 64):
            for _ in range(50):
                _parser_never_hangs(rng.integers(0, 256, n,
                                                 dtype=np.uint8).tobytes())

    def test_read_frame_fuzz_header_mutations():
        base = bytearray(pack_frame(MSG_REQUEST, 3, b""))
        for i in range(len(base)):
            for v in (0, 1, 0x7F, 0xFF):
                mutated = bytearray(base)
                mutated[i] = v
                _parser_never_hangs(bytes(mutated))


# =====================================================================
# engine-level: cancel, isolation, restart, admission storms
# =====================================================================

def test_cancel_queued_request_frees_nothing_and_answers(tiny_model):
    model, params = tiny_model
    eng = _paged_engine(model, params, batch_size=1)
    rng = np.random.default_rng(3)
    first = _rng_prompt(rng, 6)
    queued = _rng_prompt(rng, 6)
    rid_a = eng.submit(first)
    while eng.n_active < 1:
        eng.step()
    rid_q = eng.submit(queued)               # batch_size 1: must queue
    assert eng.cancel(rid_q) is True
    res = {r.request_id: r
           for r in eng.wait([rid_a, rid_q], timeout_s=120)}
    assert res[rid_q].status == "cancelled"
    assert len(res[rid_q].tokens) == 0       # never started
    assert res[rid_a].status == "ok"
    assert list(res[rid_a].tokens) == \
        _fresh_dense_tokens(model, params, first, 4)
    assert eng.n_cancelled == 1
    _assert_pool_clean(eng)


def test_cancel_mid_decode_frees_blocks_and_retained_registrations(
        tiny_model):
    """The acceptance scenario: cancelling a mid-decode request returns
    its blocks AND retires any content-table registrations its full
    pages acquired — both pools, not just the obvious one."""
    model, params = tiny_model
    eng = _paged_engine(model, params, max_new_tokens=8, capacity=48,
                        num_blocks=10)
    rng = np.random.default_rng(4)
    prompt = _rng_prompt(rng, 8)             # 2 full pages: registrable
    rid = eng.submit(prompt)
    while not any(s is not None and s.rid == rid and len(s.tokens) >= 2
                  for s in eng._slots):
        eng.step()                           # mid-decode, partial tokens
    assert eng.cancel(rid) is True
    res = eng._results[rid]
    assert res.status == "cancelled"
    assert 0 < len(res.tokens) < 8           # partial sequence preserved
    expected = _fresh_dense_tokens(model, params, prompt, 8)
    assert list(res.tokens) == expected[:len(res.tokens)]
    stats = eng.pool_stats()
    assert stats["n_live"] == 0              # every block back
    assert stats["n_retained"] == 0          # registrations retired too
    assert stats["n_table"] == 0
    _assert_pool_clean(eng)


def test_cancel_unknown_or_finished_returns_false():
    eng = ServeEngine(ToyModel(), params={}, batch_size=2, capacity=64,
                      max_new_tokens=4)
    assert eng.cancel(999) is False          # unknown rid
    rid = eng.submit(np.asarray([2, 3], np.int32))
    _run(eng)
    assert eng.cancel(rid) is False          # already finished: result kept
    assert eng._results[rid].status == "ok"
    assert eng.n_cancelled == 0


def test_submit_rejects_out_of_vocab_prompt(tiny_model):
    model, params = tiny_model
    eng = _paged_engine(model, params)
    with pytest.raises(ValueError, match="vocab"):
        eng.submit(np.asarray([1, TINY.vocab_size + 7], np.int32))
    with pytest.raises(ValueError, match="vocab"):
        eng.submit(np.asarray([-2, 3], np.int32))
    assert not eng.has_work                  # nothing was admitted
    _assert_pool_clean(eng)


def test_engine_step_fault_restarts_and_respills_survivors(tiny_model):
    """A non-attributable step exception mid-decode: survivors are
    spilled via the preemption path, the pools are rebuilt, and the
    replayed requests finish bit-identical to the no-fault oracle."""
    model, params = tiny_model
    plan = FaultPlan([Fault(point="engine_step", nth=6)])
    eng = _paged_engine(model, params, plan, max_new_tokens=6, capacity=48,
                        num_blocks=12)
    rng = np.random.default_rng(5)
    prompts = [_rng_prompt(rng, 6), _rng_prompt(rng, 9)]
    rids = [eng.submit(p) for p in prompts]
    res = {r.request_id: r for r in _run(eng, timeout=120)}
    assert eng.n_restarts == 1 and eng.n_step_failures == 1
    for rid, p in zip(rids, prompts):
        assert res[rid].status == "ok", res[rid].error
        assert list(res[rid].tokens) == \
            _fresh_dense_tokens(model, params, p, 6)
    _assert_pool_clean(eng)


def test_engine_step_fault_dense_fails_inflight_keeps_queued():
    """Dense mode has no spill path: the in-flight slot is failed with
    a clear error, queued work survives the restart untouched."""
    plan = FaultPlan([Fault(point="engine_step", nth=3)])
    eng = ServeEngine(ToyModel(), params={}, batch_size=1, capacity=64,
                      max_new_tokens=4, fault_plan=plan)
    a = np.asarray([2, 3], np.int32)
    b = np.asarray([4, 5], np.int32)
    rid_a = eng.submit(a)
    while eng.n_active < 1:
        eng.step()
    rid_b = eng.submit(b)                    # queued behind a
    res = {r.request_id: r
           for r in eng.wait([rid_a, rid_b], timeout_s=60)}
    assert res[rid_a].status == "error"
    assert "restart" in res[rid_a].error
    assert res[rid_b].status == "ok"
    assert list(res[rid_b].tokens) == _expected(b, 4)
    assert eng.n_restarts == 1


def test_engine_wedged_past_restart_budget_fails_everything():
    plan = FaultPlan([Fault(point="engine_step", nth=1, times=2,
                            msg="hbm parity storm")])
    eng = ServeEngine(ToyModel(), params={}, batch_size=2, capacity=64,
                      max_new_tokens=4, max_restarts=1, fault_plan=plan)
    rid = eng.submit(np.asarray([2, 3], np.int32))
    assert eng.step() == []                  # failure 1: restart, absorbed
    with pytest.raises(RuntimeError, match="hbm parity storm"):
        eng.step()                           # failure 2 > budget: re-raised
    res = eng._results[rid]
    assert res.status == "error"
    assert "wedged" in res.error
    # the engine recovers once the storm passes: pools were reset
    ok = eng.submit(np.asarray([4, 5], np.int32))
    out = {r.request_id: r for r in _run(eng)}
    assert out[ok].status == "ok"
    assert list(out[ok].tokens) == _expected(np.asarray([4, 5]), 4)


def test_admission_cachefull_storm_keeps_candidate_queued(tiny_model):
    """An allocator trip during the fit check must park the candidate,
    not fail it: when the storm passes it admits and completes."""
    model, params = tiny_model
    plan = FaultPlan([Fault(point="admit", nth=1, times=3,
                            exc=CacheFullError, msg="injected storm")])
    eng = _paged_engine(model, params, plan)
    rng = np.random.default_rng(6)
    prompt = _rng_prompt(rng, 6)
    rid = eng.submit(prompt)
    res = {r.request_id: r for r in _run(eng, timeout=120)}
    assert plan.arrivals("admit") > 3        # storm was actually ridden out
    assert res[rid].status == "ok"
    assert list(res[rid].tokens) == _fresh_dense_tokens(model, params,
                                                        prompt, 4)
    _assert_pool_clean(eng)


# =====================================================================
# wire-level: cancel, credit, isolation, send faults, drain
# =====================================================================

def test_wire_cancel_mid_stream_returns_partial_tokens():
    with _toy_server(max_new=200) as (eng, srv):
        cli = TensorQueryClient("127.0.0.1", srv.port)
        prompt = np.asarray([1, 2, 3], np.int32)
        qid = cli.submit(prompt)
        _wait_until(lambda: cli._requests[qid].stream,
                    what="first streamed token")
        cli.cancel(qid)
        r = cli.result(qid, timeout=30)
        assert r.status == "cancelled"
        assert 0 < len(r.tokens) < 200       # partial, not empty, not full
        assert list(r.tokens) == _expected(prompt, 200)[:len(r.tokens)]
        _wait_until(lambda: not srv._routes, what="routes to drain")
        assert eng.n_cancelled == 1
        cli.close()


def test_wire_cancel_unknown_qid_answers_empty_done_cancelled():
    """A CANCEL racing ahead of its REQUEST (or for a qid the server
    never saw) must still answer — the client is never left hanging."""
    with _toy_server() as (eng, srv):
        raw = socket.create_connection(("127.0.0.1", srv.port), timeout=5)
        raw.sendall(pack_frame(MSG_CANCEL, 77))
        msg, qid, _, status, _, payload = read_frame(raw)
        assert (msg, qid) == (MSG_DONE, 77)
        assert STATUS_NAMES[status] == "cancelled"
        assert unpack_tensor(payload).size == 0
        raw.close()
        assert srv.src.n_cancels == 1


def test_wire_credited_route_pauses_never_drops():
    with _toy_server() as (eng, srv):
        cli = TensorQueryClient("127.0.0.1", srv.port)
        _wait_until(lambda: len(srv.src.connections) == 1,
                    what="connection accepted")
        sconn = srv.src.connections[0]
        prompt = np.asarray([1, 2, 3], np.int32)
        qid = cli.submit(prompt, credit=2)   # 2 frames, then pause
        r = cli.result(qid, timeout=30)
        assert r.status == "ok"
        # nothing dropped: DONE flushed the paused tail ahead of itself,
        # so the client saw the complete stream despite zero refills
        assert r.stream == list(r.tokens) == _expected(prompt, 6)
        assert sconn.n_paused >= 6 - 2
        assert sconn.n_dropped == 0
        cli.close()


def test_wire_credit_starved_route_killed_with_overrun():
    with _toy_server(max_new=40, pause_limit=2) as (eng, srv):
        cli = TensorQueryClient("127.0.0.1", srv.port)
        prompt = np.asarray([1, 2, 3], np.int32)
        qid = cli.submit(prompt, credit=1)   # 1 frame, 2 pauses, then overrun
        r = cli.result(qid, timeout=30)
        assert r.status == "overrun"
        assert 0 < len(r.tokens) < 40        # partial sequence delivered
        assert srv.n_overrun_kills == 1
        # the connection survives its killed route
        ok = cli.submit(np.asarray([4, 5], np.int32))
        assert cli.result(ok, timeout=30).status == "ok"
        cli.close()


def test_wire_submit_fault_fails_one_row_isolated():
    plan = FaultPlan([Fault(point="submit", nth=1, msg="poison row")])
    with _toy_server(plan) as (eng, srv):
        cli = TensorQueryClient("127.0.0.1", srv.port)
        prompts = [np.asarray([i + 1, i + 2], np.int32) for i in range(3)]
        qids = [cli.submit(p) for p in prompts]
        results = [cli.result(q, timeout=30) for q in qids]
        statuses = sorted(r.status for r in results)
        assert statuses == ["error", "ok", "ok"]     # exactly one row died
        bad = next(r for r in results if r.status == "error")
        assert "poison row" in bad.error
        for p, r in zip(prompts, results):
            if r.status == "ok":
                assert list(r.tokens) == _expected(p, 6)
        cli.close()


def test_wire_worker_fault_kills_batch_server_survives():
    plan = FaultPlan([Fault(point="worker", nth=1, msg="worker died")])
    with _toy_server(plan) as (eng, srv):
        cli = TensorQueryClient("127.0.0.1", srv.port)
        qids = [cli.submit(np.asarray([i + 1, i + 2], np.int32))
                for i in range(3)]
        results = [cli.result(q, timeout=30) for q in qids]
        # every affected row reached a terminal ERROR frame — none hang
        assert all(r.status in ("ok", "error") for r in results)
        assert any(r.status == "error" and "worker died" in r.error
                   for r in results)
        # the server keeps serving after the dead worker batch
        ok = cli.submit(np.asarray([9, 9], np.int32))
        r = cli.result(ok, timeout=30)
        assert r.status == "ok"
        assert list(r.tokens) == _expected(np.asarray([9, 9]), 6)
        _wait_until(lambda: not srv._routes, what="routes to drain")
        cli.close()


def test_wire_server_close_fault_client_reconnects_and_resubmits():
    plan = FaultPlan([Fault(point="server_send", nth=1, action="close")])
    with _toy_server(plan) as (eng, srv):
        cli = TensorQueryClient("127.0.0.1", srv.port, reconnect=True,
                                retries=5, backoff=0.02)
        prompt = np.asarray([1, 2, 3], np.int32)
        qid = cli.submit(prompt)
        # first outbound frame tears the server-side socket down; the
        # client redials and replays the never-started query as-is
        r = cli.result(qid, timeout=30)
        assert r.status == "ok"
        assert list(r.tokens) == _expected(prompt, 6)
        assert cli.n_reconnects >= 1
        assert cli.n_resubmitted >= 1
        cli.close()


def test_wire_partial_frame_fault_fails_client_cleanly():
    plan = FaultPlan([Fault(point="server_send", nth=1, action="partial",
                            cut_at=4)])
    with _toy_server(plan) as (eng, srv):
        cli = TensorQueryClient("127.0.0.1", srv.port)
        qid = cli.submit(np.asarray([1, 2, 3], np.int32))
        r = cli.result(qid, timeout=30)      # 4 bytes then EOF: clean error
        assert r.status == "error"
        assert r.ttft_s is not None and r.latency_s is not None
        # a fresh connection works: the fault burned only one socket
        cli2 = TensorQueryClient("127.0.0.1", srv.port)
        ok = cli2.submit(np.asarray([4, 5], np.int32))
        assert cli2.result(ok, timeout=30).status == "ok"
        cli.close()
        cli2.close()


def test_wire_garbage_and_version_mismatch_never_kill_accept_loop():
    with _toy_server() as (eng, srv):
        # garbage magic: connection-scoped ERROR, then closed
        g = socket.create_connection(("127.0.0.1", srv.port), timeout=5)
        g.sendall(b"GARBAGE-NOT-A-FRAME-" * 4)
        msg, qid, _, status, _, payload = read_frame(g)
        assert (msg, qid) == (MSG_ERROR, CONN_QID)
        assert b"magic" in payload
        assert read_frame(g) is None         # server closed its side
        g.close()
        # wrong protocol version: rejected the same way, naming versions
        v = socket.create_connection(("127.0.0.1", srv.port), timeout=5)
        v.sendall(HDR.pack(MAGIC, VERSION + 1, MSG_REQUEST, 0, 0, 0, 0.0, 0))
        msg, qid, _, _, _, payload = read_frame(v)
        assert (msg, qid) == (MSG_ERROR, CONN_QID)
        assert b"version" in payload
        v.close()
        # a connection that dies instantly mid-handshake is shrugged off
        socket.create_connection(("127.0.0.1", srv.port), timeout=5).close()
        # ...and a clean client still gets served after all three
        cli = TensorQueryClient("127.0.0.1", srv.port)
        r = cli.result(cli.submit(np.asarray([2, 3], np.int32)), timeout=30)
        assert r.status == "ok"
        cli.close()


def test_client_close_fails_inflight_immediately():
    """close() must complete every in-flight QueryResult with a
    connection error *now* — not strand waiters until their timeout."""
    with _toy_server() as (eng, srv):
        cli = TensorQueryClient("127.0.0.1", srv.port)
        _wait_until(lambda: len(srv.src.connections) == 1,
                    what="connection accepted")
        gate = threading.Event()
        sconn = srv.src.connections[0]

        class _Wedged:
            def __init__(self, sock):
                self._sock = sock

            def sendall(self, data):
                gate.wait(timeout=30.0)
                return self._sock.sendall(data)

            def __getattr__(self, name):
                return getattr(self._sock, name)

        sconn.sock = _Wedged(sconn.sock)     # no frame reaches the client
        try:
            qid = cli.submit(np.asarray([1, 2, 3], np.int32))
            res = cli._requests[qid]
            t0 = time.monotonic()
            cli.close()
            closed_in = time.monotonic() - t0
            assert closed_in < 5.0           # did not wait out any timeout
            assert res.done.is_set()
            assert res.status == "error"
            assert "closed" in res.error
        finally:
            gate.set()


def test_drain_finishes_inflight_then_rejects_new_requests():
    with _toy_server() as (eng, srv):
        cli = TensorQueryClient("127.0.0.1", srv.port)
        prompts = [np.asarray([i + 1, i + 2], np.int32) for i in range(3)]
        qids = [cli.submit(p) for p in prompts]
        # make sure all three cleared the front door before it shuts
        _wait_until(lambda: srv.src.n_requests == 3,
                    what="requests to be accepted")
        assert srv.drain(timeout=30.0) is True
        for p, q in zip(prompts, qids):      # everything answered first
            r = cli.result(q, timeout=10)
            assert r.status == "ok"
            assert list(r.tokens) == _expected(p, 6)
        # the still-open connection gets a clean rejection, not silence
        late = cli.submit(np.asarray([8, 8], np.int32))
        r = cli.result(late, timeout=10)
        assert r.status == "error"
        assert "draining" in r.error
        # and the listener is closed for new connections
        with pytest.raises(OSError):
            socket.create_connection(("127.0.0.1", srv.port), timeout=0.5)
        cli.close()


def test_drain_timeout_cancels_leftovers_with_partial_tokens():
    with _toy_server(max_new=2000) as (eng, srv):
        cli = TensorQueryClient("127.0.0.1", srv.port)
        qid = cli.submit(np.asarray([1, 2, 3], np.int32))
        _wait_until(lambda: cli._requests[qid].stream,
                    what="request to start streaming")
        assert srv.drain(timeout=0.2) is False
        r = cli.result(qid, timeout=10)      # still answered: DONE(timeout)
        assert r.status == "timeout"
        assert 0 < len(r.tokens) < 200
        cli.close()


# =====================================================================
# the storm test: mixed faults at a rate, then full accounting audit
# =====================================================================

def test_chaos_storm_paged_pool_invariant_and_no_leaked_routes(tiny_model):
    """Sustained mixed-fault load on the paged wire path: poison rows
    and cancels land between healthy requests.  Afterwards every qid is
    terminal, the block pool balances, and the route table is empty."""
    model, params = tiny_model
    plan = FaultPlan([Fault(point="submit", every=5, msg="storm poison")])
    eng = _paged_engine(model, params, plan, batch_size=2, capacity=32,
                        max_new_tokens=4, num_blocks=10)
    srv = TensorQueryServer(eng, max_wait_ms=5.0, pad_to=16, workers=2,
                            fault_plan=plan).start()
    try:
        cli = TensorQueryClient("127.0.0.1", srv.port)
        rng = np.random.default_rng(11)
        prompts = [_rng_prompt(rng, int(rng.integers(4, 10)))
                   for _ in range(12)]
        qids = [cli.submit(p) for p in prompts]
        cancelled = set()
        for q in qids[::4]:                  # sprinkle cancels into the storm
            cli.cancel(q)
            cancelled.add(q)
        results = {q: cli.result(q, timeout=120) for q in qids}
        # every single request reached a terminal status — nothing hangs
        n_err = sum(r.status == "error" for r in results.values())
        assert all(r.status in ("ok", "error", "cancelled")
                   for r in results.values())
        assert n_err >= 1                    # the storm actually hit
        for q, r in results.items():     # qids are 0..11 in submit order
            if r.status == "error":
                assert "storm poison" in r.error
            elif r.status == "ok" and q not in cancelled:
                assert list(r.tokens) == _fresh_dense_tokens(
                    model, params, prompts[q], 4)
        _wait_until(lambda: not srv._routes, what="route table to empty")
        assert not srv._rev                  # reverse index drained too
        _assert_pool_clean(eng)
        cli.close()
    finally:
        srv.stop()
