"""Per-architecture smoke tests (deliverable f): reduced same-family
variant, one forward + one train step on CPU, shapes + finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models.frontends import fake_audio_frames, fake_vision_patches
from repro.training import TrainState, make_train_step
from repro.optim import adamw_init

B, S = 2, 16


def _extra(cfg):
    if cfg.family == "audio":
        return fake_audio_frames(cfg, B)
    if cfg.vision_seq:
        return fake_vision_patches(cfg, B)
    return None


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    arch = request.param
    cfg = get_config(arch, smoke=True).replace(compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    return arch, cfg, model, params, tokens


def test_smoke_config_is_reduced(arch_setup):
    _, cfg, *_ = arch_setup
    assert cfg.n_layers <= 2 or cfg.family in ("hybrid",)
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, model, params, tokens = arch_setup
    logits, aux = model.apply(params, tokens, _extra(cfg))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(aux)), arch


def test_train_step_updates_params(arch_setup):
    arch, cfg, model, params, tokens = arch_setup
    state = TrainState(params, adamw_init(params))
    step = make_train_step(model, peak_lr=1e-3, warmup=1, total_steps=10)
    batch = {"tokens": tokens, "labels": tokens}
    extra = _extra(cfg)
    if extra is not None:
        batch["extra_embeds"] = extra
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), arch
    assert bool(jnp.isfinite(metrics["grad_norm"])), arch
    # at least one leaf moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(new_state.params),
                        jax.tree.leaves(state.params)))
    assert moved, arch
