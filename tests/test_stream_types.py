"""Caps (stream type) negotiation — the other/tensor(s) semantics."""
import numpy as np
import pytest

from repro.core.stream import (Buffer, MediaSpec, TensorSpec, TensorsSpec,
                               specs_compatible)


def test_rank_agnostic_equivalence():
    a = TensorSpec.parse("640:480")
    b = TensorSpec.parse("640:480:1:1")
    assert a.compatible(b) and b.compatible(a)


def test_rank_pinning_tensorrt_style():
    a = TensorSpec.parse("640:480")
    b = TensorSpec(dims=(640, 480, 1, 1), require_rank=True)
    assert not a.compatible(b)
    c = TensorSpec(dims=(640, 480, 1, 1), require_rank=True)
    assert b.compatible(c)


def test_dtype_mismatch():
    a = TensorSpec(dims=(4,), dtype="float32")
    b = TensorSpec(dims=(4,), dtype="uint8")
    assert not a.compatible(b)


def test_framerate_negotiation():
    a = TensorSpec(dims=(4,), framerate=30.0)
    b = TensorSpec(dims=(4,), framerate=20.0)
    c = TensorSpec(dims=(4,))  # don't-care
    assert not a.compatible(b)
    assert a.compatible(c)


def test_tensors_bundle_limits():
    with pytest.raises(ValueError):
        TensorsSpec(tuple(TensorSpec(dims=(1,)) for _ in range(17)))
    spec = TensorsSpec((TensorSpec(dims=(3, 4)), TensorSpec(dims=(3, 4))))
    assert spec.num_tensors == 2


def test_single_tensor_promotes_to_bundle():
    a = TensorSpec(dims=(8,))
    b = TensorsSpec((TensorSpec(dims=(8,)),))
    assert specs_compatible(a, b) and specs_compatible(b, a)


def test_media_vs_tensor_incompatible():
    assert not specs_compatible(MediaSpec("video/x-raw"), TensorSpec(dims=(4,)))


def test_buffer_zero_copy_chunks():
    x = np.arange(12.0).reshape(3, 4)
    y = np.ones((2, 2))
    buf = Buffer((x, y), pts=1.0)
    assert buf.chunks[0] is x and buf.chunks[1] is y
    re = buf.with_chunks((buf.chunks[1],))
    assert re.chunks[0] is y and re.pts == 1.0


def test_buffer_spec_roundtrip():
    buf = Buffer(np.zeros((5, 7), np.float32))
    spec = buf.spec()
    assert spec.shape == (5, 7)
    assert spec.dtype == "float32"


def test_spec_nbytes_and_shape():
    s = TensorSpec.parse("640:480:3", dtype="uint8")
    assert s.shape == (3, 480, 640)
    assert s.nbytes == 640 * 480 * 3
