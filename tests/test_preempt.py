"""Preemption spill/restore conformance — the cross-family matrix.

A batch-lane slot preempted mid-decode has its KV pages and recurrent
state slab spilled to host memory and is re-admitted later into
whatever physical blocks are free.  Because attention reads go through
the page table, recurrent state rides the slot's slab, and sampler
keys are a pure function of (request, step), the restored request must
produce tokens — and per-step logits — *bit-identical* to a run that
was never preempted.  Checked for every serving family (transformer,
mamba, xLSTM, hybrid) via the ``family_model`` matrix axis.
"""
import numpy as np
import pytest

from repro.serving import ServeEngine

from test_kv_paged import TINY, _fresh_dense_tokens


def _serve_traced(model, params, prompts, *, preempt_rid=None,
                  after_tokens=2, mid_prefill=False, prefill_chunk=16,
                  temperature=0.0, top_k=None, seed=0):
    """Serve ``prompts`` on the paged engine, optionally preempting one
    request once (mid-decode after ``after_tokens`` tokens, or while
    still mid-prefill)."""
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=8, block_size=4,
                      prefill_chunk=prefill_chunk, trace_logits=True,
                      temperature=temperature, top_k=top_k, seed=seed)
    assert eng.paged
    for p in prompts:
        eng.submit(p, lane="batch")
    pending_preempt = preempt_rid is not None
    results = []
    while eng.has_work:
        if pending_preempt:
            for s in eng._slots:
                if s is None or s.rid != preempt_rid:
                    continue
                prefilled = s.prefill_off >= len(s.prompt)
                if mid_prefill and not prefilled and not s.tokens:
                    assert eng.preempt(preempt_rid)
                    pending_preempt = False
                elif (not mid_prefill and prefilled
                      and len(s.tokens) >= after_tokens):
                    assert eng.preempt(preempt_rid)
                    pending_preempt = False
                break
        results += eng.step()
    assert not pending_preempt, "never caught the slot in the target phase"
    return eng, {r.request_id: r for r in results}


def _assert_traces_equal(eng_a, eng_b, family):
    assert set(eng_a.logit_trace) == set(eng_b.logit_trace)
    for rid, trace in eng_a.logit_trace.items():
        other = eng_b.logit_trace[rid]
        assert len(trace) == len(other), (family, rid)
        for step, (x, y) in enumerate(zip(trace, other)):
            assert np.array_equal(x, y), \
                f"{family}: rid {rid} logits diverged at step {step}"


def test_preempt_restore_bit_identical_greedy(family_model):
    family, model, params = family_model
    rng = np.random.default_rng(13)
    prompts = [rng.integers(1, TINY.vocab_size, n).astype(np.int32)
               for n in (8, 6)]
    ref_eng, ref = _serve_traced(model, params, prompts)
    pre_eng, pre = _serve_traced(model, params, prompts, preempt_rid=0)
    assert pre_eng.n_preemptions == 1 and pre_eng.n_restores == 1
    for rid in ref:
        assert list(pre[rid].tokens) == list(ref[rid].tokens), (family, rid)
        assert pre[rid].status == "ok"
    _assert_traces_equal(ref_eng, pre_eng, family)
    # and both agree with the dense oracle
    for rid, p in enumerate(prompts):
        assert list(ref[rid].tokens) == \
            _fresh_dense_tokens(model, params, p, 8), family


def test_preempt_restore_bit_identical_sampled(family_model):
    """Sampler keys fold (seed, request, step) — independent of where
    the request's pages live or whether it was ever spilled."""
    family, model, params = family_model
    rng = np.random.default_rng(17)
    prompts = [rng.integers(1, TINY.vocab_size, n).astype(np.int32)
               for n in (7, 9)]
    kw = dict(temperature=0.8, top_k=8, seed=3)
    ref_eng, ref = _serve_traced(model, params, prompts, **kw)
    pre_eng, pre = _serve_traced(model, params, prompts, preempt_rid=1,
                                 after_tokens=3, **kw)
    assert pre_eng.n_preemptions == 1 and pre_eng.n_restores == 1
    for rid in ref:
        assert list(pre[rid].tokens) == list(ref[rid].tokens), (family, rid)
    _assert_traces_equal(ref_eng, pre_eng, family)


def test_preempt_mid_prefill_restarts_deterministically(family_model):
    """A slot spilled before its first token has no generated state
    worth keeping: it is restarted (fresh admission, no spill payload),
    and re-prefilling is deterministic, so the output is unchanged."""
    family, model, params = family_model
    rng = np.random.default_rng(19)
    prompts = [rng.integers(1, TINY.vocab_size, 12).astype(np.int32)]
    pre_eng, pre = _serve_traced(model, params, prompts, preempt_rid=0,
                                 mid_prefill=True, prefill_chunk=4)
    assert pre_eng.n_preemptions == 1
    assert pre_eng.n_restores == 0     # restart, not restore
    assert list(pre[0].tokens) == \
        _fresh_dense_tokens(model, params, prompts[0], 8), family


def test_preempt_pool_accounting_clean(family_model):
    """Spill + restore must leave no leaked blocks, reservations, or
    state slabs once everything drains."""
    family, model, params = family_model
    rng = np.random.default_rng(23)
    prompts = [rng.integers(1, TINY.vocab_size, n).astype(np.int32)
               for n in (8, 5)]
    eng, res = _serve_traced(model, params, prompts, preempt_rid=0)
    assert all(r.status == "ok" for r in res.values())
    assert eng.allocator.n_free == eng.allocator.num_blocks
    assert eng._reserved == 0
    if eng.state_store is not None:
        assert eng.state_store.n_live == 0, family
