"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(0)


# -- transform ----------------------------------------------------------------

@pytest.mark.parametrize("shape", [(5,), (7, 13), (3, 33, 5), (2, 8, 128)])
@pytest.mark.parametrize("dtype", [np.uint8, np.float32])
def test_transform_kernel(shape, dtype):
    from repro.kernels.transform import ops
    from repro.kernels.transform.ref import fused_transform_ref
    x = (rng.random(shape) * 200).astype(dtype)
    y = ops.fused_transform(x, scale=1 / 255.0, bias=-0.4, lo=-0.3, hi=0.3,
                            out_dtype=jnp.float32)
    yr = fused_transform_ref(jnp.asarray(x), 1 / 255.0, -0.4, -0.3, 0.3,
                             jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-6)


# -- moe gating ------------------------------------------------------------------

@pytest.mark.parametrize("T,E,k", [(7, 8, 2), (64, 16, 4), (130, 256, 8),
                                   (520, 16, 1)])
def test_gating_kernel(T, E, k):
    from repro.kernels.moe_gating import ops
    from repro.kernels.moe_gating.ref import topk_ref
    s = rng.standard_normal((T, E)).astype(np.float32)
    v, i = ops.topk(jnp.asarray(s), k)
    vr, ir = topk_ref(jnp.asarray(s), k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), atol=1e-6)
    assert np.array_equal(np.asarray(i), np.asarray(ir))


def test_gating_batched_shape():
    from repro.kernels.moe_gating import ops
    s = rng.standard_normal((2, 9, 16)).astype(np.float32)
    v, i = ops.topk(jnp.asarray(s), 3)
    assert v.shape == (2, 9, 3) and i.shape == (2, 9, 3)


# -- flash attention ---------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,S,hd,bq,bk", [
    (1, 2, 2, 32, 16, 16, 16),      # MHA
    (2, 4, 2, 64, 32, 32, 32),      # GQA
    (1, 8, 1, 48, 64, 16, 16),      # MQA, non-pow2 seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(B, H, KV, S, hd, bq, bk, dtype):
    from repro.kernels.flash_attention import ops
    from repro.kernels.flash_attention.ref import attention_ref
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd), dtype)
    o = ops.flash_attention_bshd(q, k, v, causal=True, block_q=bq, block_k=bk)
    orf = attention_ref(jnp.moveaxis(q, 2, 1).astype(jnp.float32),
                        jnp.moveaxis(k, 2, 1).astype(jnp.float32),
                        jnp.moveaxis(v, 2, 1).astype(jnp.float32), causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(jnp.moveaxis(o, 2, 1), np.float32),
                               np.asarray(orf), atol=tol, rtol=tol)


def test_flash_attention_sliding_window():
    from repro.kernels.flash_attention import ops
    from repro.kernels.flash_attention.ref import attention_ref
    B, S, H, hd, w = 1, 64, 2, 16, 24
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))
    o = ops.flash_attention_bshd(q, k, v, causal=True, sliding_window=w,
                                 block_q=16, block_k=16)
    orf = attention_ref(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                        jnp.moveaxis(v, 2, 1), causal=True, sliding_window=w)
    np.testing.assert_allclose(np.asarray(jnp.moveaxis(o, 2, 1)),
                               np.asarray(orf), atol=1e-5, rtol=1e-5)


# -- decode attention -----------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,C,hd,length", [
    (2, 4, 2, 96, 32, 70), (1, 8, 8, 64, 64, 64), (3, 6, 2, 40, 16, 1),
])
def test_decode_attention_kernel(B, H, KV, C, hd, length):
    from repro.kernels.decode_attention import ops
    from repro.kernels.decode_attention.ref import decode_attention_ref
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, hd))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, C, KV, hd))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, C, KV, hd))
    o = ops.decode_attention_bhd(q, kc, vc, length, block_k=32)
    orf = decode_attention_ref(q[:, 0], jnp.moveaxis(kc, 2, 1),
                               jnp.moveaxis(vc, 2, 1), length)
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(orf),
                               atol=1e-5, rtol=1e-5)


# -- int8 paged decode attention ----------------------------------------------

@pytest.mark.parametrize("B,H,KV,hd,nb,bs,P", [
    (3, 4, 2, 16, 12, 8, 3), (2, 8, 8, 32, 10, 16, 2),
])
def test_paged_decode_attention_quant_kernel(B, H, KV, hd, nb, bs, P):
    """Int8 kernel == dequantize-then-attend oracle (exact), and the
    int8 round-trip vs the f32 kernel stays within drift tolerance."""
    from repro.kernels.decode_attention import ops
    from repro.kernels.decode_attention.ref import (
        paged_decode_attention_quant_ref)
    from repro.models.attention import quantize_kv
    kf = jnp.asarray(rng.standard_normal((nb, bs, KV, hd)), jnp.float32)
    vf = jnp.asarray(rng.standard_normal((nb, bs, KV, hd)), jnp.float32)
    kq, ks = quantize_kv(kf)
    vq, vs = quantize_kv(vf)
    assert kq.dtype == jnp.int8 and ks.shape == (nb, bs, KV)
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    pt = jnp.asarray(np.stack([rng.permutation(nb)[:P] for _ in range(B)]),
                     jnp.int32)
    lengths = jnp.asarray(rng.integers(1, P * bs + 1, B), jnp.int32)
    o = ops.paged_decode_attention_quant_bhd(q, kq, vq, ks, vs, pt, lengths)
    orf = paged_decode_attention_quant_ref(
        q[:, 0], jnp.moveaxis(kq, 2, 1), jnp.moveaxis(vq, 2, 1),
        jnp.moveaxis(ks, 2, 1), jnp.moveaxis(vs, 2, 1), pt, lengths)
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(orf),
                               atol=1e-5, rtol=1e-5)
    of = ops.paged_decode_attention_bhd(q, kf, vf, pt, lengths)
    assert float(jnp.max(jnp.abs(o - of))) < 5e-2   # int8 drift, not exact


# -- interpret autodetect -----------------------------------------------------

def test_interpret_defaults_to_backend_autodetect():
    """Every kernels/*/ops.py entry point defaults interpret=None and
    resolves it through default_interpret(): CPU hosts autodetect to
    interpret mode (compiled Pallas silently miscompiles or crashes on
    CPU), explicit overrides pass through untouched."""
    import inspect

    from repro.kernels import default_interpret
    from repro.kernels.decode_attention.ops import (
        decode_attention_bhd, paged_decode_attention_bhd,
        paged_decode_attention_quant_bhd)
    from repro.kernels.flash_attention.ops import flash_attention_bshd
    from repro.kernels.moe_gating.ops import topk
    from repro.kernels.ssm_scan.ops import selective_scan
    from repro.kernels.transform.ops import fused_transform
    for fn in (decode_attention_bhd, paged_decode_attention_bhd,
               paged_decode_attention_quant_bhd, flash_attention_bshd,
               topk, selective_scan, fused_transform):
        sig = inspect.signature(fn)
        assert sig.parameters["interpret"].default is None, fn.__name__
    assert default_interpret() == (jax.default_backend() == "cpu")
    assert default_interpret(True) is True
    assert default_interpret(False) is False


# -- ssm scan -----------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,di,N,bd,ct", [
    (1, 16, 32, 4, 16, 8), (2, 48, 96, 8, 32, 16), (1, 100, 64, 16, 64, 32),
])
def test_ssm_scan_kernel(B, S, di, N, bd, ct):
    from repro.kernels.ssm_scan import ops
    from repro.kernels.ssm_scan.ref import selective_scan_ref
    dt = jnp.asarray(rng.random((B, S, di)).astype(np.float32) * 0.1)
    xs = jnp.asarray(rng.standard_normal((B, S, di)).astype(np.float32))
    Bc = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    Cc = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    A = -jnp.asarray(rng.random((di, N)).astype(np.float32))
    D = jnp.ones((di,), jnp.float32)
    y, h = ops.selective_scan(dt, Bc, Cc, xs, A, D, block_d=bd, chunk_t=ct)
    yr, hr = selective_scan_ref(dt, Bc, Cc, xs, A, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=2e-5,
                               rtol=2e-4)


def test_ssm_scan_matches_model_path():
    """Kernel == the model's pure-jnp selective_scan."""
    from repro.kernels.ssm_scan import ops
    from repro.models.mamba import selective_scan
    B, S, di, N = 2, 32, 64, 8
    dt = jnp.asarray(rng.random((B, S, di)).astype(np.float32) * 0.1)
    xs = jnp.asarray(rng.standard_normal((B, S, di)).astype(np.float32))
    Bc = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    Cc = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    A = -jnp.asarray(rng.random((di, N)).astype(np.float32))
    D = jnp.ones((di,), jnp.float32)
    y1, h1 = selective_scan(dt, Bc, Cc, xs, A, D)
    y2, h2 = ops.selective_scan(dt, Bc, Cc, xs, A, D, block_d=32, chunk_t=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-5,
                               rtol=2e-4)
