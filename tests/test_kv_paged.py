"""Engine conformance suite for the block-paged KV cache.

Three layers of guarantees, checked bottom-up:

  * ``BlockAllocator`` — free-list invariants (no double allocation,
    conservation, all-or-nothing failure) under unit + property tests;
  * the paged decode path — bit-for-bit identical logits to the dense
    decode path on a toy transformer, including through a *shuffled*
    page table, and the paged Pallas kernel against its oracle;
  * the ``ServeEngine`` paged scheduler — mid-decode joins produce the
    same tokens as a fresh dense run (the left-pad approximation the
    paged cache removes), eviction returns every block to the pool, and
    a request that does not fit the pool stays queued without crashing.

``hypothesis`` is optional (mirrors tests/test_property.py): the
property test skips without it, deterministic randomized fallbacks
always run.
"""
import importlib.util

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serving import BlockAllocator, CacheFullError, ServeEngine

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

TINY = ModelConfig(
    arch_id="tiny-paged", family="dense", n_layers=2, d_model=32,
    n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
    norm="rmsnorm", mlp_act="swiglu", rope="rope",
    param_dtype="float32", compute_dtype="float32")


@pytest.fixture(scope="module")
def tiny_model():
    model = build_model(TINY)
    return model, model.init(jax.random.PRNGKey(0))


def _fresh_dense_tokens(model, params, prompt, max_new, capacity=64,
                        eos_id=None):
    """Oracle: the prompt served alone, dense prefill + dense decode."""
    logits, cache = model.prefill(params, jnp.asarray(prompt)[None],
                                  capacity=capacity, cache_dtype=jnp.float32)
    toks = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    while len(toks) < max_new and toks[-1] != eos_id:
        tok = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, cache, tok, jnp.int32(pos))
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1
    return toks


# -- BlockAllocator -----------------------------------------------------------

def test_allocator_alloc_free_roundtrip():
    a = BlockAllocator(num_blocks=8, block_size=4)
    got = a.alloc(3)
    assert len(got) == len(set(got)) == 3
    assert a.n_free == 5 and a.n_live == 3
    a.free(got)
    assert a.n_free == 8 and a.n_live == 0


def test_allocator_full_is_all_or_nothing():
    a = BlockAllocator(num_blocks=4, block_size=2)
    a.alloc(3)
    before = a.n_free
    with pytest.raises(CacheFullError):
        a.alloc(2)                     # only 1 free
    assert a.n_free == before          # state untouched by the failure
    assert len(a.alloc(1)) == 1        # the last block is still available


def test_allocator_double_free_raises():
    a = BlockAllocator(num_blocks=4, block_size=2)
    (b,) = a.alloc(1)
    a.free([b])
    with pytest.raises(ValueError, match="double free"):
        a.free([b])
    with pytest.raises(ValueError):
        a.free([99])                   # foreign block


def test_allocator_blocks_for():
    a = BlockAllocator(num_blocks=4, block_size=8)
    assert a.blocks_for(0) == 1        # a slot always owns >= 1 block
    assert a.blocks_for(8) == 1
    assert a.blocks_for(9) == 2


def _run_alloc_sequence(ops):
    """Shared property body: ops is a list of (is_alloc, size_or_pick)."""
    a = BlockAllocator(num_blocks=12, block_size=4)
    live = []                          # allocation groups
    for is_alloc, x in ops:
        if is_alloc:
            try:
                got = a.alloc(x)
            except CacheFullError:
                assert x > a.n_free    # only legitimate overflow raises
                continue
            flat = [b for g in live for b in g]
            assert not set(got) & set(flat), "double allocation"
            live.append(got)
        elif live:
            a.free(live.pop(x % len(live)))
        # conservation: every block is free xor live, exactly once
        n_live = sum(len(g) for g in live)
        assert a.n_free + n_live == a.num_blocks
        assert a.n_live == n_live
    for g in live:
        a.free(g)
    assert a.n_free == a.num_blocks


def test_allocator_random_sequences_deterministic():
    rng = np.random.default_rng(7)
    for _ in range(20):
        ops = [(bool(rng.integers(0, 2)), int(rng.integers(0, 8)))
               for _ in range(60)]
        _run_alloc_sequence(ops)


if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.booleans(), st.integers(0, 15)),
                    max_size=80))
    def test_allocator_property_no_double_alloc_conservation(ops):
        _run_alloc_sequence(ops)


# -- paged decode vs dense decode: bit-for-bit --------------------------------

def _copy_dense_cache_to_pages(model, dense_cache, paged_cache, page_table,
                               block_size):
    """Scatter a B=1 dense cache's rows into pool blocks per the table."""
    pt = np.asarray(page_table)[0]
    cap = len(pt) * block_size

    def to_pages(dense_leaf, paged_leaf):
        src = np.asarray(dense_leaf)[:, 0]         # (L, C, kv, hd)
        out = np.asarray(paged_leaf).copy()
        for logical in range(min(cap, src.shape[1])):
            blk, off = pt[logical // block_size], logical % block_size
            out[:, blk, off] = src[:, logical]
        return jnp.asarray(out)

    return jax.tree.map(to_pages, dense_cache, paged_cache)


def test_paged_decode_logits_match_dense_bitwise(tiny_model):
    """Same cache content, shuffled physical placement: the paged read/
    write path must reproduce dense decode logits exactly, step after
    step (both caches evolve through their own insert paths)."""
    model, params = tiny_model
    bs, P = 4, 8                       # C = 32
    cap = bs * P
    prompt = np.array([5, 9, 3, 17, 30], np.int32)
    logits_d, dense = model.prefill(params, jnp.asarray(prompt)[None],
                                    capacity=cap, cache_dtype=jnp.float32)
    pt = jnp.asarray(
        np.random.default_rng(1).permutation(P).astype(np.int32)[None])
    paged = _copy_dense_cache_to_pages(
        model, dense, model.init_paged_cache(P, bs, dtype=jnp.float32),
        pt, bs)
    lengths = jnp.asarray([len(prompt)], jnp.int32)
    ones = jnp.asarray([1], jnp.int32)
    tok = jnp.asarray([[int(jnp.argmax(logits_d[0]))]], jnp.int32)
    for step in range(8):
        ld, dense = model.decode_step(params, dense, tok,
                                      jnp.int32(int(lengths[0])))
        lp, paged = model.paged_step(params, paged, tok, pt, lengths, ones)
        assert np.array_equal(np.asarray(ld), np.asarray(lp)), \
            f"paged/dense logits diverged at decode step {step}"
        tok = jnp.asarray([[int(jnp.argmax(ld[0]))]], jnp.int32)
        lengths = lengths + 1


def test_chunked_prefill_invariant_to_chunk_size(tiny_model):
    """The same prompt prefilled in 1/3/16-token chunks must land in the
    same engine tokens — chunking is a scheduling choice, not semantics."""
    model, params = tiny_model
    prompt = np.arange(1, 11, dtype=np.int32)
    runs = []
    for chunk in (1, 3, 16):
        eng = ServeEngine(model, params, batch_size=2, capacity=32,
                          max_new_tokens=5, block_size=4,
                          prefill_chunk=chunk)
        assert eng.paged
        runs.append(list(eng.serve([prompt])[0].tokens))
    assert runs[0] == runs[1] == runs[2]


# -- engine conformance: joins, eviction, cache-full --------------------------

def test_mid_decode_join_matches_fresh_dense_run(tiny_model):
    """The tentpole claim: a request joining mid-decode decodes at its
    *true* positions (no left-pad shift), so its tokens equal a fresh
    dense run of that prompt alone."""
    model, params = tiny_model
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=8, block_size=4, prefill_chunk=4)
    rng = np.random.default_rng(3)
    first = rng.integers(1, TINY.vocab_size, 6).astype(np.int32)
    eng.submit(first)
    for _ in range(4):                 # decode well past the join point
        eng.step()
    late = rng.integers(1, TINY.vocab_size, 9).astype(np.int32)
    eng.submit(late)
    results = []
    while eng.has_work:
        results += eng.step()
    assert eng.n_joins == 1
    by_id = {r.request_id: list(r.tokens) for r in results}
    assert by_id[0] == _fresh_dense_tokens(model, params, first, 8)
    assert by_id[1] == _fresh_dense_tokens(model, params, late, 8)


def test_concurrent_slots_each_match_fresh_runs(tiny_model):
    model, params = tiny_model
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, TINY.vocab_size, n).astype(np.int32)
               for n in (5, 9, 3, 7, 12)]
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=6, block_size=4, prefill_chunk=4)
    res = eng.serve(prompts)
    assert [r.request_id for r in res] == [0, 1, 2, 3, 4]
    for p, r in zip(prompts, res):
        assert list(r.tokens) == _fresh_dense_tokens(model, params, p, 6)
    assert eng.n_prefill_chunks > eng.n_prefills == 5  # chunked, not one-shot


def test_eviction_frees_all_blocks(tiny_model):
    model, params = tiny_model
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=4, block_size=4, prefill_chunk=4)
    total = eng.allocator.num_blocks
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, TINY.vocab_size, n).astype(np.int32)
               for n in (11, 4, 6)]
    eng.serve(prompts)
    assert eng.n_evictions == 3
    assert eng.allocator.n_free == total
    assert eng.allocator.n_live == 0
    assert eng._reserved == 0


def test_blocks_freed_as_each_request_finishes(tiny_model):
    """Pool usage must shrink the moment a slot is evicted, not at
    drain: that is what lets new requests join mid-decode."""
    model, params = tiny_model
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=3, block_size=4, prefill_chunk=8)
    short = np.array([2, 3], np.int32)
    long = np.arange(1, 13, dtype=np.int32)
    eng.submit(short)
    eng.submit(long)
    in_flight_free = None
    while eng.has_work:
        done = eng.step()
        if done and eng.n_active == 1 and in_flight_free is None:
            in_flight_free = eng.allocator.n_free
    assert in_flight_free is not None
    # after the short request finished, only the long one's blocks remain
    assert in_flight_free > 0
    assert eng.allocator.n_free == eng.allocator.num_blocks


def test_cache_full_request_stays_queued(tiny_model):
    """A pool sized for one worst-case request at a time: the second
    request must wait (no crash, no partial admission) and still run to
    the correct tokens once the first evicts."""
    model, params = tiny_model
    # worst case per request: ceil((8 prompt + 4 new) / 4) = 3 blocks
    eng = ServeEngine(model, params, batch_size=2, capacity=16,
                      max_new_tokens=4, block_size=4, num_blocks=3,
                      prefill_chunk=4)
    rng = np.random.default_rng(9)
    a = rng.integers(1, TINY.vocab_size, 8).astype(np.int32)
    b = rng.integers(1, TINY.vocab_size, 8).astype(np.int32)
    res = eng.serve([a, b])
    assert len(res) == 2
    assert eng.n_joins == 0            # b could only start after a evicted
    for p, r in zip((a, b), res):
        assert list(r.tokens) == _fresh_dense_tokens(model, params, p, 4,
                                                     capacity=32)
    assert eng.allocator.n_free == eng.allocator.num_blocks


def test_paged_mode_autodetects_and_validates(tiny_model):
    class NoPaged:
        def prefill(self, *a, **k): ...
        def decode_step(self, *a, **k): ...

    with pytest.raises(ValueError, match="paged=True"):
        ServeEngine(NoPaged(), params={}, paged=True)
    eng = ServeEngine(NoPaged(), params={})
    assert not eng.paged               # dense fallback, no allocator
    assert eng.allocator is None
    # sampling engines must keep working: auto mode falls back to dense
    # (which knows categorical sampling) instead of raising
    model, params = tiny_model
    eng = ServeEngine(model, params, greedy=False)
    assert not eng.paged
    with pytest.raises(NotImplementedError, match="greedily"):
        ServeEngine(model, params, greedy=False, paged=True)


# -- paged decode-attention kernel vs oracle ----------------------------------

def test_paged_kernel_matches_paged_ref():
    from repro.kernels.decode_attention.kernel import paged_decode_attention
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    rng = np.random.default_rng(0)
    B, H, KV, hd = 3, 4, 2, 16
    nb, bs, P = 12, 8, 3
    q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((nb, KV, bs, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((nb, KV, bs, hd)), jnp.float32)
    pt = jnp.asarray(rng.choice(nb, size=(B, P), replace=False).astype(np.int32))
    lengths = jnp.asarray([5, P * bs, 1], jnp.int32)
    o = paged_decode_attention(q, kp, vp, pt, lengths)
    r = paged_decode_attention_ref(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               atol=1e-5, rtol=1e-5)


def test_paged_ops_wrapper_matches_ref_in_engine_layout():
    """ops.paged_decode_attention_bhd takes the ServeEngine leaf layout
    (num_blocks, block_size, KV, hd); its transposition into the kernel
    layout must preserve the oracle's result."""
    from repro.kernels.decode_attention import ops
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    rng = np.random.default_rng(4)
    B, H, KV, hd = 2, 4, 2, 16
    nb, bs, P = 10, 8, 3
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    k_eng = jnp.asarray(rng.standard_normal((nb, bs, KV, hd)), jnp.float32)
    v_eng = jnp.asarray(rng.standard_normal((nb, bs, KV, hd)), jnp.float32)
    pt = jnp.asarray(rng.choice(nb, size=(B, P), replace=False).astype(np.int32))
    lengths = jnp.asarray([6, 20], jnp.int32)
    o = ops.paged_decode_attention_bhd(q, k_eng, v_eng, pt, lengths)
    r = paged_decode_attention_ref(q[:, 0], jnp.moveaxis(k_eng, 2, 1),
                                   jnp.moveaxis(v_eng, 2, 1), pt, lengths)
    assert o.shape == (B, 1, H, hd)
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(r),
                               atol=1e-5, rtol=1e-5)


def test_paged_ref_equals_dense_ref_on_contiguous_table():
    """Identity page table == plain dense cache: the two oracles must
    coincide, tying the paged kernel stack back to the dense one."""
    from repro.kernels.decode_attention.ref import (
        decode_attention_ref, paged_decode_attention_ref)
    rng = np.random.default_rng(2)
    B, H, KV, hd = 2, 4, 4, 8
    bs, P = 4, 4
    C = bs * P
    q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((B, KV, C, hd)), jnp.float32)
    vd = jnp.asarray(rng.standard_normal((B, KV, C, hd)), jnp.float32)
    lengths = jnp.asarray([7, C], jnp.int32)
    # identity layout: row b uses blocks [b*P .. b*P+P-1] in order
    kp = jnp.moveaxis(kd.reshape(B, KV, P, bs, hd), 1, 2).reshape(
        B * P, KV, bs, hd)
    vp = jnp.moveaxis(vd.reshape(B, KV, P, bs, hd), 1, 2).reshape(
        B * P, KV, bs, hd)
    pt = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    r_paged = paged_decode_attention_ref(q, kp, vp, pt, lengths)
    r_dense = decode_attention_ref(q, kd, vd, lengths)
    np.testing.assert_array_equal(np.asarray(r_paged), np.asarray(r_dense))
