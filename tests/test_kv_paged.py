"""Engine conformance suite for the block-paged KV cache.

Three layers of guarantees, checked bottom-up:

  * ``BlockAllocator`` / ``StateStore`` — free-list and slab-lifecycle
    invariants (no double allocation, no aliasing, conservation,
    all-or-nothing failure, stale state flagged until reset) under unit
    + property tests;
  * the paged decode path — bit-for-bit identical logits to the dense
    decode path, including through a *shuffled* page table, and the
    paged Pallas kernel against its oracle;
  * the ``ServeEngine`` paged scheduler — mid-decode joins produce the
    same tokens as a fresh dense run (the left-pad approximation the
    paged cache removes), eviction returns every block and state slab
    to their pools, and a request that does not fit either pool stays
    queued without crashing.

The engine guarantees run as a **cross-family conformance matrix**: the
``family_model`` fixture parametrizes them over transformer, pure-mamba,
xLSTM (mLSTM+sLSTM), and hybrid (attention+mamba, jamba-style) stacks —
one stream-pipeline substrate serving any network as a filter is the
paper's core claim, so every engine guarantee must hold for every model
family, not just attention.  (CI runs one matrix job per family via
``-k`` so a regression is attributable to its family in the Actions UI.)

``hypothesis`` is optional (mirrors tests/test_property.py): the
property tests skip without it, deterministic randomized fallbacks
always run.
"""
import importlib.util

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import RECURRENT_FAMILIES
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serving import (BlockAllocator, CacheFullError, ServeEngine,
                           StateStore)

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

TINY = ModelConfig(
    arch_id="tiny-paged", family="dense", n_layers=2, d_model=32,
    n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
    norm="rmsnorm", mlp_act="swiglu", rope="rope",
    param_dtype="float32", compute_dtype="float32")


@pytest.fixture(scope="module")
def tiny_model():
    model = build_model(TINY)
    return model, model.init(jax.random.PRNGKey(0))


def _fresh_dense_tokens(model, params, prompt, max_new, capacity=64,
                        eos_id=None):
    """Oracle: the prompt served alone, dense prefill + dense decode."""
    logits, cache = model.prefill(params, jnp.asarray(prompt)[None],
                                  capacity=capacity, cache_dtype=jnp.float32)
    toks = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    while len(toks) < max_new and toks[-1] != eos_id:
        tok = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, cache, tok, jnp.int32(pos))
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1
    return toks


# -- BlockAllocator -----------------------------------------------------------

def test_allocator_acquire_release_roundtrip():
    a = BlockAllocator(num_blocks=8, block_size=4)
    got = a.acquire(3)
    assert len(got) == len(set(got)) == 3
    assert a.n_free == 5 and a.n_live == 3
    assert all(a.ref(b) == 1 for b in got)
    a.release(got)
    assert a.n_free == 8 and a.n_live == 0


def test_allocator_full_is_all_or_nothing():
    a = BlockAllocator(num_blocks=4, block_size=2)
    a.acquire(3)
    before = a.n_free
    with pytest.raises(CacheFullError):
        a.acquire(2)                   # only 1 free
    assert a.n_free == before          # state untouched by the failure
    assert len(a.acquire(1)) == 1      # the last block is still available


def test_allocator_double_release_raises():
    a = BlockAllocator(num_blocks=4, block_size=2)
    (b,) = a.acquire(1)
    a.release([b])
    with pytest.raises(ValueError, match="double free"):
        a.release([b])
    with pytest.raises(ValueError):
        a.release([99])                # foreign block


def test_allocator_refcount_share_release():
    a = BlockAllocator(num_blocks=4, block_size=2)
    (b,) = a.acquire(1)
    a.share([b])
    a.share([b])
    assert a.ref(b) == 3
    assert a.n_shared == 1 and a.n_live == 1
    a.release([b])
    a.release([b])
    assert a.ref(b) == 1 and a.n_shared == 0
    assert a.n_free == 3               # still held by the last reference
    a.release([b])
    assert a.ref(b) == 0 and a.n_free == 4
    with pytest.raises(ValueError, match="share free"):
        a.share([b])                   # unregistered freed blocks: no refs


def test_allocator_content_table_roundtrip():
    from repro.serving import ROOT_DIGEST, chain_digest
    a = BlockAllocator(num_blocks=4, block_size=4)
    b0, b1 = a.acquire(2)
    toks0, toks1 = (1, 2, 3, 4), (5, 6, 7, 8)
    a.register(b0, ROOT_DIGEST, toks0)
    d0 = chain_digest(ROOT_DIGEST, toks0)
    a.register(b1, d0, toks1)
    assert a.lookup(ROOT_DIGEST, toks0) == b0
    assert a.lookup(d0, toks1) == b1
    assert a.lookup(ROOT_DIGEST, toks1) is None   # chain position matters
    # partial-tail match: a completed block whose page starts with the tail
    assert a.lookup_tail(d0, (5, 6)) == b1
    assert a.lookup_tail(d0, (5, 9)) is None
    assert a.n_table == 2
    # a registered block at refcount 0 is *retained*: its entry (and
    # KV) stays addressable for future prefix hits...
    a.release([b1])
    assert a.lookup(d0, toks1) == b1
    assert a.retained_blocks() == {b1}
    assert a.n_free == 3               # retained blocks count as free
    # ...and share() resurrects it off the free list
    a.share([b1])
    assert a.ref(b1) == 1 and a.n_retained == 0
    a.release([b0, b1])
    assert a.n_retained == 2 and a.n_table == 2
    # recycling is what finally unregisters — plain free blocks go
    # first, then retained blocks oldest-first (LRU)
    a.acquire(2)                       # the two never-registered blocks
    assert a.n_table == 2
    (got,) = a.acquire(1)
    assert got == b0                   # b0 was released before b1
    assert a.lookup(ROOT_DIGEST, toks0) is None
    assert a.lookup(d0, toks1) == b1


def test_allocator_register_guards():
    a = BlockAllocator(num_blocks=4, block_size=4)
    from repro.serving import ROOT_DIGEST
    (b,) = a.acquire(1)
    with pytest.raises(ValueError, match="full blocks"):
        a.register(b, ROOT_DIGEST, (1, 2))       # partial page
    with pytest.raises(ValueError, match="free block"):
        a.register(3, ROOT_DIGEST, (1, 2, 3, 4))  # not allocated
    # first writer wins: duplicate content does not steal the entry
    (b2,) = a.acquire(1)
    a.register(b, ROOT_DIGEST, (1, 2, 3, 4))
    a.register(b2, ROOT_DIGEST, (1, 2, 3, 4))
    assert a.lookup(ROOT_DIGEST, (1, 2, 3, 4)) == b
    assert a.registered_blocks() == {b}


def test_allocator_blocks_for():
    a = BlockAllocator(num_blocks=4, block_size=8)
    assert a.blocks_for(0) == 1        # a slot always owns >= 1 block
    assert a.blocks_for(8) == 1
    assert a.blocks_for(9) == 2


def _retain_n(a, n, start=0):
    """Acquire, register and release ``n`` blocks with distinct content
    so each lands on the retained list (oldest first)."""
    from repro.serving import ROOT_DIGEST
    blocks = a.acquire(n)
    for i, b in enumerate(blocks):
        a.register(b, ROOT_DIGEST,
                   tuple(range(start + i * a.block_size,
                               start + (i + 1) * a.block_size)))
        a.release([b])
    return blocks


def test_allocator_retain_cap_evicts_oldest():
    from repro.serving import ROOT_DIGEST
    a = BlockAllocator(num_blocks=8, block_size=2, retain_cap=2)
    blocks = _retain_n(a, 4)
    # only the 2 newest chains stay addressable; the oldest were retired
    # to the plain free list and unregistered
    assert a.n_retained == 2 and a.retained_blocks() == set(blocks[2:])
    assert a.n_retain_evictions == 2
    assert a.lookup(ROOT_DIGEST, (0, 1)) is None
    assert a.lookup(ROOT_DIGEST, (4, 5)) == blocks[2]
    # retention never costs capacity: every block is still allocatable
    assert a.n_free == a.num_blocks
    got = a.acquire(8)
    assert len(got) == 8 and a.n_table == 0


def test_allocator_retain_cap_zero_disables_retention():
    from repro.serving import ROOT_DIGEST
    a = BlockAllocator(num_blocks=4, block_size=2, retain_cap=0)
    _retain_n(a, 2)
    assert a.n_retained == 0 and a.n_table == 0
    assert a.lookup(ROOT_DIGEST, (0, 1)) is None
    assert a.n_free == 4


def test_allocator_retain_cap_spares_resurrected_blocks():
    a = BlockAllocator(num_blocks=8, block_size=2, retain_cap=1)
    (b0, b1) = _retain_n(a, 2)         # b0 retired by the cap, b1 retained
    a.share([b1])                      # resurrect: live again, not retained
    assert a.ref(b1) == 1 and a.n_retained == 0
    _retain_n(a, 1, start=100)         # a new retained block fits the cap
    assert a.n_retained == 1 and a.ref(b1) == 1
    a.release([b1])


def test_allocator_retain_ttl_expires_by_age():
    from repro.serving import ROOT_DIGEST
    now = [0.0]
    a = BlockAllocator(num_blocks=8, block_size=2, retain_ttl_s=10.0,
                       clock=lambda: now[0])
    (b0,) = _retain_n(a, 1)
    now[0] = 5.0
    (b1,) = _retain_n(a, 1, start=100)
    assert a.n_retained == 2
    now[0] = 11.0                      # b0 is 11s old, b1 only 6s
    a.acquire(0)                       # any allocator mutation sweeps
    assert a.retained_blocks() == {b1}
    assert a.lookup(ROOT_DIGEST, (0, 1)) is None
    assert a.lookup(ROOT_DIGEST, (100, 101)) == b1
    now[0] = 16.0
    a.acquire(0)
    assert a.n_retained == 0 and a.n_table == 0
    assert a.n_free == a.num_blocks


def test_allocator_sweep_expires_without_traffic():
    """Regression: TTL expiry used to piggyback on acquire()/release()
    only, so an idle allocator kept expired retained blocks (and their
    content-table entries) pinned forever.  ``sweep()`` must retire them
    with no allocation traffic at all."""
    from repro.serving import ROOT_DIGEST
    now = [0.0]
    a = BlockAllocator(num_blocks=8, block_size=2, retain_ttl_s=10.0,
                       clock=lambda: now[0])
    _retain_n(a, 2)
    assert a.n_retained == 2 and a.n_table == 2
    assert a.sweep() == 0              # nothing expired yet: no-op
    assert a.n_retained == 2
    now[0] = 11.0                      # both blocks are now 11s old
    assert a.sweep() == 2              # no acquire/release needed
    assert a.n_retained == 0 and a.n_table == 0
    assert a.lookup(ROOT_DIGEST, (0, 1)) is None
    assert a.n_free == a.num_blocks
    assert a.sweep() == 0              # idempotent on an empty list


def test_allocator_sweep_noop_without_ttl():
    a = BlockAllocator(num_blocks=4, block_size=2)
    _retain_n(a, 2)
    assert a.sweep() == 0              # no TTL configured: retain forever
    assert a.n_retained == 2


def test_allocator_retention_unbounded_by_default():
    a = BlockAllocator(num_blocks=6, block_size=2)
    _retain_n(a, 6)
    assert a.n_retained == 6 and a.n_retain_evictions == 0


def test_allocator_retain_param_guards():
    with pytest.raises(ValueError, match="retain_cap"):
        BlockAllocator(num_blocks=4, block_size=2, retain_cap=-1)
    with pytest.raises(ValueError, match="retain_ttl_s"):
        BlockAllocator(num_blocks=4, block_size=2, retain_ttl_s=0.0)


def _run_alloc_sequence(ops):
    """Shared property body for acquire/share/register/release
    interleavings.  ``ops`` is a list of (kind, x) with kind in 0..3:

      0: acquire x blocks (x mod 4 + 1);
      1: release a reference group picked by x;
      2: share a group picked by x (refcount + 1, later released);
      3: register a live block picked by x under a synthetic chain key.

    Invariants after every op: refcounts mirror a host-side model; every
    block is free xor live exactly once; a freed block is never
    releasable again; content-table entries never outlive their block.
    """
    from repro.serving import ROOT_DIGEST
    a = BlockAllocator(num_blocks=12, block_size=4)
    groups = []                        # each: list of blocks, one ref apiece
    refs: dict = {}                    # mirror refcounts
    n_keys = 0
    for kind, x in ops:
        if kind == 0:
            n = x % 4 + 1
            try:
                got = a.acquire(n)
            except CacheFullError:
                assert n > a.n_free    # only legitimate overflow raises
                continue
            assert not set(got) & set(refs), "double allocation"
            for b in got:
                refs[b] = 1
            groups.append(got)
        elif kind == 1 and groups:
            g = groups.pop(x % len(groups))
            a.release(g)
            for b in g:
                refs[b] -= 1
                if refs[b] == 0:
                    del refs[b]
        elif kind == 2 and groups:
            g = list(groups[x % len(groups)])
            a.share(g)
            for b in g:
                refs[b] += 1
            groups.append(g)           # the extra refs get released too
        elif kind == 3 and refs:
            b = sorted(refs)[x % len(refs)]
            n_keys += 1
            a.register(b, ROOT_DIGEST,
                       (n_keys,) * a.block_size)   # unique synthetic page
        # conservation + refcount mirror + table liveness
        assert a.n_free + len(refs) == a.num_blocks
        assert a.n_live == len(refs)
        for b, r in refs.items():
            assert a.ref(b) == r
        assert a.n_shared == sum(1 for r in refs.values() if r > 1)
        # every table entry points at a live block or a retained one —
        # never at a recycled (rewritable) block
        assert a.registered_blocks() <= set(refs) | a.retained_blocks(), \
            "content-table entry outlived its block"
        assert not a.retained_blocks() & set(refs), \
            "retained block still has references"
    for g in groups:
        a.release(g)
    assert a.n_free == a.num_blocks and a.n_live == 0
    # drained: every surviving table entry is a retained block, and
    # recycling the whole pool unregisters them all
    assert a.n_table == a.n_retained
    a.release(a.acquire(a.num_blocks))
    assert a.n_table == 0 and a.n_retained == 0
    # fully drained: nothing is double-releasable
    with pytest.raises(ValueError):
        a.release([0])


def test_allocator_random_sequences_deterministic():
    rng = np.random.default_rng(7)
    for _ in range(20):
        ops = [(int(rng.integers(0, 4)), int(rng.integers(0, 16)))
               for _ in range(60)]
        _run_alloc_sequence(ops)


if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 15)),
                    max_size=80))
    def test_allocator_property_refcount_conservation(ops):
        _run_alloc_sequence(ops)


# -- StateStore: recurrent state slab lifecycle -------------------------------

def test_state_store_admit_evict_roundtrip():
    s = StateStore(num_slots=3)
    a, b = s.admit(10), s.admit(11)
    assert a != b
    assert s.n_free == 1 and s.n_live == 2
    assert s.slab_of(10) == a and s.owner_of(a) == 10
    assert s.slab_of(99) is None and s.owner_of(2) is None
    assert s.evict(10) == a
    assert s.owner_of(a) is None and s.slab_of(10) is None
    assert s.n_free == 2 and s.n_live == 1
    s.evict(11)
    assert s.n_free == 3 and s.n_live == 0


def test_state_store_full_is_all_or_nothing():
    s = StateStore(num_slots=1)
    s.admit(0)
    with pytest.raises(CacheFullError):
        s.admit(1)
    assert s.n_live == 1 and s.slab_of(1) is None  # store unchanged
    s.evict(0)
    assert s.admit(1) is not None                  # the slab is reusable


def test_state_store_lifecycle_guards():
    s = StateStore(num_slots=2)
    with pytest.raises(ValueError):
        StateStore(num_slots=0)
    slab = s.admit(7)
    with pytest.raises(ValueError, match="already holds"):
        s.admit(7)                                 # one slab per request
    s.evict(7)
    with pytest.raises(ValueError, match="double evict"):
        s.evict(7)
    with pytest.raises(ValueError, match="free slab"):
        s.mark_reset(slab)                         # reset needs an owner


def test_state_store_stale_until_reset():
    """Evicted state stays flagged until the next owner resets it —
    the host-side mirror of 'state never survives eviction'."""
    s = StateStore(num_slots=1)
    slab = s.admit(0)
    assert not s.is_stale(slab)                    # never-used slab is clean
    s.evict(0)
    assert s.is_stale(slab)                        # evictee's state resident
    assert s.admit(1) == slab
    assert s.is_stale(slab)                        # still dirty at handoff
    s.mark_reset(slab)
    assert not s.is_stale(slab)


def _run_state_sequence(ops):
    """Shared property body for admit/evict interleavings.  ``ops`` is a
    list of (kind, x): kind 0 admits a fresh request id, kind 1 evicts
    the x-th live request.  Invariants after every op: slab ownership
    mirrors a host-side model; no slab is ever owned by two requests;
    free + live == capacity; a full store fails all-or-nothing; a
    recycled slab that ever held state arrives flagged stale (state
    cannot silently survive eviction) and admit/mark_reset clears it.
    """
    store = StateStore(num_slots=6)
    live = {}                          # mirror: rid -> slab
    used = set()                       # slabs that ever held an owner
    next_rid = 0
    for kind, x in ops:
        if kind == 0:
            try:
                slab = store.admit(next_rid)
            except CacheFullError:
                assert store.n_free == 0   # only a full store may refuse
                continue
            assert 0 <= slab < store.num_slots
            assert slab not in live.values(), "slab aliased to two requests"
            if slab in used:
                assert store.is_stale(slab), \
                    "recycled slab handed over without a stale flag"
            store.mark_reset(slab)     # the engine zeroes on first step
            assert not store.is_stale(slab)
            live[next_rid] = slab
            used.add(slab)
            next_rid += 1
        elif kind == 1 and live:
            rid = sorted(live)[x % len(live)]
            slab = store.evict(rid)
            assert slab == live.pop(rid)
            assert store.owner_of(slab) is None
            assert store.is_stale(slab)
        # conservation + ownership mirror
        assert store.n_free + store.n_live == store.num_slots
        assert store.n_live == len(live)
        for rid, slab in live.items():
            assert store.slab_of(rid) == slab and store.owner_of(slab) == rid
        slabs = list(live.values())
        assert len(set(slabs)) == len(slabs), "slab leak / alias"
    for rid in list(live):
        store.evict(rid)
    assert store.n_free == store.num_slots and store.n_live == 0
    with pytest.raises(ValueError):
        store.evict(-1)                # fully drained: nothing evictable


def test_state_store_random_sequences_deterministic():
    rng = np.random.default_rng(17)
    for _ in range(20):
        ops = [(int(rng.integers(0, 2)), int(rng.integers(0, 16)))
               for _ in range(60)]
        _run_state_sequence(ops)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 1), st.integers(0, 15)),
                    max_size=80))
    def test_state_store_property_slab_lifecycle(ops):
        _run_state_sequence(ops)


# -- paged decode vs dense decode: bit-for-bit --------------------------------

def _copy_dense_cache_to_pages(dense_cache, paged_cache, page_table,
                               block_size, slab=0):
    """Scatter a B=1 dense cache into the paged layout: K/V rows land in
    pool blocks per the page table, recurrent state (conv/ssm/mlstm/
    slstm leaves — anything that is not a "k"/"v" store) lands in slab
    row ``slab`` of its state array."""
    from jax.tree_util import DictKey, tree_map_with_path
    pt = np.asarray(page_table)[0]
    cap = len(pt) * block_size

    def cp(path, dense_leaf, paged_leaf):
        key = next((p.key for p in reversed(path)
                    if isinstance(p, DictKey)), None)
        src = np.asarray(dense_leaf)[:, 0]         # strip batch: (L, ...)
        out = np.asarray(paged_leaf).copy()
        if key in ("k", "v"):                      # (L, C, kv, hd) -> blocks
            for logical in range(min(cap, src.shape[1])):
                blk, off = pt[logical // block_size], logical % block_size
                out[:, blk, off] = src[:, logical]
        else:                                      # state -> its slab row
            out[:, slab] = src
        return jnp.asarray(out)

    return tree_map_with_path(cp, dense_cache, paged_cache)


def test_paged_decode_logits_match_dense_bitwise(family_model):
    """Same cache content, shuffled physical placement: the paged read/
    write path must reproduce dense decode logits exactly, step after
    step (both caches evolve through their own insert paths) — for every
    model family, with recurrent state carried in a non-trivial slab."""
    family, model, params = family_model
    bs, P = 4, 8                       # C = 32
    cap = bs * P
    prompt = np.array([5, 9, 3, 17, 30], np.int32)
    logits_d, dense = model.prefill(params, jnp.asarray(prompt)[None],
                                    capacity=cap, cache_dtype=jnp.float32)
    pt = jnp.asarray(
        np.random.default_rng(1).permutation(P).astype(np.int32)[None])
    slab = 2                           # state deliberately not at row 0
    kw = {"num_state_slots": 4} if model.has_recurrent_state() else {}
    paged = _copy_dense_cache_to_pages(
        dense, model.init_paged_cache(P, bs, dtype=jnp.float32, **kw),
        pt, bs, slab=slab)
    state_slots = jnp.asarray([slab], jnp.int32)
    lengths = jnp.asarray([len(prompt)], jnp.int32)
    ones = jnp.asarray([1], jnp.int32)
    tok = jnp.asarray([[int(jnp.argmax(logits_d[0]))]], jnp.int32)
    for step in range(8):
        ld, dense = model.decode_step(params, dense, tok,
                                      jnp.int32(int(lengths[0])))
        lp, paged = model.paged_step(params, paged, tok, pt, lengths, ones,
                                     state_slots)
        assert np.array_equal(np.asarray(ld), np.asarray(lp)), \
            f"{family}: paged/dense logits diverged at decode step {step}"
        tok = jnp.asarray([[int(jnp.argmax(ld[0]))]], jnp.int32)
        lengths = lengths + 1


def test_chunked_prefill_invariant_to_chunk_size(family_model):
    """The same prompt prefilled in 1/3/16-token chunks must land in the
    same engine tokens — chunking is a scheduling choice, not semantics,
    for attention page tables and recurrent state slabs alike."""
    family, model, params = family_model
    prompt = np.arange(1, 11, dtype=np.int32)
    runs = []
    for chunk in (1, 3, 16):
        eng = ServeEngine(model, params, batch_size=2, capacity=32,
                          max_new_tokens=5, block_size=4,
                          prefill_chunk=chunk)
        assert eng.paged
        runs.append(list(eng.serve([prompt])[0].tokens))
    assert runs[0] == runs[1] == runs[2], family


# -- engine conformance: joins, eviction, cache-full --------------------------

def test_mid_decode_join_matches_fresh_dense_run(family_model):
    """The tentpole claim: a request joining mid-decode decodes at its
    *true* positions (no left-pad shift — which for recurrent layers
    would run pad tokens through the state recurrence), so its tokens
    equal a fresh dense run of that prompt alone."""
    family, model, params = family_model
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=8, block_size=4, prefill_chunk=4)
    assert eng.paged, f"{family} fell back to the dense engine"
    rng = np.random.default_rng(3)
    first = rng.integers(1, TINY.vocab_size, 6).astype(np.int32)
    eng.submit(first)
    for _ in range(4):                 # decode well past the join point
        eng.step()
    late = rng.integers(1, TINY.vocab_size, 9).astype(np.int32)
    eng.submit(late)
    results = []
    while eng.has_work:
        results += eng.step()
    assert eng.n_joins == 1
    by_id = {r.request_id: list(r.tokens) for r in results}
    assert by_id[0] == _fresh_dense_tokens(model, params, first, 8), family
    assert by_id[1] == _fresh_dense_tokens(model, params, late, 8), family


def test_concurrent_slots_each_match_fresh_runs(family_model):
    family, model, params = family_model
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, TINY.vocab_size, n).astype(np.int32)
               for n in (5, 9, 3, 7, 12)]
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=6, block_size=4, prefill_chunk=4)
    res = eng.serve(prompts)
    assert [r.request_id for r in res] == [0, 1, 2, 3, 4]
    for p, r in zip(prompts, res):
        assert list(r.tokens) == _fresh_dense_tokens(model, params, p, 6), \
            family
    assert eng.n_prefill_chunks > eng.n_prefills == 5  # chunked, not one-shot


def test_eviction_frees_all_blocks(family_model):
    """Eviction must return every resource to its pool: KV blocks,
    reservations, and — for recurrent families — state slabs."""
    family, model, params = family_model
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=4, block_size=4, prefill_chunk=4)
    total = eng.allocator.num_blocks
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, TINY.vocab_size, n).astype(np.int32)
               for n in (11, 4, 6)]
    eng.serve(prompts)
    assert eng.n_evictions == 3
    assert eng.allocator.n_free == total
    assert eng.allocator.n_live == 0
    assert eng._reserved == 0
    if family in RECURRENT_FAMILIES:
        assert eng.state_store is not None
        assert eng.state_store.n_live == 0
        assert eng.state_store.n_free == eng.num_state_slots
    else:
        assert eng.state_store is None


def test_blocks_freed_as_each_request_finishes(tiny_model):
    """Pool usage must shrink the moment a slot is evicted, not at
    drain: that is what lets new requests join mid-decode."""
    model, params = tiny_model
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=3, block_size=4, prefill_chunk=8)
    short = np.array([2, 3], np.int32)
    long = np.arange(1, 13, dtype=np.int32)
    eng.submit(short)
    eng.submit(long)
    in_flight_free = None
    while eng.has_work:
        done = eng.step()
        if done and eng.n_active == 1 and in_flight_free is None:
            in_flight_free = eng.allocator.n_free
    assert in_flight_free is not None
    # after the short request finished, only the long one's blocks remain
    assert in_flight_free > 0
    assert eng.allocator.n_free == eng.allocator.num_blocks


def test_engine_idle_step_sweeps_expired_retention(tiny_model):
    """Regression: an idle server never retired TTL-expired retained
    blocks.  Expiry was only checked inside acquire()/release(), so with
    no new traffic the retained set (and its content-table entries)
    stayed pinned past its TTL indefinitely.  ``ServeEngine.step()``
    must now sweep on its periodic path even when there is no work."""
    model, params = tiny_model
    now = [0.0]
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=4, block_size=4, share_prefix=True,
                      retain_ttl_s=10.0)
    eng.allocator._clock = lambda: now[0]
    rng = np.random.default_rng(21)
    eng.serve([rng.integers(1, TINY.vocab_size, 9).astype(np.int32)])
    assert eng.allocator.n_retained > 0     # prefix pages were retained
    assert eng.allocator.n_table > 0
    now[0] = 11.0                           # past the TTL, server idle
    assert not eng.has_work
    assert eng.step() == []                 # pure idle tick
    assert eng.allocator.n_retained == 0    # ...still sweeps
    assert eng.allocator.n_table == 0
    assert eng.allocator.n_free == eng.allocator.num_blocks


def _check_pool_invariants(eng):
    """Accounting identities that must hold at every observable point."""
    s = eng.pool_stats()
    for key in ("num_blocks", "n_free", "n_live", "n_shared", "n_private",
                "n_retained", "n_table", "n_reserved", "bytes_per_block",
                "pool_bytes"):
        assert s[key] >= 0, (key, s)
    assert eng.allocator.n_retain_evictions >= 0
    # n_free counts retained blocks (they are reclaimable), so the pool
    # partitions as: plain-free + retained + live == everything
    assert (s["n_free"] - s["n_retained"]) >= 0, s
    assert (s["n_free"] - s["n_retained"]) + s["n_retained"] + s["n_live"] \
        == s["num_blocks"], s
    assert s["n_private"] == s["n_live"] - s["n_shared"], s
    assert s["pool_bytes"] == s["bytes_per_block"] * s["num_blocks"], s
    ls = eng.loop_stats()
    for k, v in ls.items():
        if isinstance(v, (int, np.integer)):
            assert v >= 0, (k, ls)


def test_pool_accounting_invariants_under_churn(tiny_model):
    """Property: through admission, prefix sharing, COW forks, a
    mid-flight preempt+restore, and final drain, the pool partition
    (plain-free + retained + live == num_blocks) and every counter stay
    consistent at each step boundary."""
    model, params = tiny_model
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=6, block_size=4, prefill_chunk=4,
                      share_prefix=True)
    rng = np.random.default_rng(17)
    shared = rng.integers(1, TINY.vocab_size, 8).astype(np.int32)
    prompts = [shared,                       # seeds the prefix table
               np.concatenate([shared, [3]]).astype(np.int32),  # shares+forks
               rng.integers(1, TINY.vocab_size, 5).astype(np.int32),
               np.concatenate([shared, [7, 9]]).astype(np.int32)]
    rids = [eng.submit(p, lane="batch") for p in prompts]
    _check_pool_invariants(eng)
    preempted = False
    steps = 0
    while eng.has_work:
        eng.step()
        steps += 1
        _check_pool_invariants(eng)
        if not preempted and steps >= 2:
            # preempt whichever slot is still running (if any): spills
            # its pages to the queue-side and must keep the books clean
            live = [r for r in rids
                    if any(sl is not None and sl.rid == r
                           for sl in eng._slots)]
            if live:
                eng.preempt(live[-1])
                preempted = True
                _check_pool_invariants(eng)
        assert steps < 400, "engine failed to drain"
    assert preempted, "churn test never exercised preemption"
    _check_pool_invariants(eng)
    s = eng.pool_stats()
    assert s["n_live"] == 0 and eng._reserved == 0
    assert s["n_free"] == s["num_blocks"]


def test_bytes_per_block_consistent_across_kv_dtypes(tiny_model):
    """bytes_per_block must track the storage dtype exactly: f32 is 2x
    bf16, and int8 (values + per-row f32 scales) buys at least the 2x
    capacity the quantization exists for."""
    model, params = tiny_model
    bpb = {}
    for kv_dtype in (None, "bf16", "int8"):
        eng = ServeEngine(model, params, batch_size=2, capacity=32,
                          max_new_tokens=4, block_size=4,
                          kv_dtype=kv_dtype)
        s = eng.pool_stats()
        assert s["bytes_per_block"] == eng.kv_bytes_per_block()
        bpb[kv_dtype] = s["bytes_per_block"]
        assert s["kv_dtype"] == ("f32" if kv_dtype is None else kv_dtype)
    assert bpb[None] == 2 * bpb["bf16"]
    assert bpb[None] >= 2 * bpb["int8"]


def test_cache_full_request_stays_queued(family_model):
    """A pool sized for one worst-case request at a time: the second
    request must wait (no crash, no partial admission) and still run to
    the correct tokens once the first evicts."""
    family, model, params = family_model
    # worst case per request: ceil((8 prompt + 4 new) / 4) = 3 blocks
    eng = ServeEngine(model, params, batch_size=2, capacity=16,
                      max_new_tokens=4, block_size=4, num_blocks=3,
                      prefill_chunk=4)
    rng = np.random.default_rng(9)
    a = rng.integers(1, TINY.vocab_size, 8).astype(np.int32)
    b = rng.integers(1, TINY.vocab_size, 8).astype(np.int32)
    res = eng.serve([a, b])
    assert len(res) == 2
    assert eng.n_joins == 0            # b could only start after a evicted
    for p, r in zip((a, b), res):
        assert list(r.tokens) == _fresh_dense_tokens(model, params, p, 4,
                                                     capacity=32), family
    assert eng.allocator.n_free == eng.allocator.num_blocks


def test_state_slots_full_request_stays_queued(family_model):
    """Recurrent families have a second exhaustible pool: with a single
    state slab, the second request must stay queued — all-or-nothing
    across both pools — then run correctly on the recycled (and reset)
    slab once the first evicts."""
    family, model, params = family_model
    if family not in RECURRENT_FAMILIES:
        pytest.skip("transformer stacks carry no recurrent state")
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=4, block_size=4, prefill_chunk=4,
                      num_state_slots=1)
    rng = np.random.default_rng(13)
    a = rng.integers(1, TINY.vocab_size, 7).astype(np.int32)
    b = rng.integers(1, TINY.vocab_size, 5).astype(np.int32)
    res = eng.serve([a, b])
    assert len(res) == 2
    assert eng.n_joins == 0            # blocks were free; only slabs gated
    assert eng.allocator.num_blocks > 6  # the block pool was never the limit
    for p, r in zip((a, b), res):
        assert list(r.tokens) == _fresh_dense_tokens(model, params, p, 4), \
            family                     # b is clean on the recycled slab
    assert eng.state_store.n_free == 1 and eng.state_store.n_live == 0
    assert eng.allocator.n_free == eng.allocator.num_blocks


def test_paged_mode_autodetects_and_validates(tiny_model):
    class NoPaged:
        def prefill(self, *a, **k): ...
        def decode_step(self, *a, **k): ...

    with pytest.raises(ValueError, match="paged=True"):
        ServeEngine(NoPaged(), params={}, paged=True)
    eng = ServeEngine(NoPaged(), params={})
    assert not eng.paged               # dense fallback, no allocator
    assert eng.allocator is None
    assert not eng.share_prefix        # sharing is a paged-mode feature
    with pytest.raises(ValueError, match="share_prefix"):
        ServeEngine(NoPaged(), params={}, share_prefix=True)
    # sampling no longer forces the dense path: paged mode stays auto-on
    model, params = tiny_model
    eng = ServeEngine(model, params, greedy=False, temperature=0.7)
    assert eng.paged
    eng = ServeEngine(model, params, greedy=False, paged=True)
    assert eng.paged and eng.share_prefix


def test_share_prefix_rejected_for_recurrent_families(family_model):
    """A recurrent layer's state summarizes its whole prefix, so mapping
    resident KV pages cannot seed a joiner: requesting share_prefix=True
    must fail loudly (naming the reason), auto must resolve to off —
    and neither may silently fall back to the dense engine."""
    family, model, params = family_model
    if family not in RECURRENT_FAMILIES:
        eng = ServeEngine(model, params)   # transformer: sharing stays auto-on
        assert eng.paged and eng.share_prefix
        return
    with pytest.raises(ValueError, match="recurrent layers"):
        ServeEngine(model, params, share_prefix=True)
    eng = ServeEngine(model, params)       # auto: paged on, sharing off
    assert eng.paged and not eng.share_prefix
    assert eng.state_store is not None
    eng = ServeEngine(model, params, share_prefix=False)
    assert eng.paged and not eng.share_prefix


# -- prefix sharing + copy-on-write -------------------------------------------

def _serve_staggered(model, params, prompts, *, share, max_new=4,
                     block_size=4, prefill_chunk=16, batch_size=4):
    """Serve ``prompts[0]`` until its prefill completes (its pages are
    then registered), then submit the rest.  ``prefill_chunk`` covers
    every prompt, so the sharing-on and sharing-off runs execute the
    same sequence of jit shapes — any logit difference is semantic, not
    scheduling.  Returns (engine, tokens by rid, per-step occupancy)."""
    eng = ServeEngine(model, params, batch_size=batch_size, capacity=32,
                      max_new_tokens=max_new, block_size=block_size,
                      prefill_chunk=prefill_chunk, share_prefix=share,
                      trace_logits=True)
    assert eng.paged and eng.share_prefix == share
    eng.submit(prompts[0])
    while eng.n_prefills < 1:
        eng.step()
    for p in prompts[1:]:
        eng.submit(p)
    results, occupancy = [], []
    while eng.has_work:
        results += eng.step()
        need = sum(-(-int(l) // block_size)
                   for i, l in enumerate(eng._lengths)
                   if eng._slots[i] is not None and l > 0)
        occupancy.append((eng.n_active, eng.allocator.n_live, need))
    return eng, {r.request_id: list(r.tokens) for r in results}, occupancy


def test_prefix_sharing_bit_identical_and_fewer_blocks(tiny_model):
    """The tentpole acceptance check: 4 requests sharing a 2-block
    prefix produce logits *bit-identical* to the sharing-disabled run,
    while strictly fewer blocks are live — occupancy drops below the
    sum of per-slot page needs, which only sharing can achieve."""
    model, params = tiny_model
    rng = np.random.default_rng(21)
    prefix = rng.integers(1, TINY.vocab_size, 8).astype(np.int32)
    prompts = [np.concatenate([prefix, np.asarray(s, np.int32)])
               for s in ((60, 61), (58, 59), (56, 57), (54, 55))]
    eng_off, toks_off, occ_off = _serve_staggered(model, params, prompts,
                                                  share=False)
    eng_on, toks_on, occ_on = _serve_staggered(model, params, prompts,
                                               share=True)
    assert toks_on == toks_off
    assert set(eng_on.logit_trace) == set(eng_off.logit_trace) == {0, 1, 2, 3}
    for rid, trace in eng_off.logit_trace.items():
        assert len(eng_on.logit_trace[rid]) == len(trace)
        for step, (a, b) in enumerate(zip(eng_on.logit_trace[rid], trace)):
            assert np.array_equal(a, b), \
                f"sharing changed logits of request {rid} at step {step}"
    # the prefix was actually shared, not re-prefilled
    assert eng_on.n_prefix_hits == 3
    assert eng_on.n_shared_tokens == 3 * len(prefix)
    assert eng_off.n_prefix_hits == 0
    # pool occupancy: strictly fewer live blocks at full residency, and
    # below the sum of per-slot page needs (impossible without sharing)
    peak_on = max(l for _, l, _ in occ_on)
    peak_off = max(l for _, l, _ in occ_off)
    assert peak_on < peak_off
    assert any(live < need for active, live, need in occ_on if active == 4)
    assert all(live >= need for _, live, need in occ_off)
    # everything drains: refcounts and reservations return to zero; table
    # entries for retained (refcount-0, reusable) blocks survive the drain
    for eng in (eng_on, eng_off):
        assert eng.allocator.n_free == eng.allocator.num_blocks
        assert eng.allocator.n_table == eng.allocator.n_retained
        assert eng._reserved == 0


def test_cow_fork_isolates_identical_prompts(tiny_model):
    """A joiner whose whole (block-aligned) prompt is resident maps
    every page; re-running its last token then writes into a shared
    block, which must be forked — not corrupted in place — so both the
    original and the joiner still decode the oracle sequence."""
    model, params = tiny_model
    rng = np.random.default_rng(31)
    prompt = rng.integers(1, TINY.vocab_size, 8).astype(np.int32)  # 2 blocks
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=6, block_size=4, prefill_chunk=16)
    eng.submit(prompt)
    while eng.n_prefills < 1:
        eng.step()
    eng.submit(prompt.copy())          # identical prompt, still resident
    results = []
    while eng.has_work:
        results += eng.step()
    assert eng.n_prefix_hits == 1
    assert eng.n_shared_tokens == 7    # capped at len(prompt) - 1
    assert eng.n_cow_forks >= 1        # the write into the shared tail forked
    oracle = _fresh_dense_tokens(model, params, prompt, 6)
    by_id = {r.request_id: list(r.tokens) for r in results}
    assert by_id[0] == oracle          # original unharmed by the fork
    assert by_id[1] == oracle          # joiner decodes the same sequence
    assert eng.allocator.n_free == eng.allocator.num_blocks


def test_tail_block_sharing_maps_partial_page(tiny_model):
    """A joiner's final *partial* page can land on another sequence's
    completed block (rows past the joiner's length are masked), covering
    prompt tokens that extend into the original's generated stream."""
    model, params = tiny_model
    rng = np.random.default_rng(41)
    p1 = rng.integers(1, TINY.vocab_size, 10).astype(np.int32)
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=6, block_size=4, prefill_chunk=16)
    eng.submit(p1)
    while int(eng._lengths[0]) < 12:   # page 2 (positions 8..11) complete
        eng.step()
    oracle1 = _fresh_dense_tokens(model, params, p1, 6)
    # 11-token prompt: pages 0/1 match by chain, tail (p1[8:], oracle1[0])
    # matches the first 3 rows of the original's completed page 2
    p2 = np.concatenate([p1, np.asarray(oracle1[:1], np.int32)])
    eng.submit(p2)
    results = []
    while eng.has_work:
        results += eng.step()
    assert eng.n_prefix_hits == 1
    assert eng.n_shared_tokens == 10   # 8 full-page + 2 tail (one re-run)
    assert eng.n_cow_forks >= 1        # tail page forked before the write
    by_id = {r.request_id: list(r.tokens) for r in results}
    assert by_id[0] == oracle1
    assert by_id[1] == _fresh_dense_tokens(model, params, p2, 6)
    assert eng.allocator.n_free == eng.allocator.num_blocks


def test_no_sharing_between_disjoint_prompts(tiny_model):
    """Different prompts must never map each other's blocks."""
    model, params = tiny_model
    rng = np.random.default_rng(51)
    a = rng.integers(1, TINY.vocab_size, 8).astype(np.int32)
    b = (a + 1) % TINY.vocab_size      # differs at every position
    b[b == 0] = 1
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=4, block_size=4, prefill_chunk=16)
    eng.submit(a)
    while eng.n_prefills < 1:
        eng.step()
    eng.submit(b)
    while eng.has_work:
        eng.step()
    assert eng.n_prefix_hits == 0 and eng.n_cow_forks == 0
    assert eng.allocator.n_free == eng.allocator.num_blocks


# -- paged decode-attention kernel vs oracle ----------------------------------

def test_paged_kernel_matches_paged_ref():
    from repro.kernels.decode_attention.kernel import paged_decode_attention
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    rng = np.random.default_rng(0)
    B, H, KV, hd = 3, 4, 2, 16
    nb, bs, P = 12, 8, 3
    q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((nb, KV, bs, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((nb, KV, bs, hd)), jnp.float32)
    pt = jnp.asarray(rng.choice(nb, size=(B, P), replace=False).astype(np.int32))
    lengths = jnp.asarray([5, P * bs, 1], jnp.int32)
    o = paged_decode_attention(q, kp, vp, pt, lengths)
    r = paged_decode_attention_ref(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               atol=1e-5, rtol=1e-5)


def test_paged_ops_wrapper_matches_ref_in_engine_layout():
    """ops.paged_decode_attention_bhd takes the ServeEngine leaf layout
    (num_blocks, block_size, KV, hd); its transposition into the kernel
    layout must preserve the oracle's result."""
    from repro.kernels.decode_attention import ops
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    rng = np.random.default_rng(4)
    B, H, KV, hd = 2, 4, 2, 16
    nb, bs, P = 10, 8, 3
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    k_eng = jnp.asarray(rng.standard_normal((nb, bs, KV, hd)), jnp.float32)
    v_eng = jnp.asarray(rng.standard_normal((nb, bs, KV, hd)), jnp.float32)
    pt = jnp.asarray(rng.choice(nb, size=(B, P), replace=False).astype(np.int32))
    lengths = jnp.asarray([6, 20], jnp.int32)
    o = ops.paged_decode_attention_bhd(q, k_eng, v_eng, pt, lengths)
    r = paged_decode_attention_ref(q[:, 0], jnp.moveaxis(k_eng, 2, 1),
                                   jnp.moveaxis(v_eng, 2, 1), pt, lengths)
    assert o.shape == (B, 1, H, hd)
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(r),
                               atol=1e-5, rtol=1e-5)


def test_paged_ref_equals_dense_ref_on_contiguous_table():
    """Identity page table == plain dense cache: the two oracles must
    coincide, tying the paged kernel stack back to the dense one."""
    from repro.kernels.decode_attention.ref import (
        decode_attention_ref, paged_decode_attention_ref)
    rng = np.random.default_rng(2)
    B, H, KV, hd = 2, 4, 4, 8
    bs, P = 4, 4
    C = bs * P
    q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((B, KV, C, hd)), jnp.float32)
    vd = jnp.asarray(rng.standard_normal((B, KV, C, hd)), jnp.float32)
    lengths = jnp.asarray([7, C], jnp.int32)
    # identity layout: row b uses blocks [b*P .. b*P+P-1] in order
    kp = jnp.moveaxis(kd.reshape(B, KV, P, bs, hd), 1, 2).reshape(
        B * P, KV, bs, hd)
    vp = jnp.moveaxis(vd.reshape(B, KV, P, bs, hd), 1, 2).reshape(
        B * P, KV, bs, hd)
    pt = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    r_paged = paged_decode_attention_ref(q, kp, vp, pt, lengths)
    r_dense = decode_attention_ref(q, kd, vd, lengths)
    np.testing.assert_array_equal(np.asarray(r_paged), np.asarray(r_dense))
