"""Serving correctness: prefill + decode_step == full forward, per arch.

MoE capacity dropping is order-dependent (full-sequence routing can drop
tokens that single-token decode keeps), so MoE archs are tested with a
generous capacity factor — the discrepancy itself is capacity semantics,
not a bug (see DESIGN.md).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models.frontends import fake_audio_frames

B, S = 2, 12


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True).replace(compute_dtype="float32")
    if cfg.moe:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    extra = fake_audio_frames(cfg, B) if cfg.family == "audio" else None

    logits_full, _ = model.apply(params, tokens, extra)
    logits_pre, cache = model.prefill(params, tokens[:, :S - 1],
                                      capacity=S + 4, extra_embeds=extra,
                                      cache_dtype=jnp.float32)
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    err_pre = float(jnp.max(jnp.abs(logits_pre - logits_full[:, -2])))
    assert err_pre < 1e-3 * max(scale, 1.0), (arch, err_pre)

    lp, cache = model.decode_step(params, cache, tokens[:, S - 1:],
                                  jnp.int32(S - 1))
    err = float(jnp.max(jnp.abs(lp - logits_full[:, -1])))
    assert err < 1e-3 * max(scale, 1.0), (arch, err)


def test_multi_token_decode_chain():
    """Decode 4 tokens sequentially; each must match the full forward."""
    cfg = get_config("glm4-9b", smoke=True).replace(compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    logits_full, _ = model.apply(params, tokens)
    k = 4
    _, cache = model.prefill(params, tokens[:, : S - k], capacity=S + 2,
                             cache_dtype=jnp.float32)
    for i in range(k):
        pos = S - k + i
        lp, cache = model.decode_step(params, cache, tokens[:, pos:pos + 1],
                                      jnp.int32(pos))
        err = float(jnp.max(jnp.abs(lp - logits_full[:, pos])))
        assert err < 2e-3, (i, err)


def test_sliding_window_decode_matches_windowed_forward():
    """Ring-buffer decode == full forward with the same window mask."""
    cfg = get_config("smollm-360m", smoke=True).replace(
        compute_dtype="float32", sliding_window=6)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                cfg.vocab_size)
    logits_full, _ = model.apply(params, tokens)
    _, cache = model.prefill(params, tokens[:, :S - 1], capacity=S,
                             cache_dtype=jnp.float32)
    # ring capacity == window
    assert cache["blocks"]["s0"]["k"].shape[3] == 6 or \
        cache["blocks"]["s0"]["k"].shape[2] == 6
    lp, _ = model.decode_step(params, cache, tokens[:, S - 1:],
                              jnp.int32(S - 1))
    err = float(jnp.max(jnp.abs(lp - logits_full[:, -1])))
    assert err < 2e-3, err


def test_mla_absorbed_decode_matches_expanded():
    """DeepSeek-V3 absorbed-matrix decode == naive cache expansion."""
    cfg = get_config("deepseek-v3-671b", smoke=True).replace(
        compute_dtype="float32",
        moe=dataclasses.replace(
            get_config("deepseek-v3-671b", smoke=True).moe,
            capacity_factor=8.0))
    m_naive = build_model(cfg, mla_absorb=False)
    m_abs = build_model(cfg, mla_absorb=True)
    params = m_naive.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                                cfg.vocab_size)
    _, cache = m_naive.prefill(params, tokens[:, :S - 1], capacity=S + 2,
                               cache_dtype=jnp.float32)
    l1, _ = m_naive.decode_step(params, cache, tokens[:, S - 1:],
                                jnp.int32(S - 1))
    l2, _ = m_abs.decode_step(params, cache, tokens[:, S - 1:],
                              jnp.int32(S - 1))
    err = float(jnp.max(jnp.abs(l1 - l2)))
    assert err < 2e-3, err
