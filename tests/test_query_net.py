"""Loopback tensor_query front door: client <-> server over TCP.

Uses the deterministic ToyModel from test_serve_continuous so expected
token sequences are known in closed form and no jit compilation beyond
the toy cache ops is required.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.elements.query import (MSG_ERROR, MSG_REQUEST, MSG_TOKENS,
                                       STATUS_CODES, pack_frame, pack_tensor,
                                       read_frame, unpack_tensor)
from repro.serving import ServeEngine, TensorQueryClient, TensorQueryServer

from test_serve_continuous import ToyModel, _expected


@pytest.fixture()
def server():
    eng = ServeEngine(ToyModel(), params={}, batch_size=4, capacity=64,
                      max_new_tokens=6)
    srv = TensorQueryServer(eng, max_wait_ms=5.0, pad_to=16).start()
    yield eng, srv
    srv.stop()


def test_loopback_roundtrip_streams_and_completes(server):
    eng, srv = server
    cli = TensorQueryClient("127.0.0.1", srv.port)
    prompts = [np.arange(1, n + 2, dtype=np.int32) for n in range(5)]
    qids = [cli.submit(p) for p in prompts]
    for p, q in zip(prompts, qids):
        r = cli.result(q, timeout=60)
        assert r.status == "ok"
        assert list(r.tokens) == _expected(p, 6)
        # streamed deltas reassemble to the DONE sequence, and TTFT was
        # measured on the first TOKENS frame, before completion
        assert r.stream == list(r.tokens)
        assert r.ttft_s is not None and r.ttft_s <= r.latency_s
    cli.close()
    assert srv.sink.n_sent == 5
    assert srv.src.n_requests == 5


def test_loopback_lanes_and_many_clients(server):
    eng, srv = server
    clients = [TensorQueryClient("127.0.0.1", srv.port) for _ in range(3)]
    qids = []
    for i, cli in enumerate(clients):
        p = np.asarray([i + 1, i + 2], np.int32)
        qids.append((cli, p, cli.submit(p, lane="batch" if i % 2 else
                                        "interactive")))
    for cli, p, q in qids:
        r = cli.result(q, timeout=60)
        assert r.status == "ok"
        assert list(r.tokens) == _expected(p, 6)
    for cli in clients:
        cli.close()
    # qids are connection-scoped: all three clients used qid 0
    assert [q for _, _, q in qids] == [0, 0, 0]


def test_oversized_prompt_rejected_with_error_frame(server):
    eng, srv = server
    cli = TensorQueryClient("127.0.0.1", srv.port)
    qid = cli.submit(np.ones(17, np.int32))        # pad_to is 16
    r = cli.result(qid, timeout=10)
    assert r.status == "error"
    assert "outside" in r.error
    ok = cli.submit(np.asarray([2, 3], np.int32))  # connection still usable
    assert cli.result(ok, timeout=60).status == "ok"
    cli.close()
    assert srv.src.n_rejected == 1


class _WedgedSock:
    """Socket proxy whose writes block until ``gate`` opens — a client
    that stopped reading, seen from the server's side of the wire."""

    def __init__(self, sock, gate):
        self._sock, self._gate = sock, gate

    def sendall(self, data):
        self._gate.wait(timeout=30.0)
        return self._sock.sendall(data)

    def __getattr__(self, name):
        return getattr(self._sock, name)


def _wait_until(cond, timeout=10.0, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return
        time.sleep(0.01)
    raise AssertionError(f"timed out waiting for {what}")


def test_routes_empty_after_drained_workload(server):
    """Regression: routes were added at submit but never removed, so the
    server's route table grew one entry per request forever."""
    eng, srv = server
    cli = TensorQueryClient("127.0.0.1", srv.port)
    prompts = [np.asarray([i + 1, i + 2], np.int32) for i in range(7)]
    qids = [cli.submit(p) for p in prompts]
    for p, q in zip(prompts, qids):
        assert cli.result(q, timeout=60).status == "ok"
    # the sink unroutes right after handing DONE to the connection;
    # the client can observe its frame a hair earlier, so poll briefly
    _wait_until(lambda: not srv._routes, what="_routes to drain")
    cli.close()


def test_slow_client_does_not_stall_other_requests(server):
    """A client whose socket never makes progress must not block the
    engine's token streaming (and with it every other request): sends
    ride a bounded per-connection queue drained by a writer thread."""
    eng, srv = server
    slow = TensorQueryClient("127.0.0.1", srv.port)
    _wait_until(lambda: len(srv.src.connections) == 1,
                what="slow connection to be accepted")
    sconn = srv.src.connections[0]       # slow client's server-side conn
    fast = TensorQueryClient("127.0.0.1", srv.port)
    _wait_until(lambda: len(srv.src.connections) == 2,
                what="fast connection to be accepted")
    # simulate a wedged peer: every socket write on the slow client's
    # server-side connection blocks until the gate opens
    gate = threading.Event()
    sconn.sock = _WedgedSock(sconn.sock, gate)
    try:
        sq = slow.submit(np.asarray([1, 2, 3], np.int32))
        t0 = time.monotonic()
        fq = [fast.submit(np.asarray([i + 1, i + 2], np.int32))
              for i in range(4)]
        for q in fq:
            r = fast.result(q, timeout=30)
            assert r.status == "ok"
        fast_latency = time.monotonic() - t0
        # the fast client drained a full workload while the slow one's
        # writer thread was wedged mid-send
        assert fast_latency < 20.0
    finally:
        gate.set()
    r = slow.result(sq, timeout=30)
    assert r.status == "ok"
    assert list(r.tokens) == _expected(np.asarray([1, 2, 3], np.int32), 6)
    slow.close()
    fast.close()


def test_tokens_dropped_on_outbound_overflow_done_authoritative(server):
    """With the outbound queue artificially tiny and the socket wedged,
    best-effort TOKENS deltas are dropped, terminal DONE frames still
    queue, and the authoritative sequence survives."""
    eng, srv = server
    cli = TensorQueryClient("127.0.0.1", srv.port)
    _wait_until(lambda: len(srv.src.connections) == 1,
                what="connection to be accepted")
    gate = threading.Event()
    sconn = srv.src.connections[0]
    sconn.sock = _WedgedSock(sconn.sock, gate)
    sconn.max_outbound = 1
    prompt = np.asarray([1, 2, 3], np.int32)
    try:
        qid = cli.submit(prompt)
        _wait_until(lambda: sconn.n_dropped > 0,
                    what="TOKENS deltas to be dropped on overflow")
    finally:
        gate.set()
    r = cli.result(qid, timeout=30)
    assert r.status == "ok"
    assert list(r.tokens) == _expected(prompt, 6)   # DONE is authoritative
    assert len(r.stream) < len(r.tokens)            # some deltas were lost
    cli.close()


def test_error_frame_stamps_ttft_and_latency(server):
    """Regression: MSG_ERROR set ``t_done`` but never ``t_first``, so a
    failed query reported ``latency_s`` with ``ttft_s`` forever None and
    percentile aggregations silently dropped error rows.  ERROR is as
    terminal as DONE: both timestamps must be stamped."""
    eng, srv = server
    cli = TensorQueryClient("127.0.0.1", srv.port)
    r = cli.result(cli.submit(np.ones(17, np.int32)), timeout=10)
    assert r.status == "error"
    assert r.ttft_s is not None and r.latency_s is not None
    assert 0 <= r.ttft_s <= r.latency_s
    cli.close()


def test_connection_death_stamps_pending_and_breaks_client(server):
    """Regression: when the reader thread died, in-flight queries were
    failed without timestamps (unmeasurable) and the client happily
    accepted new submits into the dead socket.  Now every pending query
    is stamped on both clocks and ``submit`` fails fast."""
    eng, srv = server
    cli = TensorQueryClient("127.0.0.1", srv.port)
    _wait_until(lambda: len(srv.src.connections) == 1,
                what="connection to be accepted")
    gate = threading.Event()
    sconn = srv.src.connections[0]
    sconn.sock = _WedgedSock(sconn.sock, gate)   # no frame reaches the client
    try:
        qid = cli.submit(np.asarray([1, 2, 3], np.int32))
        # a timeout does NOT collect: the query stays retrievable
        with pytest.raises(TimeoutError):
            cli.result(qid, timeout=0.1)
        cli.sock.shutdown(__import__("socket").SHUT_RDWR)   # kill transport
        r = cli.result(qid, timeout=10)
    finally:
        gate.set()
    assert r.status == "error" and "connection closed" in r.error
    assert r.ttft_s is not None and r.latency_s is not None
    assert cli._broken
    with pytest.raises(ConnectionError, match="dead"):
        cli.submit(np.asarray([4, 5], np.int32))
    cli.close()


def test_result_collects_exactly_once_and_prunes(server):
    """Regression: ``_requests`` retained every result forever — a
    long-lived connection leaked one token array per query.  Collecting
    drops the reference and leaves a tombstone so double collection is
    a clear error, distinct from an unknown qid."""
    eng, srv = server
    cli = TensorQueryClient("127.0.0.1", srv.port)
    prompts = [np.asarray([i + 1, i + 2], np.int32) for i in range(3)]
    qids = [cli.submit(p) for p in prompts]
    for p, q in zip(prompts, qids):
        r = cli.result(q, timeout=60)
        assert list(r.tokens) == _expected(p, 6)
    assert cli._requests == {}                   # pruned, not retained
    with pytest.raises(ValueError, match="already collected"):
        cli.result(qids[0], timeout=1.0)
    with pytest.raises(ValueError, match="unknown query id 99"):
        cli.result(99, timeout=1.0)              # contract unchanged
    cli.close()


def test_client_unknown_qid_raises_value_error(server):
    eng, srv = server
    cli = TensorQueryClient("127.0.0.1", srv.port)
    with pytest.raises(ValueError, match="unknown query id 42"):
        cli.result(42, timeout=1.0)
    cli.close()


def test_client_submit_after_close_raises_connection_error(server):
    eng, srv = server
    cli = TensorQueryClient("127.0.0.1", srv.port)
    cli.close()
    with pytest.raises(ConnectionError, match="closed"):
        cli.submit(np.asarray([1, 2], np.int32))


def test_client_submit_on_dead_socket_raises_connection_error(server):
    """A broken (but not close()d) socket should also surface as a clear
    ConnectionError, and the failed qid must not linger as pending."""
    eng, srv = server
    cli = TensorQueryClient("127.0.0.1", srv.port)
    cli.sock.close()                     # dead transport, client not closed
    with pytest.raises(ConnectionError, match="closed or broken"):
        cli.submit(np.asarray([1, 2], np.int32))
    assert cli._requests == {}           # the failed submit left no orphan
    cli._closed = True                   # silence the reader, then tear down
    cli.close()


def test_wire_format_roundtrip():
    arr = np.arange(12, dtype=np.int32).reshape(3, 4)
    assert np.array_equal(unpack_tensor(pack_tensor(arr)), arr)
    f32 = np.linspace(0, 1, 5, dtype=np.float32)
    out = unpack_tensor(pack_tensor(f32))
    assert out.dtype == np.float32 and np.array_equal(out, f32)
    frame = pack_frame(MSG_REQUEST, 7, pack_tensor(f32), lane=1,
                       deadline=0.25)

    class _FakeSock:
        def __init__(self, data):
            self.data, self.off = data, 0

        def recv(self, n):
            part = self.data[self.off:self.off + n]
            self.off += len(part)
            return part

    msg, qid, lane, status, deadline, payload = read_frame(_FakeSock(frame))
    assert (msg, qid, lane, status) == (MSG_REQUEST, 7, 1, 0)
    assert deadline == 0.25
    assert np.array_equal(unpack_tensor(payload), f32)
