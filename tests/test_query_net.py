"""Loopback tensor_query front door: client <-> server over TCP.

Uses the deterministic ToyModel from test_serve_continuous so expected
token sequences are known in closed form and no jit compilation beyond
the toy cache ops is required.
"""
import numpy as np
import pytest

from repro.core.elements.query import (MSG_ERROR, MSG_REQUEST, STATUS_CODES,
                                       pack_frame, pack_tensor, read_frame,
                                       unpack_tensor)
from repro.serving import ServeEngine, TensorQueryClient, TensorQueryServer

from test_serve_continuous import ToyModel, _expected


@pytest.fixture()
def server():
    eng = ServeEngine(ToyModel(), params={}, batch_size=4, capacity=64,
                      max_new_tokens=6)
    srv = TensorQueryServer(eng, max_wait_ms=5.0, pad_to=16).start()
    yield eng, srv
    srv.stop()


def test_loopback_roundtrip_streams_and_completes(server):
    eng, srv = server
    cli = TensorQueryClient("127.0.0.1", srv.port)
    prompts = [np.arange(1, n + 2, dtype=np.int32) for n in range(5)]
    qids = [cli.submit(p) for p in prompts]
    for p, q in zip(prompts, qids):
        r = cli.result(q, timeout=60)
        assert r.status == "ok"
        assert list(r.tokens) == _expected(p, 6)
        # streamed deltas reassemble to the DONE sequence, and TTFT was
        # measured on the first TOKENS frame, before completion
        assert r.stream == list(r.tokens)
        assert r.ttft_s is not None and r.ttft_s <= r.latency_s
    cli.close()
    assert srv.sink.n_sent == 5
    assert srv.src.n_requests == 5


def test_loopback_lanes_and_many_clients(server):
    eng, srv = server
    clients = [TensorQueryClient("127.0.0.1", srv.port) for _ in range(3)]
    qids = []
    for i, cli in enumerate(clients):
        p = np.asarray([i + 1, i + 2], np.int32)
        qids.append((cli, p, cli.submit(p, lane="batch" if i % 2 else
                                        "interactive")))
    for cli, p, q in qids:
        r = cli.result(q, timeout=60)
        assert r.status == "ok"
        assert list(r.tokens) == _expected(p, 6)
    for cli in clients:
        cli.close()
    # qids are connection-scoped: all three clients used qid 0
    assert [q for _, _, q in qids] == [0, 0, 0]


def test_oversized_prompt_rejected_with_error_frame(server):
    eng, srv = server
    cli = TensorQueryClient("127.0.0.1", srv.port)
    qid = cli.submit(np.ones(17, np.int32))        # pad_to is 16
    r = cli.result(qid, timeout=10)
    assert r.status == "error"
    assert "outside" in r.error
    ok = cli.submit(np.asarray([2, 3], np.int32))  # connection still usable
    assert cli.result(ok, timeout=60).status == "ok"
    cli.close()
    assert srv.src.n_rejected == 1


def test_wire_format_roundtrip():
    arr = np.arange(12, dtype=np.int32).reshape(3, 4)
    assert np.array_equal(unpack_tensor(pack_tensor(arr)), arr)
    f32 = np.linspace(0, 1, 5, dtype=np.float32)
    out = unpack_tensor(pack_tensor(f32))
    assert out.dtype == np.float32 and np.array_equal(out, f32)
    frame = pack_frame(MSG_REQUEST, 7, pack_tensor(f32), lane=1,
                       deadline=0.25)

    class _FakeSock:
        def __init__(self, data):
            self.data, self.off = data, 0

        def recv(self, n):
            part = self.data[self.off:self.off + n]
            self.off += len(part)
            return part

    msg, qid, lane, status, deadline, payload = read_frame(_FakeSock(frame))
    assert (msg, qid, lane, status) == (MSG_REQUEST, 7, 1, 0)
    assert deadline == 0.25
    assert np.array_equal(unpack_tensor(payload), f32)
