"""Per-element behaviour tests."""
import numpy as np
import pytest

from repro.core import Buffer, parse_pipeline
from repro.core.elements import (TensorAggregator, TensorIf, TensorRate,
                                 TensorRepo, TensorTransform)
from repro.core.elements.converter import TensorConverter, TensorDecoder
from repro.core.elements.sinks import TensorSink


def _feed(element, arrays, pts=None):
    """Wire element -> sink, push arrays, return collected buffers."""
    sink = TensorSink("sink", keep=True)
    element.link(sink)
    for i, a in enumerate(arrays):
        element.chain(element.sinkpad, Buffer(a, pts=pts[i] if pts else float(i)))
    return sink.buffers


def test_converter_video_to_float():
    conv = TensorConverter("c", mode="video", to_float=True)
    out = _feed(conv, [np.full((4, 4, 3), 255, np.uint8)])
    assert out[0].data.dtype == np.float32
    assert np.allclose(out[0].data, 1.0)


def test_converter_text():
    conv = TensorConverter("c", mode="text", text_size=8)
    out = _feed(conv, ["hi"])
    assert out[0].data.shape == (8,)
    assert out[0].data[0] == ord("h")


def test_decoder_argmax_label():
    dec = TensorDecoder("d", mode="argmax_label")
    out = _feed(dec, [np.array([0.1, 0.9, 0.2], np.float32)])
    assert out[0].meta["label"] == 1


def test_decoder_bounding_boxes():
    dec = TensorDecoder("d", mode="bounding_boxes")
    out = _feed(dec, [np.array([[1, 2, 3, 4, 0.9]], np.float32)])
    assert out[0].meta["boxes"][0]["score"] == pytest.approx(0.9)


def test_decoder_overlay_draws_box():
    dec = TensorDecoder("d", mode="overlay", width=32, height=32)
    out = _feed(dec, [np.array([[4, 4, 10, 10, 0.9]], np.float32)])
    frame = out[0].data
    assert frame.shape == (32, 32, 4)
    assert frame[4, 4, 1] == 255  # green box corner


def test_transform_chain():
    tr = TensorTransform("t", option="typecast:float32,divide:2.0,add:1.0")
    out = _feed(tr, [np.array([2, 4], np.uint8)])
    assert np.allclose(out[0].data, [2.0, 3.0])


def test_transform_transpose():
    tr = TensorTransform("t", option="transpose:1:0")
    out = _feed(tr, [np.arange(6).reshape(2, 3)])
    assert out[0].data.shape == (3, 2)


def test_transform_fused_backend_matches_numpy():
    chain = "typecast:float32,divide:255.0,subtract:0.5,clamp:-0.4:0.4"
    a = TensorTransform("a", option=chain, backend="numpy")
    b = TensorTransform("b", option=chain, backend="fused")
    x = np.arange(256, dtype=np.uint8).reshape(16, 16)
    ya = _feed(a, [x])[0].data
    yb = _feed(b, [x])[0].data
    np.testing.assert_allclose(ya, yb, atol=1e-6)


def test_aggregator_halves_rate():
    agg = TensorAggregator("a", frames_in=2)
    out = _feed(agg, [np.full((3,), i, np.float32) for i in range(6)])
    assert len(out) == 3
    assert out[0].data.shape == (6,)
    # output timestamp = latest input (paper)
    assert out[0].pts == 1.0


def test_aggregator_overlapping_windows():
    agg = TensorAggregator("a", frames_in=4, frames_flush=2)
    out = _feed(agg, [np.full((1,), i, np.float32) for i in range(8)])
    assert len(out) == 3  # windows at 0-3, 2-5, 4-7
    assert np.allclose(out[1].data, [2, 3, 4, 5])


def test_rate_throttles():
    rate = TensorRate("r", framerate=1.0)
    pts = [0.0, 0.3, 0.6, 1.0, 1.4, 2.0]
    out = _feed(rate, [np.zeros(1) for _ in pts], pts=pts)
    assert [b.pts for b in out] == [0.0, 1.0, 2.0]
    assert rate.n_dropped == 3


def test_tensor_if_routes_both_ways():
    ti = TensorIf("i", reduction="mean", compare="gt", value=0.0)
    t_sink, f_sink = TensorSink("t", keep=True), TensorSink("f", keep=True)
    ti.srcpads["src_true"].link(t_sink.sinkpad)
    ti.srcpads["src_false"].link(f_sink.sinkpad)
    ti.chain(ti.sinkpad, Buffer(np.array([1.0])))
    ti.chain(ti.sinkpad, Buffer(np.array([-1.0])))
    assert t_sink.n_received == 1 and f_sink.n_received == 1


def test_repo_recurrence():
    TensorRepo.reset()
    pipe = parse_pipeline(
        "appsrc name=src ! tensor_reposrc name=rs slot=state seed_shape=2 ! "
        "tensor_filter framework=python model=step ! tee name=t num_src_pads=2 "
        "t.src_0 ! tensor_sink name=out keep=true "
        "t.src_1 ! tensor_reposink slot=state",
        models={"step": lambda x, state: np.asarray(x, np.float32) + state})
    pipe.start()
    for _ in range(3):
        pipe["src"].push(np.ones(2, np.float32))
    pipe["src"].end_of_stream()
    pipe.stop()
    outs = [b.data for b in pipe["out"].buffers]
    # recurrent accumulation: 1, 2, 3
    np.testing.assert_allclose(outs[0], [1, 1])
    np.testing.assert_allclose(outs[1], [2, 2])
    np.testing.assert_allclose(outs[2], [3, 3])


def test_mux_zero_copy_and_demux_roundtrip():
    pipe = parse_pipeline(
        "appsrc name=a ! mux.sink_0 appsrc name=b ! mux.sink_1 "
        "tensor_mux name=mux num_sinks=2 ! tensor_demux num_src_pads=2 "
        "name=dm dm.src_0 ! tensor_sink name=o0 keep=true "
        "dm.src_1 ! tensor_sink name=o1 keep=true")
    pipe.start()
    xa, xb = np.arange(3.0), np.arange(4.0)
    pipe["a"].push(xa, pts=0.0)
    pipe["b"].push(xb, pts=0.0)
    pipe.stop()
    assert np.array_equal(pipe["o0"].buffers[0].data, xa)
    assert np.array_equal(pipe["o1"].buffers[0].data, xb)


def test_merge_dimension_algebra():
    # paper: two 3x4 streams -> 6x4 (concat gst dim 0 = np last dim? no:
    # gst 3x4 == np (4,3); concat gst dim 0 -> 6x4 == np (4,6)
    pipe = parse_pipeline(
        "appsrc name=a ! m.sink_0 appsrc name=b ! m.sink_1 "
        "tensor_merge name=m num_sinks=2 mode=concat:0 ! tensor_sink name=o keep=true")
    pipe.start()
    pipe["a"].push(np.zeros((4, 3)), pts=0.0)
    pipe["b"].push(np.ones((4, 3)), pts=0.0)
    pipe.stop()
    assert pipe["o"].buffers[0].data.shape == (4, 6)


def test_split_segments():
    pipe = parse_pipeline(
        "appsrc name=a ! tensor_split name=sp tensorseg=2.4 "
        "sp.src_0 ! tensor_sink name=o0 keep=true "
        "sp.src_1 ! tensor_sink name=o1 keep=true")
    pipe.start()
    pipe["a"].push(np.arange(6.0))
    pipe.stop()
    assert pipe["o0"].buffers[0].data.shape == (2,)
    assert pipe["o1"].buffers[0].data.shape == (4,)


def test_valve_and_selector():
    pipe = parse_pipeline(
        "appsrc name=a ! valve name=v drop=true ! fakesink name=o")
    pipe.start()
    pipe["a"].push(np.zeros(1))
    assert pipe["o"].n_received == 0
    pipe["v"].drop = False
    pipe["a"].push(np.zeros(1))
    assert pipe["o"].n_received == 1
    pipe.stop()
