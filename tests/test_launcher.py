"""Launcher argument validation: every unsupported flag pair must die
fast with a one-line error naming both flags — before any model or mesh
work starts.

Regression context: these combinations used to be rejected (or worse,
silently mis-served) deep inside engine construction, after demo weights
were already built; a couple reached the engine as latent misconfigs.
``validate_args`` now front-loads them all.
"""
import pytest

from repro.launch.serve import build_parser, validate_args


def _args(*argv):
    return build_parser().parse_args(list(argv))


def _expect_exit(match, *argv):
    with pytest.raises(SystemExit, match=match):
        validate_args(_args(*argv))


# -- basic sanity -------------------------------------------------------------

def test_defaults_validate_cleanly():
    validate_args(_args())


def test_requests_must_be_positive():
    _expect_exit("--requests", "--requests", "0")


def test_shared_prompt_must_leave_suffix_room():
    _expect_exit("--shared-prompt", "--prompt-len", "8",
                 "--shared-prompt", "7")


# -- speculative-decode pairs -------------------------------------------------

def test_spec_k_rejects_mesh():
    _expect_exit("--spec-k and --mesh", "--spec-k", "2", "--mesh", "2")


def test_spec_k_rejects_share_prefix_on():
    _expect_exit("--spec-k and --share-prefix", "--spec-k", "2",
                 "--share-prefix", "on")


@pytest.mark.parametrize("family", ["mamba", "xlstm", "hybrid"])
def test_spec_k_rejects_recurrent_families(family):
    _expect_exit(f"--spec-k and --family {family}", "--spec-k", "2",
                 "--family", family)


def test_spec_k_rejects_paged_off():
    _expect_exit("--spec-k and --paged off", "--spec-k", "2",
                 "--paged", "off")


def test_spec_k_valid_combo_passes():
    validate_args(_args("--spec-k", "2", "--family", "transformer"))


# -- int8 KV quantization pairs ----------------------------------------------

def test_int8_rejects_paged_off():
    _expect_exit("--kv-dtype int8 and --paged off",
                 "--kv-dtype", "int8", "--paged", "off")


def test_int8_rejects_spec_k():
    _expect_exit("--kv-dtype int8 and --spec-k",
                 "--kv-dtype", "int8", "--spec-k", "2",
                 "--family", "transformer")


def test_int8_rejects_mesh():
    _expect_exit("--kv-dtype int8 and --mesh",
                 "--kv-dtype", "int8", "--mesh", "2")


@pytest.mark.parametrize("kv_dtype", ["f32", "bf16", "int8"])
def test_kv_dtype_choices_validate_standalone(kv_dtype):
    validate_args(_args("--kv-dtype", kv_dtype))


def test_kv_dtype_rejects_unknown_choice():
    with pytest.raises(SystemExit):
        _args("--kv-dtype", "fp4")
