"""Shared test configuration: fixed-seed hypothesis profiles.

The tier-1 suite must pass with or without hypothesis installed (the
property tests degrade to deterministic fallbacks).  When it *is*
installed, ``HYPOTHESIS_PROFILE=ci`` selects a derandomized profile so
the CI property job explores the same examples run-to-run — a failure
there is a regression, never flake.
"""
import os

try:
    from hypothesis import settings
except ImportError:
    pass
else:
    settings.register_profile("ci", max_examples=200, derandomize=True,
                              deadline=None)
    settings.register_profile("dev", max_examples=50, deadline=None)
    _profile = os.environ.get("HYPOTHESIS_PROFILE")
    if _profile:
        settings.load_profile(_profile)
