"""Shared test configuration: fixed-seed hypothesis profiles + the
cross-family serving conformance axis.

The tier-1 suite must pass with or without hypothesis installed (the
property tests degrade to deterministic fallbacks).  When it *is*
installed, ``HYPOTHESIS_PROFILE=ci`` selects a derandomized profile so
the CI property job explores the same examples run-to-run — a failure
there is a regression, never flake.

``family_model`` parametrizes engine-conformance tests over one tiny
config per serving family — transformer (attention-only), pure mamba,
xLSTM (mLSTM+sLSTM), and hybrid (attention+mamba, jamba-style) — so
every ServeEngine guarantee is pinned for every model family.  CI runs
one matrix job per family via ``-k "<family>"``; the fixture is
session-scoped so the two conformance modules share each family's
params.
"""
import os

import pytest

try:
    from hypothesis import settings
except ImportError:
    pass
else:
    settings.register_profile("ci", max_examples=200, derandomize=True,
                              deadline=None)
    settings.register_profile("dev", max_examples=50, deadline=None)
    _profile = os.environ.get("HYPOTHESIS_PROFILE")
    if _profile:
        settings.load_profile(_profile)


from repro.models.config import ModelConfig, SSMConfig  # noqa: E402

TINY_SERVE = ModelConfig(
    arch_id="tiny-serve", family="dense", n_layers=2, d_model=32,
    n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
    norm="rmsnorm", mlp_act="swiglu", rope="rope",
    param_dtype="float32", compute_dtype="float32")

_SSM = SSMConfig(d_state=8, d_conv=4, expand=2)
FAMILY_CFGS = {
    "transformer": TINY_SERVE,
    # attn_layer_offset >= period: no layer index matches => pure-SSM stack
    "mamba": TINY_SERVE.replace(
        arch_id="tiny-mamba", family="hybrid", ssm=_SSM,
        attn_layer_period=1, attn_layer_offset=1),
    "xlstm": TINY_SERVE.replace(
        arch_id="tiny-xlstm", family="ssm", d_ff=0, n_kv_heads=4,
        rope="none",
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, slstm_every=2)),
    "hybrid": TINY_SERVE.replace(
        arch_id="tiny-hybrid", family="hybrid", ssm=_SSM,
        attn_layer_period=2, attn_layer_offset=0),
}
RECURRENT_FAMILIES = ("mamba", "xlstm", "hybrid")


@pytest.fixture(scope="session", params=list(FAMILY_CFGS))
def family_model(request):
    """(family name, model, params) — the engine conformance matrix axis."""
    import jax
    from repro.models import build_model
    model = build_model(FAMILY_CFGS[request.param])
    return request.param, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(autouse=True, scope="module")
def _release_jit_code():
    """Drop compiled executables between test modules.

    Every ServeEngine jits its own megasteps, and each compiled
    executable holds a handful of small code/data mmaps for the life of
    its jit wrapper.  Across the full suite that sums to tens of
    thousands of mappings — enough to cross the kernel's default
    ``vm.max_map_count`` (65530) mid-run, at which point LLVM's next
    allocation fails and XLA segfaults inside ``backend_compile``
    (observed on the big-config compiles in test_decode_consistency).
    Clearing jax's jit caches at module teardown releases dead engines'
    executables and keeps the peak map count bounded; live fixtures
    (models, params) are plain arrays and survive untouched — the next
    module just recompiles its own engines, which it would do anyway.
    """
    yield
    import gc
    import jax
    gc.collect()           # break engine<->closure cycles first
    jax.clear_caches()
