"""Micro-batching subsystem: TensorBatcher/TensorUnbatcher + the
TensorFilter bucket cache."""
import time

import numpy as np

from repro.core import Buffer, parse_pipeline
from repro.core.elements.batcher import (BATCH_META_KEY, TensorBatcher,
                                         TensorUnbatcher)
from repro.core.elements.filter import TensorFilter, bucket_for
from repro.core.elements.sinks import TensorSink


def _frame(v, pts, **meta):
    return Buffer(np.full((3,), v, np.float32), pts=pts, meta=meta)


def _wire(batcher):
    sink = TensorSink("s", keep=True)
    batcher.link(sink)
    return sink


def test_batcher_full_batch_flush():
    b = TensorBatcher("b", max_batch=4)
    sink = _wire(b)
    for i in range(9):
        b.chain(b.sinkpad, _frame(i, float(i)))
    assert sink.n_received == 2  # two full batches, one frame pending
    first = sink.buffers[0]
    assert first.data.shape == (4, 3)
    info = first.meta[BATCH_META_KEY]
    assert info["size"] == 4 and info["pts"] == [0.0, 1.0, 2.0, 3.0]
    assert first.pts == 3.0  # latest input stamps the batch (paper §III)


def test_batcher_flush_on_eos():
    b = TensorBatcher("b", max_batch=8)
    sink = _wire(b)
    for i in range(3):
        b.chain(b.sinkpad, _frame(i, float(i)))
    assert sink.n_received == 0  # partial batch held
    b.chain(b.sinkpad, Buffer.eos_buffer())
    assert sink.n_received == 1  # partial batch flushed before EOS
    assert sink.eos_seen.is_set()
    assert sink.buffers[0].data.shape == (3, 3)
    assert b.n_eos_flushes == 1


def test_batcher_max_wait_timeout_flush():
    b = TensorBatcher("b", max_batch=64, max_wait_ms=40)
    sink = _wire(b)
    b.start()
    try:
        b.chain(b.sinkpad, _frame(1, 0.0))
        b.chain(b.sinkpad, _frame(2, 1.0))
        deadline = time.monotonic() + 2.0
        while sink.n_received == 0 and time.monotonic() < deadline:
            time.sleep(0.01)
    finally:
        b.stop()
    assert sink.n_received == 1  # flushed by timeout, far below max_batch
    assert sink.buffers[0].data.shape == (2, 3)
    assert b.n_timeout_flushes == 1


def test_unbatcher_zero_copy_views():
    b = TensorBatcher("b", max_batch=2)
    ub = TensorUnbatcher("u")
    sink = TensorSink("s", keep=True)
    b.link(ub).link(sink)
    b.chain(b.sinkpad, _frame(1, 0.5, request=0))
    b.chain(b.sinkpad, _frame(2, 1.5, request=1))
    assert sink.n_received == 2
    # unbatch slices are views into the batched array, never copies
    batched = np.stack([np.full((3,), v, np.float32) for v in (1, 2)])
    ub2 = TensorUnbatcher("u2")
    s2 = TensorSink("s2", keep=True)
    ub2.link(s2)
    ub2.chain(ub2.sinkpad, Buffer(batched))
    for j, out in enumerate(s2.buffers):
        assert np.shares_memory(np.asarray(out.data), batched)


def test_pts_meta_roundtrip_through_batch_filter_unbatch():
    pipe = parse_pipeline(
        "appsrc name=src ! tensor_batcher max_batch=4 ! "
        "tensor_filter framework=python model=double max_batch=4 ! "
        "tensor_unbatcher ! tensor_sink name=out keep=true",
        models={"double": lambda x: np.asarray(x) * 2.0})
    pipe.start()
    for i in range(8):
        pipe["src"].push(np.full((3,), i, np.float32), pts=10.0 + i,
                         meta={"request": i})
    pipe["src"].end_of_stream()
    assert pipe["out"].eos_seen.wait(timeout=10)
    pipe.stop()
    bufs = pipe["out"].buffers
    assert len(bufs) == 8
    for i, buf in enumerate(bufs):
        assert buf.pts == 10.0 + i                       # per-frame pts restored
        assert buf.meta["request"] == i                  # per-frame meta restored
        np.testing.assert_allclose(np.asarray(buf.data), np.full((3,), 2.0 * i))


def test_bucket_for():
    assert [bucket_for(n, 8) for n in (1, 2, 3, 4, 5, 7, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 8, 8]


def test_bucket_cache_bounds_recompiles():
    """Varying batch sizes must hit at most log2(max_batch)+1 buckets."""
    f = TensorFilter("f", fn=lambda x: x * 2, framework="jax", max_batch=8)
    for n in (1, 2, 3, 4, 5, 6, 7, 8, 3, 5, 1):
        out = f.invoke_batched([np.ones((n, 4), np.float32)], n)
        assert np.asarray(out[0]).shape == (n, 4)  # sliced back to true size
    assert set(f.bucket_stats) == {1, 2, 4, 8}
    assert f.n_bucket_compilations <= 4  # log2(8)+1
    # per-bucket stats account for every frame
    assert sum(s[1] for s in f.bucket_stats.values()) == 1+2+3+4+5+6+7+8+3+5+1


def test_batcher_rejects_arity_change():
    b = TensorBatcher("b", max_batch=4)
    _wire(b)
    b.chain(b.sinkpad, Buffer((np.zeros(2), np.zeros(3))))
    try:
        b.chain(b.sinkpad, Buffer(np.zeros(2)))
    except ValueError as e:
        assert "arity" in str(e)
    else:
        raise AssertionError("expected ValueError on chunk arity change")
