"""Int8 paged-KV quantization: drift contract + engine gates.

The contract (see docs/serving.md):

  * ``kv_dtype=None`` vs ``kv_dtype="f32"`` — **bitwise identical**: the
    quant path is a separate sibling dispatch keyed on the cache
    pytree's ``k_scale`` leaf, so unquantized serving runs byte-for-byte
    the same code as before the feature existed.
  * ``kv_dtype="int8"`` — bitwise identity is explicitly NOT the
    contract.  The contract is *bounded drift*: per-family max |Δlogit|
    on the prompt-conditioned (first) decode step, plus greedy
    token-level agreement with the f32 engine.
  * Families with no attention layers store nothing in the quantized
    pools, so their int8 run IS bitwise identical — asserted as such.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import TINY_SERVE
from repro.serving import ServeEngine

# prompt-conditioned logit drift ceilings, measured on the tiny serve
# configs and padded ~5x; attention-free stacks must be exact
MAX_FIRST_STEP_DRIFT = {
    "transformer": 0.15,
    "hybrid": 0.15,
    "mamba": 0.0,
    "xlstm": 0.0,
}
# fraction of greedily-decoded tokens that must agree with f32
MIN_TOKEN_AGREEMENT = 0.6


@pytest.fixture(scope="module")
def tiny_model():
    from repro.models import build_model
    model = build_model(TINY_SERVE)
    return model, model.init(jax.random.PRNGKey(0))


def _serve_traced(model, params, prompts, kv_dtype, max_new=6):
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=max_new, block_size=4, prefill_chunk=4,
                      trace_logits=True, kv_dtype=kv_dtype)
    res = eng.serve(prompts)
    return eng, {r.request_id: list(r.tokens) for r in res}


@pytest.fixture(scope="module")
def quant_prompts():
    rng = np.random.default_rng(29)
    return [rng.integers(1, TINY_SERVE.vocab_size, n).astype(np.int32)
            for n in (5, 9, 3, 12)]


def test_int8_drift_bounded_per_family(family_model, quant_prompts):
    family, model, params = family_model
    ref_eng, ref_toks = _serve_traced(model, params, quant_prompts, None)
    q_eng, q_toks = _serve_traced(model, params, quant_prompts, "int8")
    assert set(q_toks) == set(ref_toks)
    tol = MAX_FIRST_STEP_DRIFT[family]
    agree = total = 0
    for rid, ref_trace in ref_eng.logit_trace.items():
        q_trace = q_eng.logit_trace[rid]
        # step 0 is conditioned on the prompt alone — no divergence
        # feedback — so its drift isolates the quantization error
        d0 = float(jnp.max(jnp.abs(q_trace[0].astype(jnp.float32)
                                   - ref_trace[0].astype(jnp.float32))))
        if tol == 0.0:
            assert d0 == 0.0, (family, rid, d0)
        else:
            assert d0 <= tol, (family, rid, d0)
        for a, b in zip(q_toks[rid], ref_toks[rid]):
            total += 1
            agree += int(a == b)
    assert total > 0
    if tol == 0.0:                     # attention-free: exact tokens
        assert agree == total, family
    else:
        assert agree / total >= MIN_TOKEN_AGREEMENT, \
            (family, f"{agree}/{total} greedy tokens agree")


def test_f32_mode_bitwise_identical_to_default(tiny_model, quant_prompts):
    """kv_dtype='f32' must be a pure alias for the default path — the
    quant dispatch keys on cache structure, so the traces are bitwise
    equal, not merely close."""
    model, params = tiny_model
    a_eng, a_toks = _serve_traced(model, params, quant_prompts, None)
    b_eng, b_toks = _serve_traced(model, params, quant_prompts, "f32")
    assert a_toks == b_toks
    for rid, trace in a_eng.logit_trace.items():
        for s, (x, y) in enumerate(zip(trace, b_eng.logit_trace[rid])):
            assert np.array_equal(np.asarray(x), np.asarray(y)), (rid, s)


def test_int8_cache_structure(tiny_model):
    """The int8 pool stores int8 K/V plus per-(block, row, head) f32
    scales; the f32 pool has no scale leaves at all."""
    model, params = tiny_model
    nb, bs = 6, 4
    cache = model.init_paged_cache(nb, bs, dtype=jnp.float32,
                                   kv_dtype="int8")
    leaves = jax.tree_util.tree_flatten_with_path(cache)[0]

    def by_name(name):
        return [l for p, l in leaves
                if any(isinstance(k, jax.tree_util.DictKey) and k.key == name
                       for k in p)]

    ks, scales = by_name("k"), by_name("k_scale")
    assert ks and scales and len(ks) == len(scales)
    for k, s in zip(ks, scales):
        assert k.dtype == jnp.int8
        assert s.dtype == jnp.float32
        assert s.shape == k.shape[:-1]   # head_dim reduced away
    plain = model.init_paged_cache(nb, bs, dtype=jnp.float32)
    plain_leaves = jax.tree_util.tree_flatten_with_path(plain)[0]
    assert not [l for p, l in plain_leaves
                if any(isinstance(k, jax.tree_util.DictKey)
                       and k.key.endswith("_scale") for k in p)]


def test_int8_capacity_at_least_doubles(tiny_model):
    """The point of the feature: at equal pool bytes, int8 must fit at
    least 2x the blocks f32 does."""
    model, params = tiny_model
    f32 = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=4, block_size=4)
    q = ServeEngine(model, params, batch_size=2, capacity=32,
                    max_new_tokens=4, block_size=4, kv_dtype="int8")
    assert f32.kv_bytes_per_block() >= 2 * q.kv_bytes_per_block()
    assert q.pool_stats()["kv_dtype"] == "int8"


def test_engine_gates_unsupported_combos(tiny_model):
    model, params = tiny_model
    with pytest.raises(ValueError, match="kv_dtype"):
        ServeEngine(model, params, kv_dtype="fp4")
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params, kv_dtype="int8", paged=False)
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(model, params, kv_dtype="int8", spec_k=2)
