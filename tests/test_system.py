"""End-to-end behaviour tests for the reproduced system."""
import numpy as np

from repro.core import parse_pipeline


def test_paper_figure1_style_pipeline():
    """The paper's exemplary pipeline shape: camera -> converter ->
    transform -> two NN branches (tee) -> decoder/sink."""
    def nn1(x):
        return np.asarray(x, np.float32).mean(axis=(0, 1))

    def nn2(x):
        return np.asarray([[2, 2, 4, 4, 0.9]], np.float32)

    p = parse_pipeline(
        "videotestsrc num_buffers=8 width=16 height=16 ! "
        "tensor_converter to_float=true ! "
        "tensor_transform option=multiply:2.0 ! tee name=t num_src_pads=2 "
        "t.src_0 ! queue ! tensor_filter framework=python model=nn1 ! "
        "tensor_decoder mode=argmax_label ! tensor_sink name=labels keep=true "
        "t.src_1 ! queue ! tensor_filter framework=python model=nn2 ! "
        "tensor_decoder mode=bounding_boxes ! tensor_sink name=boxes keep=true",
        models={"nn1": nn1, "nn2": nn2})
    p.run_until_eos(timeout=30)
    assert p["labels"].n_received == 8
    assert p["boxes"].n_received == 8
    assert "label" in p["labels"].buffers[0].meta
    assert p["boxes"].buffers[0].meta["boxes"][0]["score"] > 0
