"""Speculative draft-verify decode bursts — transformer conformance.

A small draft model runs ``spec_k`` tokens ahead inside the paged
decode burst; the target verifies every drafted position in one
batched ``paged_step`` and the standard rejection-sampling accept rule
keeps the output distribution *provably* that of the target alone.
The checkable consequences, pinned here:

  * greedy speculative decode is **token-identical** to non-speculative
    greedy decode — including staggered joins, eos truncation, and
    preemption spill/restore mid-speculation;
  * a self-draft (draft == target) accepts every proposal;
  * the accept rule itself preserves the target distribution
    (seeded empirical check directly on ``spec_accept``), and so does
    the end-to-end sampled engine;
  * recurrent families (mamba / xlstm / hybrid) are rejected with a
    descriptive error, as target *and* as draft — rejected tokens roll
    back by length arithmetic, which recurrent state slabs cannot do.

Test names carry the family (``transformer`` / ``mamba`` / ...) so the
CI family-conformance matrix can select rows with ``-k``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import FAMILY_CFGS, RECURRENT_FAMILIES
from repro.models import build_model
from repro.serving import ServeEngine, spec_accept

from test_kv_paged import TINY, _fresh_dense_tokens

DRAFT = TINY.replace(arch_id="tiny-draft", n_layers=1, d_model=16,
                     n_heads=2, n_kv_heads=1, d_ff=32)


@pytest.fixture(scope="module")
def target_mp():
    model = build_model(TINY)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def draft_mp():
    model = build_model(DRAFT)
    return model, model.init(jax.random.PRNGKey(1))


def _prompts(sizes=(5, 9, 3), seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, TINY.vocab_size, n).astype(np.int32)
            for n in sizes]


def _serve(model, params, prompts, *, draft=None, spec_k=0, max_new=10,
           eos_id=None, temperature=0.0, top_k=None, seed=0, burst=4,
           batch_size=4):
    dm, dp = draft if draft is not None else (None, None)
    eng = ServeEngine(model, params, batch_size=batch_size, capacity=64,
                      max_new_tokens=max_new, block_size=4, prefill_chunk=8,
                      burst=burst, eos_id=eos_id, temperature=temperature,
                      top_k=top_k, seed=seed, draft_model=dm,
                      draft_params=dp, spec_k=spec_k)
    assert eng.paged
    for p in prompts:
        eng.submit(p, lane="batch")
    results = []
    while eng.has_work:
        results += eng.step()
    return eng, {r.request_id: r for r in results}


# -- greedy token identity ----------------------------------------------------

@pytest.mark.parametrize("spec_k", [2, 4])
def test_transformer_spec_greedy_token_identical(target_mp, draft_mp, spec_k):
    """Greedy spec == non-spec greedy == the dense oracle, per request."""
    model, params = target_mp
    prompts = _prompts()
    _, ref = _serve(model, params, prompts)
    eng, out = _serve(model, params, prompts, draft=draft_mp, spec_k=spec_k)
    for rid, p in enumerate(prompts):
        assert list(out[rid].tokens) == list(ref[rid].tokens), rid
        assert list(out[rid].tokens) == \
            _fresh_dense_tokens(model, params, p, 10), rid
        assert out[rid].status == "ok"
    ls = eng.loop_stats()
    assert ls["n_spec_rounds"] > 0 and ls["n_draft_proposed"] > 0


def test_transformer_spec_greedy_identity_with_joins(target_mp, draft_mp):
    """Requests joining mid-burst (staggered admission, mixed prefill +
    in-flight speculation) still produce oracle-identical streams."""
    model, params = target_mp
    dm, dp = draft_mp
    prompts = _prompts((6, 9, 4), seed=5)
    eng = ServeEngine(model, params, batch_size=4, capacity=64,
                      max_new_tokens=10, block_size=4, prefill_chunk=8,
                      burst=4, draft_model=dm, draft_params=dp, spec_k=3)
    eng.submit(prompts[0], lane="batch")
    results = []
    joined = False
    while eng.has_work:
        results += eng.step()
        if not joined and any(
                s is not None and s.rid == 0 and len(s.tokens) >= 2
                for s in eng._slots):
            for p in prompts[1:]:
                eng.submit(p, lane="batch")
            joined = True
    assert joined, "request 0 finished before the joiners were submitted"
    out = {r.request_id: r for r in results}
    for rid, p in enumerate(prompts):
        assert list(out[rid].tokens) == \
            _fresh_dense_tokens(model, params, p, 10), rid


def test_transformer_spec_eos_truncation_identity(target_mp, draft_mp):
    """An eos landing inside the drafted prefix truncates the round at
    exactly the position non-speculative decode would stop at."""
    model, params = target_mp
    prompts = _prompts((5, 7), seed=9)
    _, free = _serve(model, params, prompts, max_new=12)
    # pick an eos that actually appears mid-stream in some output
    eos = None
    for r in free.values():
        toks = list(r.tokens)
        if len(toks) > 2:
            eos = toks[len(toks) // 2]
            break
    assert eos is not None
    _, ref = _serve(model, params, prompts, max_new=12, eos_id=eos)
    _, out = _serve(model, params, prompts, draft=draft_mp, spec_k=4,
                    max_new=12, eos_id=eos)
    for rid in ref:
        assert list(out[rid].tokens) == list(ref[rid].tokens), rid


def test_transformer_spec_self_draft_accepts_everything(target_mp):
    """Draft == target: every greedy proposal matches the target argmax,
    so every drafted token is accepted (the upper bound of the rule)."""
    model, params = target_mp
    prompts = _prompts((5, 8), seed=3)
    _, ref = _serve(model, params, prompts)
    eng, out = _serve(model, params, prompts, draft=(model, params),
                      spec_k=4)
    for rid in ref:
        assert list(out[rid].tokens) == list(ref[rid].tokens), rid
    ls = eng.loop_stats()
    assert ls["n_draft_proposed"] > 0
    assert ls["n_draft_accepted"] == ls["n_draft_proposed"]
    assert ls["spec_accept_rate"] == 1.0


# -- preemption ---------------------------------------------------------------

def test_transformer_spec_preempt_restore_identity(target_mp, draft_mp):
    """A slot preempted mid-speculation spills BOTH cache pools plus the
    spec PRNG/deficit state; the restored request's stream is identical
    to a never-preempted speculative run (itself oracle-identical)."""
    model, params = target_mp
    dm, dp = draft_mp
    prompts = _prompts((8, 6), seed=13)
    _, ref = _serve(model, params, prompts, draft=draft_mp, spec_k=3)
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=8, block_size=4, prefill_chunk=8,
                      burst=2, draft_model=dm, draft_params=dp, spec_k=3)
    for p in prompts:
        eng.submit(p, lane="batch")
    pending = True
    results = []
    while eng.has_work:
        if pending:
            for s in eng._slots:
                if s is not None and s.rid == 0 \
                        and s.prefill_off >= len(s.prompt) \
                        and len(s.tokens) >= 2:
                    assert eng.preempt(0)
                    pending = False
                    break
        results += eng.step()
    assert not pending, "never caught rid 0 mid-decode"
    assert eng.n_preemptions == 1 and eng.n_restores == 1
    out = {r.request_id: r for r in results}
    for rid, p in enumerate(prompts):
        assert list(out[rid].tokens) == list(ref[rid].tokens)[:8], rid
        assert list(out[rid].tokens) == \
            _fresh_dense_tokens(model, params, p, 8), rid
    # pool accounting stayed clean through the spill/restore
    assert eng.allocator.n_free == eng.allocator.num_blocks
    assert eng._reserved == 0


# -- distribution preservation ------------------------------------------------

def _tv(a, b):
    return 0.5 * float(np.abs(np.asarray(a, np.float64)
                              - np.asarray(b, np.float64)).sum())


def test_spec_accept_preserves_target_distribution():
    """Seeded empirical check of the rejection rule itself: with draft
    proposals drawn from q, the emitted tokens are distributed as the
    *target* p at every position — accepted or resampled alike."""
    B, G, V = 20000, 3, 8
    rng = np.random.default_rng(0)
    p = rng.dirichlet(np.ones(V) * 1.5, size=G + 1)
    q = rng.dirichlet(np.ones(V) * 1.5, size=G)
    draft = np.stack([rng.choice(V, size=B, p=qj) for qj in q],
                     axis=1).astype(np.int32)
    emit, n_acc = spec_accept(
        jnp.asarray(draft),
        jnp.broadcast_to(jnp.asarray(q, jnp.float32)[None], (B, G, V)),
        jnp.broadcast_to(jnp.asarray(p, jnp.float32)[None], (B, G + 1, V)),
        jnp.full((B,), G, jnp.int32),
        jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(7), i))(
            jnp.arange(B)))
    emit, n_acc = np.asarray(emit), np.asarray(n_acc)
    # position 0 is always emitted and must be ~ p[0]
    hist0 = np.bincount(emit[:, 0], minlength=V) / B
    assert _tv(hist0, p[0]) < 0.03
    # position 1, over rows whose first draft was accepted, must be ~ p[1]
    sel = n_acc >= 1
    assert sel.sum() > 2000
    hist1 = np.bincount(emit[sel, 1], minlength=V) / sel.sum()
    assert _tv(hist1, p[1]) < 0.05
    # full acceptance draws the bonus from the target's extra row alone
    sel = n_acc == G
    if sel.sum() > 1000:
        histG = np.bincount(emit[sel, G], minlength=V) / sel.sum()
        assert _tv(histG, p[G]) < 0.08


def test_spec_accept_budget_rows_draw_from_target_row():
    """A zero-budget row accepts nothing and its replacement comes from
    the target row alone (draft probs there are garbage by contract)."""
    B, G, V = 8000, 2, 6
    rng = np.random.default_rng(1)
    p0 = rng.dirichlet(np.ones(V))
    garbage = jnp.asarray(rng.random((B, G, V)), jnp.float32)  # not a dist
    emit, n_acc = spec_accept(
        jnp.zeros((B, G), jnp.int32), garbage,
        jnp.broadcast_to(jnp.asarray(p0, jnp.float32)[None, None],
                         (B, G + 1, V)),
        jnp.zeros((B,), jnp.int32),
        jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(9), i))(
            jnp.arange(B)))
    emit, n_acc = np.asarray(emit), np.asarray(n_acc)
    assert (n_acc == 0).all()
    hist = np.bincount(emit[:, 0], minlength=V) / B
    assert _tv(hist, p0) < 0.04


def test_transformer_spec_sampled_matches_nonspec_distribution(
        target_mp, draft_mp):
    """End-to-end: the sampled spec engine's per-step token marginals
    match the non-spec engine's.  Token 0 is drawn pre-speculation from
    the same (seed, rid, step) stream, so it must be *identical*; token
    1 is spec-affected, so its empirical distribution over many rids is
    compared in total variation."""
    model, params = target_mp
    prompt = _prompts((6,), seed=21)[0]
    n = 240
    kw = dict(max_new=3, temperature=0.7, top_k=4, seed=11,
              batch_size=8, burst=2)
    _, ref = _serve(model, params, [prompt] * n, **kw)
    _, out = _serve(model, params, [prompt] * n, draft=draft_mp,
                    spec_k=3, **kw)
    t0_ref = [ref[i].tokens[0] for i in range(n)]
    t0_out = [out[i].tokens[0] for i in range(n)]
    assert t0_ref == t0_out
    V = TINY.vocab_size
    h_ref = np.bincount([ref[i].tokens[1] for i in range(n)],
                        minlength=V) / n
    h_out = np.bincount([out[i].tokens[1] for i in range(n)],
                        minlength=V) / n
    # top_k=4 concentrates the support; sampling noise at n=240 keeps
    # honest runs well under this bound while an off-by-one-row bug in
    # the accept rule lands far above it
    assert _tv(h_ref, h_out) < 0.25


# -- stats & gating -----------------------------------------------------------

def test_transformer_spec_loop_stats(target_mp, draft_mp):
    model, params = target_mp
    eng, _ = _serve(model, params, _prompts((5, 7), seed=2),
                    draft=draft_mp, spec_k=3)
    ls = eng.loop_stats()
    for key in ("spec_k", "n_spec_rounds", "n_spec_tokens",
                "n_draft_proposed", "n_draft_accepted",
                "spec_accept_hist", "spec_accept_rate"):
        assert key in ls, key
    assert ls["spec_k"] == 3
    assert len(ls["spec_accept_hist"]) == 4
    assert sum(ls["spec_accept_hist"]) == ls["n_spec_rounds"]
    assert 0 <= ls["n_draft_accepted"] <= ls["n_draft_proposed"]
    assert 0.0 <= ls["spec_accept_rate"] <= 1.0
    assert ls["n_spec_tokens"] >= ls["n_spec_rounds"]  # >= 1 token/round
    # non-spec engines advertise none of this
    eng2, _ = _serve(model, params, _prompts((4,), seed=2))
    assert "n_spec_rounds" not in eng2.loop_stats()


def test_transformer_spec_gating_errors(target_mp, draft_mp):
    model, params = target_mp
    dm, dp = draft_mp
    with pytest.raises(ValueError, match="requires draft_model"):
        ServeEngine(model, params, spec_k=2)
    with pytest.raises(ValueError, match="requires paged mode"):
        ServeEngine(model, params, paged=False, draft_model=dm,
                    draft_params=dp, spec_k=2)
    with pytest.raises(ValueError, match="prefill_chunk >= 2"):
        ServeEngine(model, params, prefill_chunk=1, draft_model=dm,
                    draft_params=dp, spec_k=2)
    with pytest.raises(ValueError, match="share_prefix=True is incompatible"):
        ServeEngine(model, params, share_prefix=True, draft_model=dm,
                    draft_params=dp, spec_k=2)
    with pytest.raises(ValueError, match="spec_k must be >= 0"):
        ServeEngine(model, params, spec_k=-1)
    with pytest.raises(ValueError, match="vocab mismatch"):
        odd = build_model(DRAFT.replace(arch_id="tiny-odd-vocab",
                                        vocab_size=32))
        ServeEngine(model, params, draft_model=odd, draft_params={},
                    spec_k=2)
    # spec mode forces prefix sharing off (COW forks only cover the
    # target pool) — auto share_prefix must resolve to False
    eng = ServeEngine(model, params, draft_model=dm, draft_params=dp,
                      spec_k=2)
    assert eng.share_prefix is False


@pytest.mark.parametrize("family", RECURRENT_FAMILIES)
def test_spec_rejected_for_recurrent_family(family, target_mp, draft_mp):
    """Rollback is arithmetic on lengths; recurrent state advanced
    through rejected tokens cannot be rolled back.  Both roles gated."""
    model, params = target_mp
    dm, dp = draft_mp
    rec = build_model(FAMILY_CFGS[family])
    with pytest.raises(ValueError, match="target model .*recurrent"):
        ServeEngine(rec, {}, draft_model=dm, draft_params=dp, spec_k=2)
    with pytest.raises(ValueError, match="draft model .*recurrent"):
        ServeEngine(model, params, draft_model=rec, draft_params={},
                    spec_k=2)
