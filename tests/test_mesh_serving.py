"""Tensor-parallel paged serving conformance — the sharded matrix.

A ServeEngine handed a ``(1, N)`` serving mesh shards the model weights
by the training PartitionSpec rules and the paged KV pool's feature
dims over the "model" axis, while every piece of host-mirrored control
state (page tables, lengths, slot tokens) stays replicated.  The
contract under test: sharded decode is **token-identical** to the
single-device engine — greedy and seeded sampling, through mid-decode
joins, prefix-shared COW forks, and preemption spill/restore — because
tensor parallelism only changes *where* each matmul shard runs, never
what the sampler sees (logits are gathered replicated before every
draw).

Bit-identical logits across *different* mesh sizes are explicitly not
the bar (sharded reductions reorder float sums); token identity is, and
within one mesh shape preempted vs. undisturbed runs must still match
bitwise.

Needs >= 2 devices.  On CPU simulate them with::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_mesh_serving.py
"""
import jax
import numpy as np
import pytest

if jax.device_count() < 2:
    pytest.skip(
        "needs >= 2 devices "
        "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
        allow_module_level=True)

from repro.launch.mesh import make_serving_mesh
from repro.serving import ServeEngine

from test_kv_paged import TINY


def _serve_all(model, params, prompts, *, mesh=None, temperature=0.0,
               top_k=None, seed=0, trace=False, preempt_rid=None,
               after_tokens=2):
    """Serve ``prompts``; the tail of the list is submitted two ticks
    in (so late requests join slots that are already mid-decode),
    optionally preempting one request mid-decode."""
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=8, block_size=4, prefill_chunk=4,
                      temperature=temperature, top_k=top_k, seed=seed,
                      trace_logits=trace, mesh=mesh)
    assert eng.paged
    for p in prompts[:2]:
        eng.submit(p, lane="batch")
    late, ticks = list(prompts[2:]), 0
    pending = preempt_rid is not None
    results = []
    while eng.has_work or late:
        ticks += 1
        if ticks == 3:
            for p in late:
                eng.submit(p, lane="batch")
            late = []
        if pending:
            for s in eng._slots:
                if s is None or s.rid != preempt_rid:
                    continue
                if (s.prefill_off >= len(s.prompt)
                        and len(s.tokens) >= after_tokens):
                    assert eng.preempt(preempt_rid)
                    pending = False
                break
        results += eng.step()
    assert not pending, "never caught the slot mid-decode"
    return eng, {r.request_id: r for r in results}


def _prompts(seed, n=5, vocab=TINY.vocab_size):
    # spread lengths across prefill-chunk boundaries so slots finish at
    # different ticks (that's what makes mid-decode joins happen)
    rng = np.random.default_rng(seed)
    lengths = [4, 12, 6, 11, 8][:n]
    return [rng.integers(1, vocab, k).astype(np.int32) for k in lengths]


def _assert_same_results(ref, got, label):
    assert set(ref) == set(got)
    for rid in ref:
        assert got[rid].status == ref[rid].status == "ok", (label, rid)
        assert list(got[rid].tokens) == list(ref[rid].tokens), \
            f"{label}: rid {rid} tokens diverged"


# -- token identity: the four-family matrix ------------------------------

def test_mesh2_token_identical_greedy(family_model):
    family, model, params = family_model
    prompts = _prompts(23)
    _, ref = _serve_all(model, params, prompts)
    eng, got = _serve_all(model, params, prompts,
                          mesh=make_serving_mesh(model=2))
    _assert_same_results(ref, got, f"{family} mesh=2 greedy")
    assert eng.n_joins > 0          # identity held through mid-decode joins


def test_mesh2_token_identical_sampled(family_model):
    """Sampler keys fold (seed, request, step) — placement-independent,
    so seeded sampling matches across mesh sizes too."""
    family, model, params = family_model
    prompts = _prompts(29)
    kw = dict(temperature=0.8, top_k=8, seed=3)
    _, ref = _serve_all(model, params, prompts, **kw)
    _, got = _serve_all(model, params, prompts,
                        mesh=make_serving_mesh(model=2), **kw)
    _assert_same_results(ref, got, f"{family} mesh=2 sampled")


def test_mesh_sweep_transformer():
    """Every mesh size the host can simulate decodes the same tokens."""
    from repro.models import build_model
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(31)
    _, ref = _serve_all(model, params, prompts)
    for n in (2, 4, 8):
        if n > jax.device_count():
            continue
        _, got = _serve_all(model, params, prompts,
                            mesh=make_serving_mesh(model=n))
        _assert_same_results(ref, got, f"mesh={n}")


# -- sharded engine behaviors --------------------------------------------

def test_mesh_prefix_share_cow_identity():
    """Prefix sharing + COW forks run unchanged over the mesh: block
    bookkeeping is host-side and replicated, only the pool payload is
    sharded."""
    from repro.models import build_model
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(37)
    shared = rng.integers(1, TINY.vocab_size, 8).astype(np.int32)
    prompts = [np.concatenate(
                   [shared,
                    rng.integers(1, TINY.vocab_size, 3 + i).astype(np.int32)])
               for i in range(4)]
    _, ref = _serve_all(model, params, prompts)
    eng, got = _serve_all(model, params, prompts,
                          mesh=make_serving_mesh(model=2))
    _assert_same_results(ref, got, "mesh=2 prefix-shared")
    assert eng.n_prefix_hits > 0 and eng.n_shared_tokens > 0


def test_mesh_preempt_restore(family_model):
    """Spill/restore round-trips sharded pages through host memory and
    back; the restored request must match the undisturbed sharded run
    bitwise (same mesh => same reduction order) and the single-device
    run token-wise."""
    family, model, params = family_model
    prompts = _prompts(41, n=2)
    mesh = make_serving_mesh(model=2)
    _, base = _serve_all(model, params, prompts)
    ref_eng, ref = _serve_all(model, params, prompts, mesh=mesh, trace=True)
    pre_eng, pre = _serve_all(model, params, prompts, mesh=mesh, trace=True,
                              preempt_rid=0)
    assert pre_eng.n_preemptions == 1 and pre_eng.n_restores == 1
    _assert_same_results(ref, pre, f"{family} mesh preempt")
    _assert_same_results(base, pre, f"{family} mesh-vs-single preempt")
    for rid, trace in ref_eng.logit_trace.items():
        other = pre_eng.logit_trace[rid]
        assert len(trace) == len(other), (family, rid)
        for step, (x, y) in enumerate(zip(trace, other)):
            assert np.array_equal(x, y), \
                f"{family}: rid {rid} logits diverged at step {step}"


def test_mesh_params_and_pool_actually_sharded():
    """The mesh engine must not silently replicate everything: at least
    one weight leaf and one paged-pool leaf are split over "model"."""
    from repro.models import build_model
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    eng, _ = _serve_all(model, params, _prompts(43, n=2),
                        mesh=make_serving_mesh(model=2))
    p_sharded = [l for l in jax.tree.leaves(eng.params)
                 if not l.sharding.is_fully_replicated]
    assert p_sharded, "no parameter leaf is sharded over the mesh"
    c_sharded = [l for l in jax.tree.leaves(eng._paged_cache)
                 if not l.sharding.is_fully_replicated]
    assert c_sharded, "no paged-pool leaf is sharded over the mesh"


def test_mesh_steady_state_upload_parity():
    """Sharding must not degrade the device-resident decode loop: the
    mesh engine re-uploads slot state exactly as often as the
    single-device engine (structural changes only, never per tick)."""
    from repro.models import build_model
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(47)
    ref_eng, _ = _serve_all(model, params, prompts)
    mesh_eng, _ = _serve_all(model, params, prompts,
                             mesh=make_serving_mesh(model=2))
    ref_ls, mesh_ls = ref_eng.loop_stats(), mesh_eng.loop_stats()
    assert mesh_ls["n_state_uploads"] == ref_ls["n_state_uploads"]
    assert mesh_ls["n_device_steps"] == ref_ls["n_device_steps"]


def test_mesh_requires_paged_mode():
    from repro.models import build_model
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params, batch_size=2, capacity=32,
                    max_new_tokens=4, paged=False,
                    mesh=make_serving_mesh(model=2))
