"""Pipeline/parser/scheduler behaviour."""
import time

import numpy as np
import pytest

from repro.core import Pipeline, PipelineError, parse_pipeline
from repro.core.elements import Queue
from repro.core.stream import Buffer
from repro.single import SingleShot


def test_parser_chain_and_props():
    p = parse_pipeline("videotestsrc num_buffers=3 width=8 height=8 ! "
                       "tensor_converter ! fakesink name=out")
    assert set(p.elements) >= {"out"}
    p.run_until_eos(timeout=20)
    assert p["out"].n_received == 3


def test_parser_forward_references():
    p = parse_pipeline(
        "appsrc name=a ! m.sink_0 appsrc name=b ! m.sink_1 "
        "tensor_mux name=m num_sinks=2 ! fakesink name=out")
    p.start()
    p["a"].push(np.zeros(2), pts=0.0)
    p["b"].push(np.zeros(2), pts=0.0)
    assert p["out"].n_received == 1
    p.stop()


def test_parser_errors():
    with pytest.raises(ValueError):
        parse_pipeline("nosuchelement ! fakesink")
    with pytest.raises(ValueError):
        parse_pipeline("appsrc name=a !")


def test_error_bus_propagates():
    def boom(x):
        raise RuntimeError("boom")

    p = parse_pipeline(
        "videotestsrc num_buffers=3 width=8 height=8 ! queue ! "
        "tensor_filter framework=python model=boom ! fakesink name=out",
        models={"boom": boom})
    with pytest.raises(PipelineError):
        p.run_until_eos(timeout=20)


def test_queue_leaky_downstream_drops():
    q = Queue("q", max_size=2, leaky="downstream")
    # not started: worker not draining, so puts beyond capacity drop
    for i in range(5):
        q._running = True
        q.chain(q.sinkpad, Buffer(np.zeros(1), pts=float(i)))
    assert q.n_dropped == 3


def test_duplicate_element_names_rejected():
    with pytest.raises(ValueError):
        parse_pipeline("appsrc name=x ! fakesink name=x")


def test_caps_negotiation_failure_at_link():
    from repro.core.element import Element
    from repro.core.stream import TensorSpec

    up = Element("up")
    up.add_src_pad(spec=TensorSpec(dims=(4,), dtype="float32"))
    down = Element("down")
    down.add_sink_pad(spec=TensorSpec(dims=(5,), dtype="float32"))
    with pytest.raises(ValueError, match="caps negotiation failed"):
        up.link(down)


def test_single_api_latency_stats():
    s = SingleShot(fn=lambda x: x * 2)
    for _ in range(4):
        s.invoke(np.ones(3))
    assert s.n_invocations == 4
    assert s.mean_latency_s >= 0.0
    np.testing.assert_allclose(s.invoke(np.ones(3)), 2 * np.ones(3))


def test_jax_backend_filter():
    import jax.numpy as jnp
    s = SingleShot(fn=lambda x: jnp.tanh(x).sum(), framework="jax")
    out = np.asarray(s.invoke(np.ones((4, 4), np.float32)))
    assert np.isfinite(out)


def test_end_to_end_multimodal_system():
    """System test: camera + sensor fused by mux into a joint model."""
    def fusion(img, sensor):
        return np.concatenate([np.asarray(img, np.float32).reshape(-1)[:4],
                               np.asarray(sensor, np.float32)])

    p = parse_pipeline(
        "videotestsrc num_buffers=6 width=8 height=8 ! tensor_converter "
        "to_float=true ! mux.sink_0 "
        "sensorsrc num_buffers=6 channels=3 ! mux.sink_1 "
        "tensor_mux name=mux num_sinks=2 sync=slowest ! queue ! "
        "tensor_filter framework=python model=fusion ! "
        "tensor_sink name=out keep=true", models={"fusion": fusion})
    p.run_until_eos(timeout=30)
    assert p["out"].n_received == 6
    assert p["out"].buffers[0].data.shape == (7,)
