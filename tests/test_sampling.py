"""Seeded sampling across serving modes.

The engine derives slot ``b``'s key for its ``t``-th token as
``fold_in(fold_in(PRNGKey(seed), request_id), t)`` and draws through one
shared jitted sampler, so the token stream is a function of
``(seed, request, step)`` only — not of serving mode, batch composition,
or join timing.  These tests pin that contract:

  * paged seeded sampling == dense seeded sampling, token for token;
  * ``temperature=0`` is exactly the greedy path (no rng involved);
  * same seed reproduces, different seeds diverge;
  * the ``sample_logits`` primitive respects top-k / temperature.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.models.config import ModelConfig
from repro.serving import ServeEngine, sample_logits

TINY = ModelConfig(
    arch_id="tiny-sampling", family="dense", n_layers=2, d_model=32,
    n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
    norm="rmsnorm", mlp_act="swiglu", rope="rope",
    param_dtype="float32", compute_dtype="float32")


@pytest.fixture(scope="module")
def tiny_model():
    model = build_model(TINY)
    return model, model.init(jax.random.PRNGKey(0))


def _prompts(n=4, length=6, seed=2):
    # equal lengths: the dense engine then prefills one un-padded wave,
    # so both modes decode at identical true positions
    rng = np.random.default_rng(seed)
    return [rng.integers(1, TINY.vocab_size, length).astype(np.int32)
            for _ in range(n)]


def _serve_tokens(model, params, prompts, **kw):
    kw.setdefault("batch_size", len(prompts))
    kw.setdefault("capacity", 32)
    kw.setdefault("max_new_tokens", 6)
    eng = ServeEngine(model, params, **kw)
    res = eng.serve([p.copy() for p in prompts])
    assert [r.request_id for r in res] == list(range(len(prompts)))
    return eng, [list(r.tokens) for r in res]


def test_paged_sampling_matches_dense_seeded(tiny_model):
    model, params = tiny_model
    prompts = _prompts()
    # temperature > 0 alone selects sampling — no greedy=False needed
    cfg = dict(temperature=0.8, top_k=16, seed=11)
    eng_d, toks_d = _serve_tokens(model, params, prompts, paged=False, **cfg)
    eng_p, toks_p = _serve_tokens(model, params, prompts, paged=True,
                                  block_size=4, prefill_chunk=8, **cfg)
    assert not eng_d.paged and eng_p.paged
    assert toks_d == toks_p
    # and actually sampled: a greedy run disagrees somewhere
    _, toks_g = _serve_tokens(model, params, prompts, paged=True,
                              block_size=4, prefill_chunk=8)
    assert toks_p != toks_g


def test_cross_mode_seeded_sampling_per_family(family_model):
    """The (seed, request, step) sampling contract holds for every
    serving family: recurrent/hybrid stacks draw the same token streams
    through the paged engine (state slabs, chunked prefill) as through
    a dense run of the same seed — temperature > 0, token-identical.

    Equal-length prompts keep the dense engine to one un-padded prefill
    wave, so both modes decode at identical true positions (for
    recurrent layers dense left-padding would not just shift positions,
    it would corrupt the state summary)."""
    family, model, params = family_model
    prompts = _prompts(n=4, length=6, seed=23)
    cfg = dict(temperature=0.8, top_k=16, seed=29)
    eng_d, toks_d = _serve_tokens(model, params, prompts, paged=False, **cfg)
    eng_p, toks_p = _serve_tokens(model, params, prompts, paged=True,
                                  block_size=4, prefill_chunk=8, **cfg)
    assert not eng_d.paged and eng_p.paged, family
    if family != "transformer":
        assert eng_p.state_store is not None
    assert toks_d == toks_p, family
    # and actually sampled: the greedy stream disagrees somewhere
    _, toks_g = _serve_tokens(model, params, prompts, paged=True,
                              block_size=4, prefill_chunk=8)
    assert toks_p != toks_g, family
    # reruns are reproducible: same seed, same paged stream
    _, toks_p2 = _serve_tokens(model, params, prompts, paged=True,
                               block_size=4, prefill_chunk=8, **cfg)
    assert toks_p2 == toks_p, family


def test_sampling_survives_mid_decode_join(tiny_model):
    """Join timing must not shift a request's sample stream: the key is
    a function of (request, step), not of when the slot was admitted."""
    model, params = tiny_model
    prompts = _prompts(n=3, length=6, seed=5)
    cfg = dict(greedy=False, temperature=0.9, top_k=None, seed=3,
               paged=True, block_size=4, prefill_chunk=8)
    # batch_size 4: all three run together, no queueing
    _, together = _serve_tokens(model, params, prompts, batch_size=4, **cfg)
    # batch_size 1: strictly sequential — same per-request streams
    eng, seq = _serve_tokens(model, params, prompts, batch_size=1, **cfg)
    assert eng.n_requests == 3
    assert seq == together


def test_temperature_zero_reduces_to_greedy(tiny_model):
    model, params = tiny_model
    prompts = _prompts(seed=7)
    for paged in (False, True):
        _, greedy = _serve_tokens(model, params, prompts, paged=paged)
        eng, t0 = _serve_tokens(model, params, prompts, paged=paged,
                                greedy=False, temperature=0.0, seed=9)
        assert eng._greedy           # temperature 0 selects the greedy path
        assert t0 == greedy
    # paged default (auto) serves sampling engines too now
    eng = ServeEngine(model, params, greedy=False, temperature=0.5)
    assert eng.paged


def test_seeded_sampling_reproducible_and_seed_sensitive(tiny_model):
    model, params = tiny_model
    prompts = _prompts(seed=13)
    cfg = dict(paged=True, block_size=4, prefill_chunk=8, greedy=False,
               temperature=1.2, max_new_tokens=8)
    _, a = _serve_tokens(model, params, prompts, seed=17, **cfg)
    _, b = _serve_tokens(model, params, prompts, seed=17, **cfg)
    _, c = _serve_tokens(model, params, prompts, seed=18, **cfg)
    assert a == b                    # reruns are bit-reproducible
    assert a != c                    # the seed actually feeds the draw


def test_sample_logits_primitive():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((5, 32)), jnp.float32)
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(5)])
    argmax = np.asarray(jnp.argmax(logits, axis=-1))
    # greedy and temperature=0 are exact argmax, with or without keys
    assert np.array_equal(sample_logits(logits), argmax)
    assert np.array_equal(
        sample_logits(logits, keys, greedy=False, temperature=0.0), argmax)
    # top_k=1 degenerates to argmax whatever the key
    assert np.array_equal(
        sample_logits(logits, keys, greedy=False, temperature=0.7, top_k=1),
        argmax)
    # top_k=k never samples outside each row's top-k set
    k = 4
    topk = np.asarray(jax.lax.top_k(logits, k)[1])
    for i in range(20):
        keys_i = jnp.stack([jax.random.PRNGKey(100 * i + j)
                            for j in range(5)])
        draw = np.asarray(sample_logits(logits, keys_i, greedy=False,
                                        temperature=1.0, top_k=k))
        for row in range(5):
            assert draw[row] in topk[row]
    # sampling without a key is an error, not silent greediness
    with pytest.raises(ValueError, match="rng"):
        sample_logits(logits, None, greedy=False, temperature=1.0)


def test_engine_rejects_bad_sampling_config(tiny_model):
    model, params = tiny_model
    with pytest.raises(ValueError, match="temperature"):
        ServeEngine(model, params, temperature=-0.5)
    with pytest.raises(ValueError, match="top_k"):
        ServeEngine(model, params, top_k=0)
