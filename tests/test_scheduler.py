"""Priority-aware scheduler: head-of-line fix, lanes, deadlines,
timeout semantics, automatic preemption, and prefix reuse across
evictions.

The paged tests run the tiny transformer from test_kv_paged (real
block accounting); the lane/timeout tests run the deterministic
ToyModel (dense path) where closed-form expected tokens make ordering
assertions exact.
"""
import time

import jax
import numpy as np
import pytest

from repro.models import build_model
from repro.serving import ServeEngine

from test_kv_paged import TINY, _fresh_dense_tokens
from test_serve_continuous import ToyModel, _expected


@pytest.fixture(scope="module")
def tiny_model():
    model = build_model(TINY)
    return model, model.init(jax.random.PRNGKey(0))


def _rng_prompt(rng, n):
    return rng.integers(1, TINY.vocab_size, n).astype(np.int32)


# -- head-of-line blocking (the seed bug) -------------------------------------

def test_small_request_admits_past_blocked_big_one(tiny_model):
    """Regression for FIFO head-of-line admission: a queued request too
    big for the current pool headroom must not block a smaller request
    behind it.  The seed engine admitted from the queue head only, so
    SMALL would have waited for BIG here."""
    model, params = tiny_model
    rng = np.random.default_rng(7)
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=4, block_size=4, num_blocks=5,
                      prefill_chunk=16)
    a = _rng_prompt(rng, 8)       # 3-page worst case: fits, keeps 2 free
    big = _rng_prompt(rng, 16)    # 5-page worst case: blocked while A lives
    small = _rng_prompt(rng, 4)   # 2-page worst case: fits alongside A
    rid_a = eng.submit(a)
    while eng.n_active < 1:
        eng.step()
    rid_big = eng.submit(big)
    rid_small = eng.submit(small)
    # SMALL gets a slot while BIG is still queued
    for _ in range(50):
        eng.step()
        active = {s.rid for s in eng._slots if s is not None}
        if rid_small in active:
            break
    else:
        pytest.fail("small request never admitted past the blocked big one")
    assert eng.scheduler.n_queued() == 1          # big still waiting
    results = {r.request_id: r for r in eng.wait([rid_a, rid_big, rid_small],
                                                 timeout_s=120)}
    assert all(r.status == "ok" for r in results.values())
    for rid, prompt in ((rid_a, a), (rid_big, big), (rid_small, small)):
        assert list(results[rid].tokens) == \
            _fresh_dense_tokens(model, params, prompt, 4)


def test_impossible_request_fails_oom_not_wedged(tiny_model):
    """A request that cannot fit even an empty pool fails fast with
    status 'oom' instead of wedging the queue forever."""
    model, params = tiny_model
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=4, block_size=4, num_blocks=3,
                      prefill_chunk=16)
    rng = np.random.default_rng(8)
    huge = _rng_prompt(rng, 16)            # 5 pages > 3-block pool
    ok = _rng_prompt(rng, 4)
    res = {r.request_id: r
           for r in eng.serve([huge, ok], timeout_s=120)}
    assert res[0].status == "oom" and len(res[0].tokens) == 0
    assert res[1].status == "ok"
    assert list(res[1].tokens) == _fresh_dense_tokens(model, params, ok, 4)


# -- lanes --------------------------------------------------------------------

def test_interactive_lane_admits_before_earlier_batch_work():
    eng = ServeEngine(ToyModel(), params={}, batch_size=1, capacity=64,
                      max_new_tokens=4)
    b1 = eng.submit(np.asarray([2, 3], np.int32), lane="batch")
    while eng.n_active < 1:
        eng.step()
    b2 = eng.submit(np.asarray([4, 5], np.int32), lane="batch")
    i1 = eng.submit(np.asarray([6, 7], np.int32), lane="interactive")
    order = []
    while eng.has_work:
        order.extend(r.request_id for r in eng.step())
    # interactive submitted after b2 but finishes before it
    assert order.index(i1) < order.index(b2)
    res = eng.wait([b1, b2, i1], timeout_s=10)
    assert [list(r.tokens) for r in res] == [
        _expected(np.asarray(p, np.int32), 4)
        for p in ([2, 3], [4, 5], [6, 7])]


def test_unknown_lane_rejected():
    eng = ServeEngine(ToyModel(), params={}, batch_size=1, capacity=64,
                      max_new_tokens=4)
    with pytest.raises(ValueError, match="unknown lane"):
        eng.submit(np.asarray([1, 2], np.int32), lane="bulk")


# -- deadlines ----------------------------------------------------------------

def test_queued_request_expires_past_deadline():
    eng = ServeEngine(ToyModel(), params={}, batch_size=1, capacity=64,
                      max_new_tokens=8)
    occupant = eng.submit(np.asarray([30, 31], np.int32))
    while eng.n_active < 1:
        eng.step()
    doomed = eng.submit(np.asarray([5, 6], np.int32), deadline=0.001)
    time.sleep(0.01)
    res = {r.request_id: r for r in eng.wait([occupant, doomed],
                                             timeout_s=30)}
    assert res[doomed].status == "expired"
    assert len(res[doomed].tokens) == 0
    assert res[occupant].status == "ok"
    assert eng.n_expired == 1


def test_admitted_request_is_immune_to_its_deadline():
    eng = ServeEngine(ToyModel(), params={}, batch_size=2, capacity=64,
                      max_new_tokens=6)
    rid = eng.submit(np.asarray([2, 3], np.int32), deadline=30.0)
    (res,) = eng.wait([rid], timeout_s=30)
    assert res.status == "ok"
    assert res.ttft_s is not None and res.ttft_s < 30.0


# -- serve/wait timeout semantics ---------------------------------------------

def test_wait_timeout_returns_partial_tokens_not_raise():
    eng = ServeEngine(ToyModel(), params={}, batch_size=1, capacity=64,
                      max_new_tokens=40)
    rid = eng.submit(np.asarray([2, 3], np.int32))
    for _ in range(6):                 # generate a few tokens, then stop
        eng.step()
    (res,) = eng.wait([rid], timeout_s=0.0)
    assert res.status == "timeout"
    assert 0 < len(res.tokens) < 40    # partial output is preserved
    assert list(res.tokens) == _expected(
        np.asarray([2, 3], np.int32), len(res.tokens))
    # the pool is clean: the engine serves the next request normally
    nxt = eng.serve([np.asarray([4, 5], np.int32)], timeout_s=30)
    assert nxt[0].status == "ok"
    assert eng.n_active == 0


def test_serve_timeout_fails_queued_requests_without_dropping():
    eng = ServeEngine(ToyModel(), params={}, batch_size=1, capacity=64,
                      max_new_tokens=4)
    prompts = [np.asarray([k, k + 1], np.int32) for k in (2, 4, 6)]
    res = eng.serve(prompts, timeout_s=0.0)
    assert len(res) == 3               # nothing dropped
    assert all(r.status == "timeout" for r in res)
    again = eng.serve(prompts, timeout_s=60)
    assert [r.status for r in again] == ["ok"] * 3
    assert [list(r.tokens) for r in again] == [_expected(p, 4)
                                               for p in prompts]


# -- automatic preemption -----------------------------------------------------

def test_interactive_preempts_running_batch_slot(tiny_model):
    model, params = tiny_model
    rng = np.random.default_rng(9)
    eng = ServeEngine(model, params, batch_size=1, capacity=32,
                      max_new_tokens=6, block_size=4, num_blocks=8,
                      prefill_chunk=16)
    bp = _rng_prompt(rng, 8)
    ip = _rng_prompt(rng, 8)
    rid_b = eng.submit(bp, lane="batch")
    while not (eng._slots[0] is not None and eng._slots[0].tokens):
        eng.step()                     # batch slot is mid-decode
    rid_i = eng.submit(ip, lane="interactive")
    res = {r.request_id: r for r in eng.wait([rid_b, rid_i], timeout_s=120)}
    assert eng.n_preemptions >= 1 and eng.n_restores >= 1
    assert res[rid_i].status == "ok" and res[rid_b].status == "ok"
    # the preempted batch request restored bit-identically: its tokens
    # match a never-preempted dense run of the same prompt
    assert list(res[rid_b].tokens) == \
        _fresh_dense_tokens(model, params, bp, 6)
    assert list(res[rid_i].tokens) == \
        _fresh_dense_tokens(model, params, ip, 6)
    # interactive got the slot first despite arriving second
    assert res[rid_i].ttft_s is not None


# -- prefix reuse across evictions (the seed bug) -----------------------------

def test_prefix_reuse_survives_full_drain(tiny_model):
    """Retained blocks: re-submitting a prompt after its original has
    finished and been evicted still maps the registered prefix pages.
    The seed freed registered blocks on release, so the second run
    re-prefilled from scratch (n_prefix_hits stayed 0)."""
    model, params = tiny_model
    rng = np.random.default_rng(11)
    prompt = _rng_prompt(rng, 8)       # 2 full pages
    eng = ServeEngine(model, params, batch_size=2, capacity=32,
                      max_new_tokens=6, block_size=4, prefill_chunk=16)
    first = eng.serve([prompt], timeout_s=120)
    assert eng.n_prefix_hits == 0
    assert eng.n_active == 0 and eng.allocator.n_live == 0   # fully drained
    second = eng.serve([prompt.copy()], timeout_s=120)
    assert eng.n_prefix_hits == 1
    assert eng.n_shared_tokens == len(prompt) - 1
    oracle = _fresh_dense_tokens(model, params, prompt, 6)
    assert list(first[0].tokens) == oracle
    assert list(second[0].tokens) == oracle   # resurrection kept KV intact
