"""Property-based tests (hypothesis) on framework invariants.

``hypothesis`` is an optional dev dependency (requirements-dev.txt);
without it the property tests skip but the deterministic fallback tests
below still run, so this file always asserts something.
"""
import importlib.util

import numpy as np
import pytest

from repro.core import Buffer, parse_pipeline
from repro.core.elements.batcher import TensorBatcher, TensorUnbatcher
from repro.core.elements.routing import TensorMerge, TensorMux
from repro.core.elements.sinks import TensorSink
from repro.core.elements.transform import (apply_chain_numpy, fold_affine,
                                           parse_chain)
from repro.core.stream import TensorSpec

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


# -- deterministic fallbacks (no hypothesis required) ------------------------

def test_caps_rank_agnostic_negotiation_fallback():
    """TensorSpec rank-agnostic negotiation on fixed cases (paper §III)."""
    for dims in [(640, 480), (3,), (2, 4, 8, 16)]:
        a = TensorSpec(dims=dims)
        b = TensorSpec(dims=dims + (1, 1))
        assert a.compatible(b) and b.compatible(a)
    # trailing 1s are insignificant, interior 1s are not
    assert TensorSpec(dims=(640, 480)).compatible(TensorSpec(dims=(640, 480, 1)))
    assert not TensorSpec(dims=(640, 480)).compatible(TensorSpec(dims=(640, 1, 480)))
    # require_rank pins the exact rank (TensorRT-style escape hatch)
    assert not TensorSpec(dims=(640, 480), require_rank=True).compatible(
        TensorSpec(dims=(640, 480, 1)))
    # dtype must still match
    assert not TensorSpec(dims=(4,), dtype="float32").compatible(
        TensorSpec(dims=(4,), dtype="uint8"))


def _batcher_roundtrip(n_frames, max_batch, dims, n_chunks, seed):
    """Shared body: random frames through tensor_batcher→tensor_unbatcher
    must come back identical — data, chunk arity, pts, meta, order —
    including the EOS partial-flush path (n_frames % max_batch != 0)."""
    batcher = TensorBatcher("b", max_batch=max_batch)
    unb = TensorUnbatcher("u")
    sink = TensorSink("s", keep=True)
    batcher.link(unb)
    unb.link(sink)
    rng = np.random.default_rng(seed)
    frames = [tuple(rng.standard_normal(dims).astype(np.float32)
                    for _ in range(n_chunks)) for _ in range(n_frames)]
    pts = [float(rng.uniform(0, 100)) for _ in range(n_frames)]
    for i, chunks in enumerate(frames):
        batcher.chain(batcher.sinkpad,
                      Buffer(chunks, pts=pts[i], meta={"i": i, "tag": f"f{i}"}))
    batcher.chain(batcher.sinkpad, Buffer.eos_buffer())  # flush the remainder
    assert sink.eos_seen.is_set()
    assert sink.n_received == n_frames
    for i, (buf, chunks) in enumerate(zip(sink.buffers, frames)):
        assert buf.pts == pts[i]
        assert buf.meta == {"i": i, "tag": f"f{i}"}
        assert len(buf.chunks) == n_chunks
        for got, sent in zip(buf.chunks, chunks):
            np.testing.assert_array_equal(np.asarray(got), sent)
    if n_frames % max_batch:
        assert batcher.n_eos_flushes == 1


def test_batcher_unbatcher_roundtrip_fallback():
    for n_frames, max_batch, dims, n_chunks in [
            (1, 4, (3,), 1),           # single frame, pure EOS flush
            (8, 4, (2, 5), 1),         # exact multiple, no partial
            (7, 3, (4,), 2),           # partial final batch, multi-chunk
            (5, 1, (1,), 1)]:          # batch size 1 degenerates to pass-thru
        _batcher_roundtrip(n_frames, max_batch, dims, n_chunks,
                           seed=n_frames * 31 + max_batch)


if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")

    dims_st = st.lists(st.integers(1, 16), min_size=1, max_size=4)
else:  # pragma: no cover - exercised only without hypothesis installed
    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            return _skipped
        return deco
    dims_st = None

    class st:  # noqa: N801 - stand-in namespace
        @staticmethod
        def lists(*a, **k): return None
        @staticmethod
        def integers(*a, **k): return None
        @staticmethod
        def sampled_from(*a, **k): return None


@given(dims_st)
def test_caps_trailing_ones_equivalent(dims):
    a = TensorSpec(dims=tuple(dims))
    b = TensorSpec(dims=tuple(dims) + (1, 1))
    assert a.compatible(b) and b.compatible(a)


@given(dims_st, st.sampled_from(["float32", "uint8", "int32"]))
def test_spec_shape_roundtrip(dims, dtype):
    spec = TensorSpec(dims=tuple(dims), dtype=dtype)
    arr = np.zeros(spec.shape, dtype=dtype)
    assert TensorSpec.from_array(arr).compatible(spec)


@given(st.integers(1, 8), st.integers(1, 5))
def test_mux_demux_roundtrip(n_tensors, length):
    arrays = [np.random.rand(length + i) for i in range(n_tensors)]
    buf = Buffer(tuple(arrays), pts=1.0)
    # zero-copy: rebundling preserves identity and order
    out = buf.with_chunks(buf.chunks)
    for a, b in zip(arrays, out.chunks):
        assert a is b


@given(st.integers(2, 5), st.integers(1, 4), st.integers(1, 4))
def test_merge_concat_shape(n, rows, cols):
    """N gst (cols x rows) tensors concat on gst dim0 -> cols*N x rows."""
    merge = TensorMerge("m", num_sinks=n, mode="concat:0")
    arrays = [np.random.rand(rows, cols) for _ in range(n)]
    out = merge.combine([Buffer(a, pts=float(i)) for i, a in enumerate(arrays)])
    assert out.data.shape == (rows, cols * n)
    # latest timestamp (paper)
    assert out.pts == float(n - 1)


@given(st.lists(st.sampled_from(
    ["typecast:float32", "add:1.5", "subtract:0.25", "multiply:2.0",
     "divide:4.0"]), min_size=1, max_size=5))
def test_fold_affine_equals_sequential(ops_list):
    chain = ",".join(ops_list)
    ops = parse_chain(chain)
    folded = fold_affine(ops)
    assert folded is not None
    scale, bias, lo, hi, dtype = folded
    x = np.linspace(-8, 8, 33, dtype=np.float32)
    seq = apply_chain_numpy(x, ops)
    fused = np.clip(x * scale + bias, lo, hi)
    np.testing.assert_allclose(seq, fused, rtol=1e-5, atol=1e-5)


@given(st.integers(1, 6), st.integers(1, 6), st.integers(5, 30))
def test_aggregator_window_count(frames_in, flush, n):
    flush = min(flush, frames_in)  # element clamps stride to window size
    from repro.core.elements.aggregator import TensorAggregator
    from repro.core.elements.sinks import TensorSink
    agg = TensorAggregator("a", frames_in=frames_in, frames_flush=flush)
    sink = TensorSink("s", keep=True)
    agg.link(sink)
    for i in range(n):
        agg.chain(agg.sinkpad, Buffer(np.zeros(2), pts=float(i)))
    expected = max((n - frames_in) // flush + 1, 0) if n >= frames_in else 0
    assert sink.n_received == expected
    for b in sink.buffers:
        assert b.data.shape == (2 * frames_in,)


@given(st.integers(1, 12), st.integers(1, 5), dims_st, st.integers(1, 3),
       st.integers(0, 10_000))
def test_batcher_unbatcher_roundtrip(n_frames, max_batch, dims, n_chunks,
                                     seed):
    _batcher_roundtrip(n_frames, max_batch, tuple(dims), n_chunks, seed)


@given(st.integers(2, 16), st.integers(1, 8))
def test_moe_position_in_expert_is_a_valid_ranking(E, k):
    import jax.numpy as jnp
    from repro.models.moe import _position_in_expert
    rng = np.random.default_rng(E * 31 + k)
    flat = rng.integers(0, E, size=(24 * k,))
    pos = np.asarray(_position_in_expert(jnp.asarray(flat), E))
    for e in range(E):
        ranks = sorted(pos[flat == e])
        assert ranks == list(range(len(ranks)))
