"""Continuous batching in ServeEngine: mid-decode admission, eviction
on eos_id, request-order results, and cache-splice integrity.

Uses a deterministic toy model whose generation state lives ONLY in the
KV-cache analogue: prefill stores ``cur = (sum(prompt) % vocab)`` in the
cache and every decode step emits ``cur + 1`` — the fed-back token is
ignored.  Any corruption of an in-flight slot's cache by a mid-decode
join therefore derails that sequence visibly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import ServeEngine

VOCAB = 97


class ToyModel:
    """prefill/decode_step-compatible counter model (cache-driven)."""

    def prefill(self, params, tokens, capacity, extra_embeds=None,
                cache_dtype=jnp.float32):
        base = jnp.sum(tokens, axis=1).astype(jnp.int32) % VOCAB  # (B,)
        first = (base + 1) % VOCAB
        cache = {"cur": first,
                 "kv": jnp.zeros((tokens.shape[0], capacity), cache_dtype)}
        return jax.nn.one_hot(first, VOCAB) * 100.0, cache

    def decode_step(self, params, cache, token, pos):
        nxt = (cache["cur"] + 1) % VOCAB
        logits = jax.nn.one_hot(nxt, VOCAB) * 100.0
        kv = cache["kv"].at[:, pos].set(1.0)
        return logits, {"cur": nxt, "kv": kv}


def _expected(prompt, max_new, eos_id=None):
    base = int(np.sum(prompt)) % VOCAB
    toks = [(base + 1 + k) % VOCAB for k in range(max_new)]
    if eos_id is not None and eos_id in toks:
        toks = toks[: toks.index(eos_id) + 1]
    return toks


def _engine(**kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("capacity", 64)
    kw.setdefault("max_new_tokens", 6)
    return ServeEngine(ToyModel(), params={}, **kw)


def test_serve_returns_results_in_request_order():
    eng = _engine(batch_size=2, max_new_tokens=4)
    prompts = [np.arange(1, n + 2, dtype=np.int32) for n in range(5)]
    res = eng.serve(prompts)
    assert [r.request_id for r in res] == [0, 1, 2, 3, 4]
    for p, r in zip(prompts, res):
        assert list(r.tokens) == _expected(p, 4)
    assert eng.n_evictions == 5
    assert eng.n_prefills >= 2  # more than one wave for 5 reqs on 2 slots


def test_eviction_on_eos_id():
    # prompt sums to eos_id - 2 -> generates eos after 2 tokens
    eos = 10
    prompt = np.asarray([3, 5], np.int32)          # base 8 -> 9, 10(eos)
    long_prompt = np.asarray([20, 21], np.int32)   # base 41 -> never hits 10
    eng = _engine(batch_size=2, max_new_tokens=6, eos_id=eos)
    res = eng.serve([prompt, long_prompt])
    assert list(res[0].tokens) == [9, 10]          # stopped at eos, not max_new
    assert len(res[1].tokens) == 6                 # ran to max_new
    assert eng.n_evictions == 2


def test_late_request_joins_mid_decode():
    eos = 7
    eng = _engine(batch_size=2, max_new_tokens=8, eos_id=eos)
    a = np.asarray([2, 3], np.int32)      # base 5 -> 6, 7(eos): frees its slot
    b = np.asarray([30, 31], np.int32)    # base 61: runs all 8 steps
    eng.submit(a)
    eng.submit(b)
    finished = []
    for _ in range(3):                    # a finishes within 3 steps
        finished += eng.step()
    assert any(r.request_id == 0 for r in finished)
    assert eng.n_active == 1              # b still decoding, one slot free
    late = np.asarray([4, 4], np.int32)   # short prompt: fits current pos
    eng.submit(late)
    while eng.has_work:
        finished += eng.step()
    assert eng.n_joins == 1               # late request joined mid-decode
    by_id = {r.request_id: list(r.tokens) for r in finished}
    assert by_id[0] == [6, 7]
    assert by_id[1] == _expected(b, 8, eos)
    assert by_id[2] == _expected(late, 8, eos)  # joined slot decodes correctly


def test_join_does_not_corrupt_inflight_sequence():
    """The cache splice must leave other slots' state untouched."""
    eng = _engine(batch_size=2, max_new_tokens=10, eos_id=3)
    a = np.asarray([1, 1], np.int32)      # base 2 -> 3(eos) immediately
    b = np.asarray([50, 0, 0, 0], np.int32)  # base 50, long prompt, no eos
    eng.submit(b)
    eng.submit(a)
    results = []
    while eng.has_work:
        results += eng.step()
        if eng.n_active == 1 and eng._next_rid == 2:
            eng.submit(np.asarray([5], np.int32))  # join while b in flight
    by_id = {r.request_id: list(r.tokens) for r in results}
    assert eng.n_joins == 1
    # b's generation is the uninterrupted counter sequence despite the join
    assert by_id[0] == _expected(b, 10, 3)
    assert by_id[2] == _expected(np.asarray([5]), 10, 3)


def test_long_prompt_defers_until_position_catches_up():
    eng = _engine(batch_size=2, max_new_tokens=12)
    short = np.asarray([1, 1], np.int32)
    eng.submit(short)
    results = eng.step()                   # prefill wave: pos = 2
    long = np.arange(1, 7, dtype=np.int32)  # len 6 > pos: must wait
    eng.submit(long)
    while eng.has_work:
        results += eng.step()
    by_id = {r.request_id: list(r.tokens) for r in results}
    assert by_id[1] == _expected(long, 12)
    assert len(by_id) == 2


def test_submit_rejects_prompt_longer_than_capacity():
    eng = _engine(capacity=8)
    with pytest.raises(ValueError, match="capacity"):
        eng.submit(np.arange(1, 11, dtype=np.int32))  # len 10 > capacity 8


def test_pipeline_filter_adapter_row_order():
    eng = _engine(batch_size=2, max_new_tokens=3)
    fn = eng.as_pipeline_filter()
    prompts = np.stack([np.asarray([i + 1, i + 2], np.int32) for i in range(4)])
    out = fn(prompts)
    assert out.shape == (4, 3)
    for i in range(4):
        assert list(out[i]) == _expected(prompts[i], 3)
