"""Substrate tests: optimizer, schedule, data, checkpoint, trainer, engine."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import TokenStream, lm_batch_specs
from repro.models import build_model
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.serving import ServeEngine
from repro.training import Trainer


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = adamw_update(params, grads, state, lr=0.1,
                                     weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_adamw_grad_clip():
    params = {"w": jnp.array([1.0])}
    state = adamw_init(params)
    huge = {"w": jnp.array([1e9])}
    new, _ = adamw_update(params, huge, state, lr=0.1, grad_clip=1.0)
    assert float(jnp.abs(new["w"] - params["w"])[0]) < 1.0


def test_cosine_schedule_shape():
    assert float(cosine_schedule(jnp.int32(0), peak_lr=1.0, warmup=10,
                                 total=100)) == 0.0
    assert float(cosine_schedule(jnp.int32(10), peak_lr=1.0, warmup=10,
                                 total=100)) == 1.0
    end = float(cosine_schedule(jnp.int32(100), peak_lr=1.0, warmup=10,
                                total=100, floor=0.1))
    assert abs(end - 0.1) < 1e-5


def test_token_stream_deterministic_and_in_range():
    a = next(iter(TokenStream(100, 32, 4, seed=1)))
    b = next(iter(TokenStream(100, 32, 4, seed=1)))
    assert np.array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 100
    assert a["tokens"].shape == (4, 32)
    specs = lm_batch_specs(4, 32)
    assert specs["tokens"].shape == (4, 32)


def test_checkpoint_roundtrip_and_validation():
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((3, 3), jnp.float32)}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, tree)
        assert latest_step(d) == 7
        back = restore_checkpoint(d, 7, tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        import pytest
        bad = {"a": jnp.arange(6.0), "b": tree["b"]}
        with pytest.raises(ValueError):
            restore_checkpoint(d, 7, bad)


def test_trainer_loss_decreases():
    cfg = get_config("smollm-360m", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg)
    tr = Trainer(model, peak_lr=1e-3, warmup=3, total_steps=30)
    hist = tr.fit(TokenStream(cfg.vocab_size, 32, 4, seed=0), steps=15,
                  log_fn=None)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_serve_engine_batched_requests():
    cfg = get_config("smollm-360m", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=2, capacity=48,
                      max_new_tokens=6)
    reqs = [np.arange(5, dtype=np.int32), np.arange(9, dtype=np.int32),
            np.arange(3, dtype=np.int32)]
    res = eng.serve(reqs)
    assert len(res) == 3
    for r in res:
        assert r.tokens.shape == (6,)
        assert r.tokens.min() >= 0 and r.tokens.max() < cfg.vocab_size
    # greedy decode is deterministic
    res2 = eng.serve(reqs)
    assert np.array_equal(res[0].tokens, res2[0].tokens)
