"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses the smollm-360m family at a ~100M reduced size (CPU-feasible), the
synthetic token stream (zipf + copy structure, so loss genuinely falls),
AdamW + cosine schedule, and checkpointing.
"""
import argparse

import jax

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data import TokenStream
from repro.models import build_model
from repro.training import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # ~100M params: smollm family, 12 layers, d_model 768
    cfg = get_config("smollm-360m").replace(
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32768, max_seq=args.seq,
        param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg)
    n = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"model: {n/1e6:.1f}M params")

    trainer = Trainer(model, peak_lr=6e-4, warmup=30, total_steps=args.steps)
    stream = TokenStream(cfg.vocab_size, args.seq, args.batch, seed=0)
    hist = trainer.fit(stream, steps=args.steps, log_every=20)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    path = save_checkpoint(args.ckpt_dir, args.steps, trainer.state.params)
    print(f"checkpoint saved: {path}")


if __name__ == "__main__":
    main()
