"""Serve a small LM with continuously-batched requests THROUGH a stream
pipeline — the paper's thesis end-to-end: the serving engine is just
another Tensor-Filter.

Requests stream into a ``tensor_batcher`` (flushes on a full batch OR
after ``max_wait_ms`` — light traffic still gets bounded latency), the
continuous-batching ServeEngine runs as a ``tensor_filter`` with a
padded-bucket cache, and ``tensor_unbatcher`` restores one buffer per
request with its original pts/meta.

    PYTHONPATH=src python examples/serve_pipeline.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import parse_pipeline
from repro.models import build_model
from repro.serving import ServeEngine

cfg = get_config("smollm-360m", smoke=True).replace(
    param_dtype="float32", compute_dtype="float32")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
BATCH = 4
engine = ServeEngine(model, params, batch_size=BATCH, capacity=96,
                     max_new_tokens=12)

# request stream -> micro-batcher -> engine filter -> unbatch -> sink
pipe = parse_pipeline(
    "appsrc name=req ! tensor_batcher max_batch=%d max_wait_ms=200 ! "
    "queue max_size=4 ! tensor_filter name=llm framework=python model=llm "
    "max_batch=%d ! tensor_unbatcher ! tensor_sink name=out keep=true"
    % (BATCH, BATCH),
    models={"llm": engine.as_pipeline_filter()})
pipe.start()

rng = np.random.default_rng(0)
N_REQ = 13  # deliberately not a multiple of BATCH: EOS flushes the tail
            # (max_wait_ms covers the no-EOS case: a trickle of requests
            # still gets served within 200ms instead of waiting for a
            # full batch)
t0 = time.perf_counter()
for i in range(N_REQ):
    prompt = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    pipe["req"].push(prompt, meta={"request": i})
pipe["req"].end_of_stream()
pipe["out"].eos_seen.wait(timeout=300)
wall = time.perf_counter() - t0
pipe.stop()

out = pipe["out"]
gens = [np.asarray(b.data) for b in out.buffers]
total = sum(g.size for g in gens)
llm = pipe["llm"]
print(f"served {out.n_received} requests -> {total} tokens "
      f"in {wall:.2f}s ({total/wall:.1f} tok/s)")
print(f"scheduler: prefills={engine.n_prefills} joins={engine.n_joins} "
      f"evictions={engine.n_evictions}")
print(f"filter buckets: { {b: s[0] for b, s in llm.bucket_stats.items()} } "
      f"({llm.n_bucket_compilations} distinct padded shapes)")
print("request order preserved:",
      [b.meta.get("request") for b in out.buffers] == list(range(N_REQ)))
print("sample generation:", gens[0] if gens else "none")
