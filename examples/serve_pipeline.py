"""Serve a small LM with batched requests THROUGH a stream pipeline —
the paper's thesis end-to-end: the serving engine is just another
Tensor-Filter.

    PYTHONPATH=src python examples/serve_pipeline.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import parse_pipeline
from repro.models import build_model
from repro.serving import ServeEngine

cfg = get_config("smollm-360m", smoke=True).replace(
    param_dtype="float32", compute_dtype="float32")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
BATCH = 4
engine = ServeEngine(model, params, batch_size=BATCH, capacity=96,
                     max_new_tokens=12)

# request stream -> aggregator batches them -> engine filter -> sink
rng = np.random.default_rng(0)


def llm_filter(prompts):
    """prompts: (BATCH, S) int32 -> generated (BATCH, max_new)."""
    return engine.generate_batch(np.asarray(prompts, np.int32))


pipe = parse_pipeline(
    "appsrc name=req ! tensor_aggregator frames_in=%d stack=true ! "
    "queue max_size=4 ! tensor_filter framework=python model=llm ! "
    "tensor_sink name=out keep=true" % BATCH,
    models={"llm": llm_filter})
pipe.start()

N_REQ = 12
t0 = time.perf_counter()
for i in range(N_REQ):
    prompt = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    pipe["req"].push(prompt)
pipe["req"].end_of_stream()
deadline = time.monotonic() + 120
out = pipe["out"]
while out.n_received < N_REQ // BATCH and time.monotonic() < deadline:
    time.sleep(0.05)
wall = time.perf_counter() - t0
pipe.stop()

gens = [np.asarray(b.data) for b in out.buffers]
total = sum(g.size for g in gens)
print(f"served {N_REQ} requests ({len(gens)} batches) -> {total} tokens "
      f"in {wall:.2f}s ({total/wall:.1f} tok/s)")
print("sample generation:", gens[0][0] if gens else "none")
