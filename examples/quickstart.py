"""Quickstart: NNStreamer-style pipelines in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import parse_pipeline
from repro.single import SingleShot

# 1. a textual pipeline, gst-launch style: synthetic camera -> normalize
#    -> neural network (reduced smollm config as an LM "filter" over pixel
#    tokens is silly; use the classic classifier demo instead)
def tiny_classifier(frame):
    # any callable is a filter backend ("custom python sub-plugin")
    return np.asarray(frame, np.float32).mean(axis=(0, 1))  # per-channel

pipe = parse_pipeline(
    "videotestsrc num_buffers=16 width=64 height=64 ! "
    "tensor_converter to_float=true ! "
    "tensor_transform option=multiply:2.0,subtract:1.0 ! "
    "tensor_filter framework=python model=clf ! "
    "tensor_decoder mode=argmax_label ! tensor_sink name=out keep=true",
    models={"clf": tiny_classifier})
pipe.run_until_eos(timeout=30)
out = pipe["out"]
print(f"pipeline processed {out.n_received} frames")
print(f"first result: label={out.buffers[0].meta['label']} "
      f"(chunk={np.asarray(out.buffers[0].data)})")

# 2. the Single API — one model, no pipeline (paper's Tizen/Android API)
single = SingleShot(fn=tiny_classifier)
print("single-shot:", single.invoke(np.ones((4, 4, 3), np.uint8)))

# 3. branching + value-based flow control, still one textual description
pipe2 = parse_pipeline(
    "sensorsrc num_buffers=32 channels=4 ! tee name=t num_src_pads=2 "
    "t.src_0 ! queue ! tensor_aggregator frames_in=4 ! fakesink name=agg "
    "t.src_1 ! queue ! tensor_if name=gate reduction=max compare=gt value=0.8 "
    "gate.src_true ! fakesink name=hot gate.src_false ! fakesink name=cold")
pipe2.run_until_eos(timeout=30)
print(f"aggregated windows: {pipe2['agg'].n_received}, "
      f"hot: {pipe2['hot'].n_received}, cold: {pipe2['cold'].n_received}")
