"""E3's complex topology as a runnable example: MTCNN-style cascade
with NMS / BBR / image-patch custom filters and an overlay decoder.

    PYTHONPATH=src python examples/mtcnn_cascade.py
"""
import sys

sys.path.insert(0, ".")  # for benchmarks.* helpers when run from repo root

import jax
import numpy as np

from benchmarks.e3_mtcnn import _build_fns
from repro.core import parse_pipeline
from repro.core.elements.sources import VideoTestSrc

stages = _build_fns(jax.random.PRNGKey(3))
pnet_stage, rnet_stage, onet_stage = stages


def pnet_f(frame):
    return frame, pnet_stage(np.asarray(frame))


def rnet_f(frame, boxes):
    return frame, rnet_stage(np.asarray(frame), np.asarray(boxes))


def onet_f(frame, boxes):
    return onet_stage(np.asarray(frame), np.asarray(boxes))


pipe = parse_pipeline(
    "appsrc name=src ! queue ! "
    "tensor_filter framework=python model=pnet ! queue ! "
    "tensor_filter framework=python model=rnet ! queue ! "
    "tensor_filter framework=python model=onet ! "
    "tensor_decoder mode=bounding_boxes ! tensor_sink name=out keep=true",
    models={"pnet": pnet_f, "rnet": rnet_f, "onet": onet_f})
pipe.start()

src = VideoTestSrc("gen", width=160, height=160)
for i in range(12):
    pipe["src"].push(src.create(i).data)
pipe["src"].end_of_stream()
pipe["out"].eos_seen.wait(timeout=120)
pipe.stop()

out = pipe["out"]
print(f"processed {out.n_received} frames through the 3-stage cascade")
for b in out.buffers[:3]:
    print(f"  boxes: {b.meta['boxes']}")
