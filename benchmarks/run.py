"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only e1,e4] [--json PATH]

Prints ``name,us_per_call,derived`` CSV rows and writes the same rows
as machine-readable JSON (default ``BENCH_serving.json``) so the perf
trajectory — steady-state decode tokens/s, host syncs per token,
batching/join/prefix-sharing wins — is tracked commit-over-commit.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: e1,e2,e3,e4,e5,e6,e7,e8,e9,"
                         "e10_quant,e11_chaos,roofline")
    ap.add_argument("--json", default=None,
                    help="write rows as machine-readable JSON here "
                         "(default: BENCH_serving.json on full runs; "
                         "--only runs skip the file unless one is given, "
                         "so a filtered run never clobbers the tracked "
                         "full report; '' disables)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    json_path = args.json if args.json is not None \
        else ("" if only else "BENCH_serving.json")

    from . import (e1_multimodel, e2_ars, e3_mtcnn, e4_overhead, e5_batching,
                   e6_decode_loop, e7_frontdoor, e8_sharded, e9_speculative,
                   e10_quant, e11_chaos, roofline)
    sections = [("e1", e1_multimodel), ("e2", e2_ars), ("e3", e3_mtcnn),
                ("e4", e4_overhead), ("e5", e5_batching),
                ("e6", e6_decode_loop), ("e7", e7_frontdoor),
                ("e8", e8_sharded), ("e9", e9_speculative),
                ("e10_quant", e10_quant), ("e11_chaos", e11_chaos),
                ("roofline", roofline)]
    print("name,us_per_call,derived")
    failed = False
    report = {"sections": {}, "rows": []}
    def emit(name, row):
        print(row, flush=True)
        bench, us, derived = row.split(",", 2)
        try:
            us_f = float(us)
        except ValueError:
            us_f = None
        report["rows"].append({"name": bench, "us_per_call": us_f,
                               "derived": derived, "section": name})

    for name, mod in sections:
        if only and name not in only:
            continue
        # stream rows as the section produces them: a mid-run failure
        # keeps everything measured up to that point (stdout AND json)
        try:
            for row in mod.run():
                emit(name, row)
            report["sections"][name] = "ok"
        except Exception:  # noqa: BLE001
            failed = True
            emit(name, f"{name}_ERROR,0.0,{traceback.format_exc(limit=3)!r}")
            report["sections"][name] = "error"
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote {json_path} ({len(report['rows'])} rows)",
              file=sys.stderr)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
