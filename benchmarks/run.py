"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only e1,e4]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: e1,e2,e3,e4,e5,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (e1_multimodel, e2_ars, e3_mtcnn, e4_overhead, e5_batching,
                   roofline)
    sections = [("e1", e1_multimodel), ("e2", e2_ars), ("e3", e3_mtcnn),
                ("e4", e4_overhead), ("e5", e5_batching),
                ("roofline", roofline)]
    print("name,us_per_call,derived")
    failed = False
    for name, mod in sections:
        if only and name not in only:
            continue
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception:  # noqa: BLE001
            failed = True
            print(f"{name}_ERROR,0.0,{traceback.format_exc(limit=3)!r}",
                  flush=True)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
