"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms (seconds, per chip — all dry-run numbers are per-device):
  compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw              (819 GB/s)
  collective = collective_bytes / link_bw      (~50 GB/s ICI)

plus MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (prefill/decode)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.

Known measurement caveats (documented):
  * HLO numbers come from 1-/2-period *unrolled* compiles extrapolated
    linearly (XLA cost analysis visits while bodies once).
  * per-time-step scans inside SSM/xLSTM chunk bodies are still while
    loops; an analytic correction adds the missing (ct-1)/ct step work.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)


def _active_params(cfg) -> float:
    """Analytic active-parameter count (per token), excluding the
    embedding gather table but including the LM head matmul."""
    import jax
    from repro.models import build_model
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = sum(x.size for x in jax.tree.leaves(shapes))
    total -= cfg.vocab_size * cfg.d_model          # embed gather table
    if cfg.tie_embeddings:
        total += cfg.vocab_size * cfg.d_model      # reused as head matmul
    if cfg.moe is not None:
        m = cfg.moe
        per_expert = 3 * cfg.d_model * m.d_expert
        n_moe_layers = sum(cfg.is_moe_layer(i) for i in range(cfg.n_layers))
        total -= n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return float(total)


def _scan_correction_flops(cfg, shape, n_dev: int) -> float:
    """Per-device flops for per-step scans XLA counts once per chunk."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return 0.0  # decode has no inner time scans
    corr = 0.0
    if cfg.family in ("hybrid",) and cfg.ssm is not None:
        di, N = cfg.d_inner, cfg.ssm.d_state
        n_mamba = sum(not cfg.is_attn_layer(i) for i in range(cfg.n_layers))
        corr += n_mamba * B * S * di * N * 8.0
    if cfg.family == "ssm":
        di = 2 * cfg.d_model
        H = cfg.n_heads
        dh = di // H
        every = cfg.ssm.slstm_every or 4
        n_m = cfg.n_layers - cfg.n_layers // every
        n_s = cfg.n_layers // every
        corr += n_m * B * S * H * dh * dh * 6.0          # mlstm C update+read
        corr += n_s * B * S * (2 * H * dh * 4 * dh)      # slstm R matmul
    mult = 3.0 if shape.kind == "train" else 1.0         # fwd+bwd
    return corr * mult / n_dev


def load_records(dry_dir: str = "experiments/dryrun") -> List[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dry_dir, "*__pod1.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def analyse(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    from repro.configs.shapes import SHAPES
    from repro.launch.specs import resolve_config
    cfg = resolve_config(rec["arch"], rec["shape"])
    shape = SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]

    flops = rec.get("flops", 0.0) + _scan_correction_flops(cfg, shape, n_dev)
    bytes_ = rec.get("bytes_accessed", 0.0)
    coll = sum(v["bytes"] for v in rec.get("collectives", {}).values())

    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_ / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    n_active = _active_params(cfg)
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * D
    elif shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * D
    else:
        model_flops = 2.0 * n_active * shape.global_batch
    model_flops_dev = model_flops / n_dev
    ratio = model_flops_dev / flops if flops else 0.0

    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "extrapolated": bool(rec.get("extrapolated")),
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_dev": model_flops_dev, "hlo_flops_dev": flops,
        "useful_ratio": ratio,
        "hbm_args_gib": rec.get("argument_size_in_bytes", 0) / 2**30,
        "hbm_temp_gib": rec.get("temp_size_in_bytes", 0) / 2**30,
        "coll_detail": rec.get("collectives", {}),
    }


def advice(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.4:
            return ("compute-bound with low useful ratio: cut remat recompute "
                    "or replicated attention (head-count vs TP mismatch)")
        return "compute-bound near model flops: scale chips or quantize"
    if d == "memory":
        return ("memory-bound: fuse elementwise chains / raise arithmetic "
                "intensity (bigger blocks, bf16 accumulators, flash kernels)")
    return ("collective-bound: re-shard to cut all-gathers (e.g. keep "
            "activations sharded through residual), overlap collectives "
            "with compute, or shrink the TP degree")


def table(dry_dir: str = "experiments/dryrun") -> List[str]:
    rows = []
    out = ["arch,shape,t_compute_s,t_memory_s,t_collective_s,dominant,"
           "model_flops/hlo_flops,hbm_args_GiB,hbm_temp_GiB,cost_basis"]
    for rec in load_records(dry_dir):
        r = analyse(rec)
        if r is None:
            continue
        rows.append(r)
        basis = "extrapolated" if r["extrapolated"] else "raw(scan-undercount)"
        out.append(
            f"{r['arch']},{r['shape']},{r['t_compute_s']:.4f},"
            f"{r['t_memory_s']:.4f},{r['t_collective_s']:.4f},{r['dominant']},"
            f"{r['useful_ratio']:.3f},{r['hbm_args_gib']:.2f},"
            f"{r['hbm_temp_gib']:.2f},{basis}")
    return out


def run() -> List[str]:
    lines = table()
    return [f"roofline_{i},0.0,{l}" for i, l in enumerate(lines[1:], 1)] \
        or ["roofline_none,0.0,no dry-run records found"]


if __name__ == "__main__":
    print("\n".join(table()))
