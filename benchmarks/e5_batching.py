"""E5 — micro-batching: throughput vs batch size + bucket-cache
recompile accounting.

Analogs on this host:
  * throughput vs batch: the same per-frame model driven through
    appsrc -> tensor_batcher(max_batch=k) -> tensor_filter ->
    tensor_unbatcher -> fakesink at k in {1,2,4,8}.  Per-invocation
    overhead (python dispatch, BLAS call setup, pipeline pads) is
    amortized across the batch — the paper's pipelined-filter
    amortization argument extended across stream frames.
  * bucket cache: a jitted filter fed every batch size 1..8 must
    compile at most log2(max_batch)+1 = 4 variants (one per power-of-2
    bucket), not 8.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import parse_pipeline
from repro.core.elements.filter import TensorFilter

D = 256      # weight-bound at small n: a (n,D)@(D,D) GEMM costs nearly the
LAYERS = 8   # same for n=1 and n=8, so batching amortizes the weight fetch
N_FRAMES = 512


def _make_mlp():
    rng = np.random.default_rng(7)
    ws = [rng.standard_normal((D, D)).astype(np.float32) * 0.05
          for _ in range(LAYERS)]

    def mlp(x):
        for w in ws:
            x = np.maximum(x @ w, 0.0)
        return x
    return mlp


def _throughput(batch: int, mlp) -> float:
    pipe = parse_pipeline(
        "appsrc name=src ! tensor_batcher max_batch=%d ! "
        "tensor_filter framework=python model=mlp max_batch=%d ! "
        "tensor_unbatcher ! fakesink name=out" % (batch, batch),
        models={"mlp": mlp})
    pipe.start()
    frame = np.ones((D,), np.float32)
    t0 = time.perf_counter()
    for _ in range(N_FRAMES):
        pipe["src"].push(frame)
    pipe["src"].end_of_stream()
    assert pipe["out"].eos_seen.wait(timeout=120)
    wall = time.perf_counter() - t0
    assert pipe["out"].n_received == N_FRAMES
    pipe.stop()
    return N_FRAMES / wall


def bench_throughput_vs_batch() -> List[str]:
    mlp = _make_mlp()
    mlp(np.ones((8, D), np.float32))  # warm BLAS
    rows = []
    rates = {}
    for batch in (1, 2, 4, 8):
        fps = _throughput(batch, mlp)
        rates[batch] = fps
        rows.append(f"e5_batch{batch},{1e6 / fps:.1f},fps={fps:.0f}"
                    f";speedup_vs_b1=x{fps / rates[1]:.2f}")
    speedup = rates[8] / rates[1]
    assert speedup >= 2.0, f"batch-8 speedup only x{speedup:.2f}"
    return rows


def bench_bucket_recompiles() -> List[str]:
    import jax.numpy as jnp

    def jmlp(x):
        for _ in range(4):
            x = jnp.maximum(x @ jnp.eye(D, dtype=jnp.float32), 0.0)
        return x

    filt = TensorFilter("bucketed", fn=jmlp, framework="jax", max_batch=8)
    rng = np.random.default_rng(3)
    sizes = [int(rng.integers(1, 9)) for _ in range(64)]
    for n in sorted(set(sizes)) + sizes:  # every size appears at least once
        filt.invoke_batched([np.ones((n, D), np.float32)], n)
    n_buckets = filt.n_bucket_compilations
    assert n_buckets <= 4, f"{n_buckets} buckets for max_batch=8"
    per_bucket = ";".join(
        f"b{b}:n={int(s[1])}:{1e3 * s[2] / s[0]:.2f}ms"
        for b, s in sorted(filt.bucket_stats.items()))
    return [f"e5_bucket_cache,{n_buckets}.0,"
            f"compilations_for_sizes_1..8 (max log2(8)+1=4);{per_bucket}"]


def _bench_join_positions(cfg, prefix: str, dense_note: str,
                          paged_note: str) -> List[str]:
    """Shared protocol for the join-latency benches: dense join cost
    (one prefill at the batch position) vs paged join cost (fixed
    ``prefill_chunk``-token steps batched with ongoing decode), measured
    at increasing batch positions.  Both sides are measured on warmed
    jit calls (compile excluded); the paged call also carries one decode
    step for the in-flight slot, so the comparison is conservative.
    Asserts the paged side wins at the largest position and stays flat
    in position.
    """
    import jax
    import jax.numpy as jnp
    from repro.models import build_model
    from repro.serving import ServeEngine

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    recurrent = getattr(model, "has_recurrent_state", lambda: False)()
    positions = (64, 128, 256)
    cap, chunk, join_len, reps = 320, 8, 8, 5

    def med(fn):
        fn()                                   # warm (compile) then measure
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times.append(time.perf_counter() - t0)
        return sorted(times)[reps // 2] * 1e3  # ms

    rows = []
    dense_ms, paged_ms = {}, {}
    eng_d = ServeEngine(model, params, batch_size=2, capacity=cap,
                        max_new_tokens=8, paged=False)
    for p in positions:
        batch = jnp.zeros((2, p), jnp.int32)
        dense_ms[p] = med(lambda: eng_d._prefill(params, batch, None))
        rows.append(f"{prefix}_dense_p{p},{dense_ms[p] * 1e3:.1f},"
                    f"join={dense_note}_{p};{dense_ms[p]:.2f}ms")

    eng_p = ServeEngine(model, params, batch_size=2, capacity=cap,
                        max_new_tokens=8, block_size=16, prefill_chunk=chunk)
    assert eng_p.paged
    assert (eng_p.state_store is not None) == recurrent
    P = eng_p._pages_per_slot
    # jit WITHOUT donation: the engine's donating _paged_fn would eat the
    # cache buffer on the warm-up call; here the same cache is re-fed
    paged_fn = jax.jit(model.paged_step)
    kw = {"num_state_slots": 2} if recurrent else {}
    cache = model.init_paged_cache(eng_p.allocator.num_blocks,
                                   eng_p.block_size, dtype=jnp.float32, **kw)
    pt = jnp.asarray(np.arange(2 * P, dtype=np.int32).reshape(2, P))
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(1, cfg.vocab_size,
                                          (2, chunk)).astype(np.int32))
    t_valid = jnp.asarray([1, chunk], jnp.int32)  # decode + prefill chunk
    slots = jnp.asarray([0, 1], jnp.int32)
    for p in positions:
        lengths = jnp.asarray([p, 0], jnp.int32)
        n_chunks = -(-join_len // chunk)
        ms = med(lambda: paged_fn(params, cache, tokens, pt,
                                  lengths, t_valid, slots)[0]) * n_chunks
        paged_ms[p] = ms
        rows.append(f"{prefix}_paged_p{p},{ms * 1e3:.1f},"
                    f"join={n_chunks}x{chunk}tok_chunks{paged_note}"
                    f";{ms:.2f}ms")

    pmax, pmin = positions[-1], positions[0]
    flat = paged_ms[pmax] / paged_ms[pmin]
    gain = dense_ms[pmax] / paged_ms[pmax]
    rows.append(f"{prefix}_summary,{gain:.2f},"
                f"dense/paged_at_pos{pmax}=x{gain:.2f};"
                f"paged_pos_spread=x{flat:.2f}")
    assert gain > 1.5, f"paged join only x{gain:.2f} faster at pos {pmax}"
    assert flat < 2.5, f"paged join cost grew x{flat:.2f} with position"
    return rows


def bench_join_latency() -> List[str]:
    """Mid-decode join cost, dense vs paged KV cache.

    Dense continuous batching admits a joiner with one prefill at the
    batch's *current position* — cost (and a fresh jit shape) grows with
    how long the batch has been decoding.  The paged engine consumes the
    joiner's prompt in fixed ``prefill_chunk``-token steps batched with
    ongoing decode, so join cost is independent of the batch position.
    """
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        arch_id="e5-tiny", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
        norm="rmsnorm", mlp_act="swiglu", rope="rope",
        param_dtype="float32", compute_dtype="float32")
    return _bench_join_positions(cfg, "e5_join", "prefill_at_pos", "")


def bench_prefix_share() -> List[str]:
    """Prefix sharing: the memory + join-latency win for a shared
    system prompt.

    N requests carry one long common prefix (the fleet-scale "same
    system prompt" case) plus short unique suffixes.  With sharing on,
    joiners map the resident prefix blocks (refcount bump) instead of
    re-prefilling them, so peak pool occupancy drops and a join only
    has to prefill its suffix — time-to-first-token for the late
    requests shrinks with the prefix length.  Timings are reported;
    the asserts are structural (chunk calls, peak blocks), which is
    what the sharing path guarantees deterministically.
    """
    import jax
    from repro.models import build_model
    from repro.models.config import ModelConfig
    from repro.serving import ServeEngine

    cfg = ModelConfig(
        arch_id="e5-tiny-share", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
        norm="rmsnorm", mlp_act="swiglu", rope="rope",
        param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prefix_len, suffix_len, n_req, bs, chunk = 96, 8, 4, 8, 8
    rng = np.random.default_rng(5)
    prefix = rng.integers(1, cfg.vocab_size, prefix_len).astype(np.int32)
    prompts = [np.concatenate(
        [prefix, rng.integers(1, cfg.vocab_size, suffix_len).astype(np.int32)])
        for _ in range(n_req)]

    def serve(share):
        eng = ServeEngine(model, params, batch_size=n_req, capacity=160,
                          max_new_tokens=8, block_size=bs,
                          prefill_chunk=chunk, share_prefix=share)
        assert eng.paged
        eng.submit(prompts[0])
        while eng.n_prefills < 1:      # resident prefix, pages registered
            eng.step()
        t0 = time.perf_counter()
        for p in prompts[1:]:
            eng.submit(p)
        peak = eng.allocator.n_live
        while eng.n_prefills < n_req:  # every joiner reached first token
            eng.step()
            peak = max(peak, eng.allocator.n_live)
        t_join = time.perf_counter() - t0
        while eng.has_work:
            eng.step()
            peak = max(peak, eng.allocator.n_live)
        return eng, t_join, peak

    serve(True)                        # warm both jit shape buckets
    eng_off, t_off, peak_off = serve(False)
    eng_on, t_on, peak_on = serve(True)
    assert eng_on.n_shared_tokens == (n_req - 1) * prefix_len
    assert peak_on < peak_off, (peak_on, peak_off)
    assert eng_on.n_prefill_chunks < eng_off.n_prefill_chunks
    return [
        f"e5_prefix_share_mem,{peak_off - peak_on}.0,"
        f"peak_live_blocks={peak_on}_vs_{peak_off}"
        f";prefix={prefix_len}tok_x{n_req}req",
        f"e5_prefix_share_join,{t_on * 1e3:.1f},"
        f"join_ttft={t_on * 1e3:.1f}ms_vs_{t_off * 1e3:.1f}ms"
        f";prefill_chunks={eng_on.n_prefill_chunks}_vs_"
        f"{eng_off.n_prefill_chunks}"
        f";shared_tokens={eng_on.n_shared_tokens}"
        f";cow_forks={eng_on.n_cow_forks}",
    ]


def bench_recurrent_join() -> List[str]:
    """Mid-decode join cost for a *recurrent* (mamba) stack through the
    paged engine's state slabs.

    Before per-slot recurrent state, mamba/xlstm families fell back to
    the dense engine, where admitting a joiner costs one prefill at the
    batch's current position — for a recurrence that means re-scanning
    `position` tokens, so join cost grows linearly with how long the
    batch has been decoding.  The paged engine consumes the joiner's
    prompt in fixed ``prefill_chunk``-token steps that carry the slot's
    state slab forward, batched with ongoing decode — join cost is
    position-independent.
    """
    from repro.models.config import ModelConfig, SSMConfig

    cfg = ModelConfig(
        arch_id="e5-tiny-mamba", family="hybrid", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
        norm="rmsnorm", mlp_act="swiglu", rope="rope",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        attn_layer_period=1, attn_layer_offset=1,   # pure-mamba stack
        param_dtype="float32", compute_dtype="float32")
    return _bench_join_positions(cfg, "e5_rjoin", "recurrence_rescan_at_pos",
                                 "_state_slab")


def run() -> List[str]:
    rows = []
    rows += bench_throughput_vs_batch()
    rows += bench_bucket_recompiles()
    rows += bench_join_latency()
    rows += bench_prefix_share()
    rows += bench_recurrent_join()
    return rows
