"""E5 — micro-batching: throughput vs batch size + bucket-cache
recompile accounting.

Analogs on this host:
  * throughput vs batch: the same per-frame model driven through
    appsrc -> tensor_batcher(max_batch=k) -> tensor_filter ->
    tensor_unbatcher -> fakesink at k in {1,2,4,8}.  Per-invocation
    overhead (python dispatch, BLAS call setup, pipeline pads) is
    amortized across the batch — the paper's pipelined-filter
    amortization argument extended across stream frames.
  * bucket cache: a jitted filter fed every batch size 1..8 must
    compile at most log2(max_batch)+1 = 4 variants (one per power-of-2
    bucket), not 8.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import parse_pipeline
from repro.core.elements.filter import TensorFilter

D = 256      # weight-bound at small n: a (n,D)@(D,D) GEMM costs nearly the
LAYERS = 8   # same for n=1 and n=8, so batching amortizes the weight fetch
N_FRAMES = 512


def _make_mlp():
    rng = np.random.default_rng(7)
    ws = [rng.standard_normal((D, D)).astype(np.float32) * 0.05
          for _ in range(LAYERS)]

    def mlp(x):
        for w in ws:
            x = np.maximum(x @ w, 0.0)
        return x
    return mlp


def _throughput(batch: int, mlp) -> float:
    pipe = parse_pipeline(
        "appsrc name=src ! tensor_batcher max_batch=%d ! "
        "tensor_filter framework=python model=mlp max_batch=%d ! "
        "tensor_unbatcher ! fakesink name=out" % (batch, batch),
        models={"mlp": mlp})
    pipe.start()
    frame = np.ones((D,), np.float32)
    t0 = time.perf_counter()
    for _ in range(N_FRAMES):
        pipe["src"].push(frame)
    pipe["src"].end_of_stream()
    assert pipe["out"].eos_seen.wait(timeout=120)
    wall = time.perf_counter() - t0
    assert pipe["out"].n_received == N_FRAMES
    pipe.stop()
    return N_FRAMES / wall


def bench_throughput_vs_batch() -> List[str]:
    mlp = _make_mlp()
    mlp(np.ones((8, D), np.float32))  # warm BLAS
    rows = []
    rates = {}
    for batch in (1, 2, 4, 8):
        fps = _throughput(batch, mlp)
        rates[batch] = fps
        rows.append(f"e5_batch{batch},{1e6 / fps:.1f},fps={fps:.0f}"
                    f";speedup_vs_b1=x{fps / rates[1]:.2f}")
    speedup = rates[8] / rates[1]
    assert speedup >= 2.0, f"batch-8 speedup only x{speedup:.2f}"
    return rows


def bench_bucket_recompiles() -> List[str]:
    import jax.numpy as jnp

    def jmlp(x):
        for _ in range(4):
            x = jnp.maximum(x @ jnp.eye(D, dtype=jnp.float32), 0.0)
        return x

    filt = TensorFilter("bucketed", fn=jmlp, framework="jax", max_batch=8)
    rng = np.random.default_rng(3)
    sizes = [int(rng.integers(1, 9)) for _ in range(64)]
    for n in sorted(set(sizes)) + sizes:  # every size appears at least once
        filt.invoke_batched([np.ones((n, D), np.float32)], n)
    n_buckets = filt.n_bucket_compilations
    assert n_buckets <= 4, f"{n_buckets} buckets for max_batch=8"
    per_bucket = ";".join(
        f"b{b}:n={int(s[1])}:{1e3 * s[2] / s[0]:.2f}ms"
        for b, s in sorted(filt.bucket_stats.items()))
    return [f"e5_bucket_cache,{n_buckets}.0,"
            f"compilations_for_sizes_1..8 (max log2(8)+1=4);{per_bucket}"]


def run() -> List[str]:
    rows = []
    rows += bench_throughput_vs_batch()
    rows += bench_bucket_recompiles()
    return rows
