"""E9 — speculative decode bursts: draft-verify inside the paged loop.

A draft model proposes ``spec_k`` tokens per burst round; the target
verifies all of them in ONE batched ``paged_step`` (T = spec_k+1) and
the rejection rule keeps the output distribution exactly the target's.
The win is bounded by the acceptance rate: each round costs one draft
pass per proposal plus one (batched) target pass, and yields
``1 + accepted`` tokens.

Measured here on the e6-scale tiny model, greedy:

  * **k0 baseline** — the plain (non-speculative) decode burst;
  * **self-draft, K in {2, 4, 8}** — draft == target, the acceptance
    upper bound (rate 1.0, K+1 tokens per target step).  On these tiny
    CPU models the draft pass costs as much as the target pass, so
    wall-clock parity — not speedup — is expected; the row that matters
    is tokens **per target verify step**, which is what scales when the
    target is much larger than the draft;
  * **tiny random draft, K=4** — an *untrained* draft: acceptance near
    zero, the worst case (every round still emits one token).

Asserted: greedy speculative output is token-identical to the k0
baseline for every variant (the paper-level invariant), and the
self-draft acceptance rate is exactly 1.0.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

BATCH = 8
PROMPT_LEN = 12
MAX_NEW = 32
CAPACITY = PROMPT_LEN + MAX_NEW


def _cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(
        arch_id="e9-tiny", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
        norm="rmsnorm", mlp_act="swiglu", rope="rope",
        param_dtype="float32", compute_dtype="float32")


def _draft_cfg():
    return _cfg().replace(arch_id="e9-draft", n_layers=1, d_model=32,
                          n_heads=2, n_kv_heads=1, d_ff=64)


def _prompts():
    rng = np.random.default_rng(0)
    return [rng.integers(1, 127, PROMPT_LEN).astype(np.int32)
            for _ in range(BATCH)]


def _serve_timed(model, params, *, draft=None, spec_k=0):
    """Two full workloads on one engine: the first compiles + warms,
    the second is timed.  Returns (ordered token streams of the timed
    round, tokens/s, loop_stats of the timed round)."""
    from repro.serving import ServeEngine

    dm, dp = draft if draft is not None else (None, None)
    # share_prefix off everywhere: speculative engines force it off, and
    # the identical-prompt warm round would otherwise hand the baseline
    # a prefix-cache workload the spec engines don't run
    eng = ServeEngine(model, params, batch_size=BATCH, capacity=CAPACITY,
                      max_new_tokens=MAX_NEW, paged=True, block_size=16,
                      prefill_chunk=PROMPT_LEN, burst=8, share_prefix=False,
                      draft_model=dm, draft_params=dp, spec_k=spec_k)
    prompts = _prompts()

    def one_round():
        order = [eng.submit(p, lane="batch") for p in prompts]
        out = []
        t0 = time.perf_counter()
        while eng.has_work:
            out += eng.step()
        wall = time.perf_counter() - t0
        by_rid = {r.request_id: list(r.tokens) for r in out}
        return [by_rid[rid] for rid in order], wall

    one_round()                                     # compile + warm
    before = eng.loop_stats()
    streams, wall = one_round()
    after = eng.loop_stats()
    stats = {k: after[k] - before[k] for k in
             ("n_spec_rounds", "n_spec_tokens", "n_draft_proposed",
              "n_draft_accepted") if k in after}
    if "spec_accept_hist" in after:
        stats["hist"] = [a - b for a, b in zip(after["spec_accept_hist"],
                                               before["spec_accept_hist"])]
    tok_s = sum(len(s) for s in streams) / wall
    return streams, tok_s, stats


def run() -> List[str]:
    import jax
    from repro.models import build_model

    model = build_model(_cfg())
    params = model.init(jax.random.PRNGKey(0))
    dmodel = build_model(_draft_cfg())
    dparams = dmodel.init(jax.random.PRNGKey(1))

    base_streams, base_tok_s, _ = _serve_timed(model, params)
    rows = [f"e9_spec_k0_baseline,{1e6 / base_tok_s:.1f},"
            f"tok_s={base_tok_s:.0f};plain_burst;batch={BATCH}"]

    variants = [("self", (model, params), 2), ("self", (model, params), 4),
                ("self", (model, params), 8),
                ("rand_draft", (dmodel, dparams), 4)]
    for name, draft, k in variants:
        streams, tok_s, st = _serve_timed(model, params, draft=draft,
                                          spec_k=k)
        # the invariant that makes speculation free to adopt: greedy
        # output is token-identical to the non-speculative engine
        assert streams == base_streams, \
            f"e9 {name} K={k}: speculative tokens diverged from baseline"
        rounds = max(1, st["n_spec_rounds"])
        rate = st["n_draft_accepted"] / max(1, st["n_draft_proposed"])
        hist = "|".join(str(c) for c in st["hist"])
        rows.append(
            f"e9_spec_k{k}_{name},{1e6 / tok_s:.1f},"
            f"tok_s={tok_s:.0f};tokens_per_round="
            f"{st['n_spec_tokens'] / rounds:.2f};accept_rate={rate:.2f}"
            f";hist={hist};vs_k0=x{tok_s / base_tok_s:.2f}")
        if name == "self":
            assert rate == 1.0, \
                f"self-draft must accept everything, got {rate:.3f}"
    return rows
