"""E10 — int8 paged-KV quantization: capacity and throughput at equal
pool bytes.

The serving win of ``kv_dtype="int8"`` is capacity, not speed: at a
fixed HBM budget for the KV pool, int8 blocks (values + per-row f32
scales) are smaller than f32 blocks, so the same budget holds >= 2x the
blocks -> >= 2x the resident requests before admission starts queueing.
Both engines are sized from the same byte budget via
``kv_bytes_per_block()`` — the exact accounting ``pool_stats()``
reports — then serve the same request mix; decode tok/s is reported to
show the dequantizing attention path does not give the capacity win
back.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

PROMPT_LEN = 12
MAX_NEW = 8
N_REQ = 8
BATCH = 4
BLOCK = 4


def _build():
    import jax
    from repro.models import build_model
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        arch_id="e10-tiny", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
        norm="rmsnorm", mlp_act="swiglu", rope="rope",
        param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _engine(model, params, kv_dtype, num_blocks):
    from repro.serving import ServeEngine
    return ServeEngine(model, params, batch_size=BATCH, capacity=32,
                       max_new_tokens=MAX_NEW, block_size=BLOCK,
                       prefill_chunk=4, num_blocks=num_blocks,
                       kv_dtype=kv_dtype)


def run() -> List[str]:
    cfg, model, params = _build()
    rng = np.random.default_rng(31)
    prompts = [rng.integers(1, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
               for _ in range(N_REQ)]

    # probe engines just for the per-block byte cost of each storage mode
    probe = {d: _engine(model, params, d, 16).kv_bytes_per_block()
             for d in (None, "int8")}
    budget = probe[None] * 24          # a pool worth 24 f32 blocks

    rows = []
    caps = {}
    for dtype, label in ((None, "f32"), ("int8", "int8")):
        num_blocks = budget // probe[dtype]
        eng = _engine(model, params, dtype, num_blocks)
        s = eng.pool_stats()
        assert s["pool_bytes"] <= budget
        assert s["kv_dtype"] == label
        # worst-case blocks one request pins for its whole lifetime
        per_req = eng.allocator.blocks_for(PROMPT_LEN + MAX_NEW)
        resident = num_blocks // per_req
        caps[label] = (num_blocks, resident)
        eng.serve(prompts[:1])         # warm every jit shape bucket
        t0 = time.perf_counter()
        res = eng.serve(prompts)
        wall = time.perf_counter() - t0
        toks = sum(len(r.tokens) for r in res)
        assert len(res) == N_REQ and all(r.status == "ok" for r in res)
        rows.append(
            f"e10_{label},{1e6 * wall / toks:.1f},"
            f"pool={s['pool_bytes']}B@{s['bytes_per_block']}B/blk"
            f";blocks={num_blocks};resident_requests={resident}"
            f";decode_tok_s={toks / wall:.0f}")

    (fb, fr), (qb, qr) = caps["f32"], caps["int8"]
    rows.append(f"e10_capacity_ratio,{qb / fb:.2f},"
                f"blocks_x{qb / fb:.2f}_residents_x{qr / max(fr, 1):.2f}"
                f"_at_equal_pool_bytes")
    assert qb >= 2 * fb, f"int8 blocks {qb} < 2x f32 blocks {fb}"
    assert qr >= 2 * fr, f"int8 residents {qr} < 2x f32 residents {fr}"
    return rows
