"""E11 — goodput under injected faults (the chaos benchmark).

The same loopback tensor-query stack as E7 (paged ServeEngine behind
serversrc ! batcher ! queue ! engine-filter ! unbatcher ! serversink)
is driven twice with an identical open-loop Poisson workload:

  * **clean** — no fault plan: the baseline goodput / p99 TTFT;
  * **chaos** — a :class:`FaultPlan` poisons ~10% of submitted rows
    (``submit`` seam), injects two non-attributable engine step
    failures (``engine_step`` seam → bounded restart: survivors spill
    and re-admit), and the client cancels ~5% of its own queries
    mid-flight.

The headline is *graceful degradation*: under chaos every single
request still reaches a terminal status (ok / error / cancelled —
nothing hangs, the server never dies), the pool balances afterwards
(``n_free + n_live == num_blocks``), and goodput stays within the same
order as clean — the faults cost their own requests, not the system.
"""
from __future__ import annotations

import threading
import time
from typing import List

import numpy as np

BATCH_SLOTS = 4
MAX_NEW = 32
PROMPT_LEN = 12
CAPACITY = 48
LOAD_S = 8.0               # open-loop window per phase
RATE = 30.0                # Poisson arrivals / s
FAULT_EVERY = 10           # poison every 10th submitted row (~10%)
CANCEL_EVERY = 20          # client cancels every 20th query (~5%)


def _cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(
        arch_id="e11-tiny", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
        norm="rmsnorm", mlp_act="swiglu", rope="rope",
        param_dtype="float32", compute_dtype="float32")


def _percentile_us(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q) * 1e6)


def _phase(model, params, cfg, plan, cancel_every=0):
    """One open-loop run; returns (results, wall_s, engine, server)."""
    from repro.serving import (ServeEngine, TensorQueryClient,
                               TensorQueryServer)
    eng = ServeEngine(model, params, batch_size=BATCH_SLOTS,
                      capacity=CAPACITY, max_new_tokens=MAX_NEW,
                      block_size=8, prefill_chunk=16, fault_plan=plan)
    server = TensorQueryServer(eng, max_wait_ms=4.0, pad_to=PROMPT_LEN,
                               workers=4, fault_plan=plan).start()
    try:
        warm = TensorQueryClient("127.0.0.1", server.port)
        wq = warm.submit(np.arange(1, PROMPT_LEN + 1, dtype=np.int32))
        warm.result(wq, timeout=120)   # compile prefill/decode paths
        warm.close()

        cli = TensorQueryClient("127.0.0.1", server.port)
        rng = np.random.default_rng(0)
        gaps = list(rng.exponential(1.0 / RATE, max(1, int(LOAD_S * RATE))))
        prompt_rng = np.random.default_rng(1)
        qids: List[int] = []
        cancelled: List[int] = []

        def submit_loop():
            t_next = time.monotonic()
            for i, gap in enumerate(gaps):
                t_next += gap
                lag = t_next - time.monotonic()
                if lag > 0:
                    time.sleep(lag)
                prompt = prompt_rng.integers(
                    1, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
                qid = cli.submit(prompt)
                qids.append(qid)
                if cancel_every and (i + 1) % cancel_every == 0:
                    cli.cancel(qid)
                    cancelled.append(qid)

        t0 = time.perf_counter()
        th = threading.Thread(target=submit_loop)
        th.start()
        th.join()
        results = [cli.result(q, timeout=300) for q in qids]
        wall = time.perf_counter() - t0
        cli.close()
        pool = eng.pool_stats()
        # accounting audit: the storm must not leak a single block/route
        assert pool["n_free"] + pool["n_live"] == pool["num_blocks"], pool
        assert pool["n_reserved"] == 0, pool
        counters = {"restarts": eng.n_restarts,
                    "step_failures": eng.n_step_failures,
                    "cancelled": eng.n_cancelled,
                    "overrun_kills": server.n_overrun_kills,
                    "n_cancel_frames": len(cancelled)}
    finally:
        server.stop()
    return results, wall, counters


def run():
    import jax
    from repro.models import build_model
    from repro.serving import Fault, FaultPlan

    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def summarize(results, wall):
        ok = [r for r in results if r.status == "ok"]
        ttft = [r.ttft_s for r in ok if r.ttft_s is not None]
        toks = sum(len(r.tokens) for r in ok)
        return ok, ttft, toks / wall

    # -- clean baseline ----------------------------------------------------
    clean_res, clean_wall, _ = _phase(model, params, cfg, plan=None)
    assert all(r.status == "ok" for r in clean_res), \
        [r.status for r in clean_res if r.status != "ok"]
    ok_c, ttft_c, goodput_c = summarize(clean_res, clean_wall)

    # -- chaos: ~10% poisoned rows, 2 engine restarts, ~5% client cancels --
    plan = FaultPlan([
        Fault(point="submit", every=FAULT_EVERY, msg="chaos poison row"),
        Fault(point="engine_step", nth=50, msg="chaos step fault 1"),
        Fault(point="engine_step", nth=200, msg="chaos step fault 2"),
    ])
    chaos_res, chaos_wall, counters = _phase(model, params, cfg, plan,
                                             cancel_every=CANCEL_EVERY)
    # graceful degradation: every request is terminal, nothing hangs
    statuses = [r.status for r in chaos_res]
    assert all(s in ("ok", "error", "cancelled", "timeout", "oom")
               for s in statuses), set(statuses)
    n_err = statuses.count("error")
    n_cancel = statuses.count("cancelled")
    assert n_err >= 1, "fault plan never fired"
    ok_x, ttft_x, goodput_x = summarize(chaos_res, chaos_wall)
    # the faults cost their own requests, not the system: the healthy
    # majority still completes and throughput stays the same order
    assert len(ok_x) >= 0.5 * len(chaos_res), \
        f"only {len(ok_x)}/{len(chaos_res)} survived the chaos phase"
    assert goodput_x > 0.2 * goodput_c, \
        f"goodput collapsed under faults: {goodput_x:.1f} vs {goodput_c:.1f}"

    yield (f"e11_clean_ttft_p99,{_percentile_us(ttft_c, 99):.1f},"
           f"p50={_percentile_us(ttft_c, 50) / 1e3:.1f}ms "
           f"n={len(ok_c)}/{len(clean_res)} ok")
    yield (f"e11_clean_goodput,0.0,{goodput_c:.1f} tok/s over "
           f"{clean_wall:.1f}s clean window")
    yield (f"e11_chaos_ttft_p99,{_percentile_us(ttft_x, 99):.1f},"
           f"p50={_percentile_us(ttft_x, 50) / 1e3:.1f}ms "
           f"n={len(ok_x)}/{len(chaos_res)} ok")
    yield (f"e11_chaos_goodput,0.0,{goodput_x:.1f} tok/s under "
           f"~{100 // FAULT_EVERY}% fault rate "
           f"({goodput_x / goodput_c:.0%} of clean)")
    yield (f"e11_chaos_faults,0.0,fired={plan.n_fired} errors={n_err} "
           f"cancelled={n_cancel} restarts={counters['restarts']} "
           f"step_failures={counters['step_failures']} "
           f"engine_cancels={counters['cancelled']}")


if __name__ == "__main__":
    for row in run():
        print(row)
