"""E4 — framework overheads (paper Table III).

Analogs on this host:
  * backend swap win: the same model invoked through a slow "bound"
    backend (eager python/numpy) vs the framework-chosen fast backend
    (jax.jit) — the TF-Lite 1.15.2-vs-2.1 x3.54 story: flexibility to
    pick the execution engine is itself a performance feature.
  * pre-processing reuse: naive per-op transform chain vs the fused
    Pallas transform kernel (MediaPipe re-implemented filters were 25%
    slower / 40% more overhead).
  * hybrid embedding: an NNStreamer pipeline embedding a foreign
    sub-pipeline as one filter (paper case d) — overhead vs native.
  * per-buffer pipeline overhead: appsrc -> filter(identity) -> sink.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Buffer, parse_pipeline
from repro.core.elements.transform import TensorTransform, apply_chain_numpy, parse_chain
from repro.single import SingleShot

from .models_zoo import make_detector

N = 300
FRAME = (96, 96, 3)


def bench_backend_swap() -> List[str]:
    key = jax.random.PRNGKey(5)
    det = make_detector(key)
    frame = (np.random.randint(0, 255, FRAME, np.uint8).astype(np.float32)
             / 255.0 - 0.5)
    np.asarray(det(frame))

    # "old bound backend": eager numpy re-implementation of the same net
    # (stands in for the NNFW version the rigid framework is stuck with)
    def slow_det(f):
        x = f.astype(np.float32)[None]
        rng = np.random.default_rng(0)
        for i, w in enumerate((16, 32, 64, 64)):
            kern = rng.standard_normal((3, 3, x.shape[-1], w)).astype(np.float32) * 0.05
            pad = np.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
            s = 2 if i % 2 == 0 else 1
            out = np.zeros((1, (x.shape[1]+s-1)//s, (x.shape[2]+s-1)//s, w), np.float32)
            for dy in range(3):
                for dx in range(3):
                    out += np.einsum("bhwc,co->bhwo",
                                     pad[:, dy:dy+x.shape[1]:s, dx:dx+x.shape[2]:s, :],
                                     kern[dy, dx])
            x = np.maximum(out, 0)
        return x.mean(axis=(1, 2))

    fast = SingleShot(fn=det, framework="python")
    slow = SingleShot(fn=slow_det, framework="python")
    for s in (fast, slow):
        s.invoke(frame)

    def rate(s, n=60):
        t0 = time.perf_counter()
        for _ in range(n):
            s.invoke(frame)
        return n / (time.perf_counter() - t0)

    rf, rs = rate(fast), rate(slow, n=10)
    return [
        f"e4_backend_fast,{1e6/rf:.1f},fps={rf:.1f}",
        f"e4_backend_bound,{1e6/rs:.1f},fps={rs:.1f};fast_is_x{rf/rs:.2f}",
    ]


def bench_preprocessing() -> List[str]:
    chain = "typecast:float32,divide:255.0,subtract:0.5,clamp:-0.5:0.5"
    x = np.random.randint(0, 255, (64, 224, 224, 3), np.uint8)
    ops = parse_chain(chain)

    t0 = time.perf_counter()
    for _ in range(10):
        apply_chain_numpy(x, ops)
    naive = (time.perf_counter() - t0) / 10

    from repro.kernels.transform import ops as tops
    xj = jnp.asarray(x)
    np.asarray(tops.fused_transform_xla(xj, scale=1/255., bias=-0.5, lo=-0.5,
                                        hi=0.5, out_dtype=jnp.float32))
    t0 = time.perf_counter()
    for _ in range(10):
        np.asarray(tops.fused_transform_xla(xj, scale=1/255., bias=-0.5,
                                            lo=-0.5, hi=0.5,
                                            out_dtype=jnp.float32))
    fused = (time.perf_counter() - t0) / 10
    # Pallas kernel correctness cross-check (interpret mode, small slice)
    small = x[:2]
    pk = np.asarray(tops.fused_transform(small, scale=1/255., bias=-0.5,
                                         lo=-0.5, hi=0.5,
                                         out_dtype=jnp.float32))
    ref = np.clip(small.astype(np.float32)/255. - 0.5, -0.5, 0.5)
    assert np.allclose(pk, ref, atol=1e-6)
    return [
        f"e4_preproc_naive_chain,{naive*1e6:.1f},per-batch (4 passes)",
        f"e4_preproc_fused_xla,{fused*1e6:.1f},per-batch (1 pass);"
        f"naive_is_{100*(naive/fused-1):+.1f}%;pallas_kernel=validated",
    ]


def bench_pipeline_overhead() -> List[str]:
    pipe = parse_pipeline(
        "appsrc name=src ! tensor_filter framework=python model=identity ! "
        "fakesink name=out")
    pipe.start()
    src, out = pipe["src"], pipe["out"]
    x = np.zeros((16,), np.float32)
    t0 = time.perf_counter()
    for _ in range(N):
        src.push(x)
    wall = time.perf_counter() - t0
    pipe.stop()
    per = wall / N
    return [f"e4_pipeline_overhead,{per*1e6:.2f},per-buffer (filter+2 pads)"]


def bench_hybrid() -> List[str]:
    """Embed a foreign 'sub-pipeline' (python mini-framework) as a filter."""
    key = jax.random.PRNGKey(6)
    det = make_detector(key)
    frame = (np.random.randint(0, 255, FRAME, np.uint8).astype(np.float32)
             / 255.0 - 0.5)
    np.asarray(det(frame))

    def foreign_subpipeline(f):
        x = np.asarray(f, np.float32) * 2.0            # its own pre-proc
        x = x * 0.5                                    # (round trip, same dtype)
        return det(x)

    def native(f):
        return det(f)

    def rate(model, name, n=60):
        pipe = parse_pipeline(
            "appsrc name=src ! queue ! tensor_filter framework=python "
            f"model={name} ! fakesink name=out", models={name: model})
        pipe.start()
        src = pipe["src"]
        t0 = time.perf_counter()
        for _ in range(n):
            src.push(frame)
        src.end_of_stream()
        pipe["out"].eos_seen.wait(timeout=60)
        r = n / (time.perf_counter() - t0)
        pipe.stop()
        return r

    rn = rate(native, "native")
    rh = rate(foreign_subpipeline, "hybrid")
    return [
        f"e4_native,{1e6/rn:.1f},fps={rn:.1f}",
        f"e4_hybrid_embed,{1e6/rh:.1f},fps={rh:.1f};overhead={100*(rn/rh-1):+.1f}%",
    ]


def run() -> List[str]:
    rows = []
    rows += bench_backend_swap()
    rows += bench_preprocessing()
    rows += bench_pipeline_overhead()
    rows += bench_hybrid()
    return rows
