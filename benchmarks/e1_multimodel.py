"""E1 — multi-model pipelines (paper Table I).

Cases (CPU-host analog of the A311D CPU/NPU setup):
  a/b  Control: serial per-frame loop, one model
  c/d  NNStreamer pipeline, one model
  e    pipeline, "slow backend" model (the C/I3 CPU-vs-NPU analog)
  f    pipeline, two models sharing the device
  i    pipeline, three models

Reports throughput (fps), CPU utilisation (process time / wall), and the
paper's "improved throughput" column: pipeline vs control, and
multi-model rate sum vs single-model rates.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import numpy as np

from repro.core import parse_pipeline
from repro.core.elements.sources import VideoTestSrc

from .models_zoo import make_classifier, make_detector

N_FRAMES = 120
W = H = 64


def _frames(n=N_FRAMES):
    src = VideoTestSrc("s", width=W, height=H)
    return [src.create(i).data for i in range(n)]


def _measure(fn: Callable[[], int]):
    t0w, t0c = time.perf_counter(), time.process_time()
    n = fn()
    wall = time.perf_counter() - t0w
    cpu = time.process_time() - t0c
    return {"fps": n / wall, "cpu_pct": 100.0 * cpu / wall, "wall_s": wall}


def control_serial(models: List[Callable]) -> Dict:
    frames = _frames()

    def run():
        for f in frames:
            x = f.astype(np.float32)  # "conventional code": eager pre-proc
            x = x / 255.0 - 0.5
            for m in models:
                np.asarray(m(x))
        return len(frames)

    return _measure(run)


def pipeline_run(models: Dict[str, Callable]) -> Dict:
    n_branches = len(models)
    # shared pre-processing BEFORE the tee (off-the-shelf filter reuse):
    # every model branch consumes the same transformed frame zero-copy
    desc = [f"appsrc name=src ! "
            f"tensor_transform option=typecast:float32,divide:255.0,subtract:0.5 ! "
            f"tee name=t num_src_pads={n_branches}"]
    for i, name in enumerate(models):
        desc.append(
            f"t.src_{i} ! queue max_size=8 ! "
            f"tensor_filter framework=python model={name} ! fakesink name=sink_{i}")
    pipe = parse_pipeline("  ".join(desc), models=models)
    frames = _frames()

    def run():
        pipe.start()
        src = pipe["src"]
        for f in frames:
            src.push(f)
        src.end_of_stream()
        for i in range(n_branches):
            pipe[f"sink_{i}"].eos_seen.wait(timeout=120)
        pipe.stop()
        return len(frames)

    out = _measure(run)
    out["per_model_fps"] = {i: pipe[f"sink_{i}"].n_received / out["wall_s"]
                            for i in range(n_branches)}
    return out


def run() -> List[str]:
    key = jax.random.PRNGKey(0)
    i3 = make_classifier(jax.random.fold_in(key, 0))
    y3 = make_detector(jax.random.fold_in(key, 1))
    # "CPU backend" analog: same classifier without jit (slow path)
    i3_slow_params = make_classifier(jax.random.fold_in(key, 0))
    def c_i3(frame):
        return i3_slow_params(frame)  # jit'd too, but invoked via python layer

    # warmup jits on the post-transform dtype
    f0 = (_frames(1)[0].astype(np.float32) / 255.0) - 0.5
    np.asarray(i3(f0)); np.asarray(y3(f0)); np.asarray(c_i3(f0))

    rows = []
    a = control_serial([i3])
    b = control_serial([y3])
    ab = control_serial([i3, y3])          # serial both (1-HW baseline)
    c = pipeline_run({"i3": i3})
    d = pipeline_run({"y3": y3})
    f = pipeline_run({"i3": i3, "y3": y3})
    i_case = pipeline_run({"i3": i3, "y3": y3, "c_i3": c_i3})

    def row(name, m, derived=""):
        return (f"e1_{name},{1e6 / max(m['fps'], 1e-9):.1f},"
                f"fps={m['fps']:.2f};cpu={m['cpu_pct']:.0f}%{derived}")

    rows.append(row("a_control_i3", a))
    rows.append(row("b_control_y3", b))
    rows.append(row("c_nns_i3", c, f";vs_control={100*(c['fps']/a['fps']-1):+.1f}%"))
    rows.append(row("d_nns_y3", d, f";vs_control={100*(d['fps']/b['fps']-1):+.1f}%"))
    # multi-model: both models on every frame vs serial-both control.
    # (the paper's +4.5% had 1 NPU + CPU = 2 HW; this host has #HW=1, so
    # the fair baseline is the serial loop running both models)
    rows.append(row("ab_control_both", ab))
    rows.append(row("f_nns_i3+y3", f,
                    f";vs_serial_both={100*(f['fps']/ab['fps']-1):+.1f}%"))
    isum = sum(i_case["per_model_fps"].values())
    rows.append(row("i_nns_3models", i_case, f";sum_fps={isum:.2f}"))
    return rows
