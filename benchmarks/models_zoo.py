"""Small JAX models standing in for E1-E3's networks.

I3/Y3 (Inception-v3 / YOLO-v3 on an A311D NPU) are represented by two
jitted convnets of different depths — the benchmark measures *pipeline
architecture* effects (serial vs pipelined, multi-model sharing), which
are independent of the absolute model sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _make_conv_params(key, widths, in_ch=3):
    params = []
    ch = in_ch
    for i, w in enumerate(widths):
        k = jax.random.fold_in(key, i)
        params.append(jax.random.normal(k, (3, 3, ch, w), jnp.float32)
                      * (1.0 / np.sqrt(9 * ch)))
        ch = w
    return params


def make_classifier(key, widths=(16, 32, 64), n_classes=100, name="i3"):
    """I3 analog: conv stack -> global pool -> classes."""
    params = _make_conv_params(key, widths)
    k_head = jax.random.fold_in(key, 99)
    head = jax.random.normal(k_head, (widths[-1], n_classes), jnp.float32) * 0.05

    @jax.jit
    def forward(frame):
        """frame: (H,W,3) float32, already normalized by the pipeline."""
        x = frame[None].astype(jnp.float32)
        for i, w in enumerate(params):
            x = jax.nn.relu(_conv(x, w, stride=2 if i % 2 == 0 else 1))
        x = x.mean(axis=(1, 2))
        return (x @ head)[0]

    return forward


def make_detector(key, widths=(16, 32, 64, 64), n_boxes=8, name="y3"):
    """Y3 analog: deeper conv stack -> (N,5) boxes [x,y,w,h,score]."""
    params = _make_conv_params(key, widths)
    k_head = jax.random.fold_in(key, 99)
    head = jax.random.normal(k_head, (widths[-1], n_boxes * 5), jnp.float32) * 0.05

    @jax.jit
    def forward(frame):
        """frame: (H,W,3) float32, already normalized by the pipeline."""
        x = frame[None].astype(jnp.float32)
        for i, w in enumerate(params):
            x = jax.nn.relu(_conv(x, w, stride=2 if i % 2 == 0 else 1))
        x = x.mean(axis=(1, 2))
        return (x @ head).reshape(n_boxes, 5)

    return forward


def make_mlp(key, in_dim, hidden, out_dim, depth: int = 1):
    ks = jax.random.split(key, depth + 2)
    w_in = jax.random.normal(ks[0], (in_dim, hidden), jnp.float32) / np.sqrt(in_dim)
    mids = [jax.random.normal(ks[1 + i], (hidden, hidden), jnp.float32)
            / np.sqrt(hidden) for i in range(depth)]
    w_out = jax.random.normal(ks[-1], (hidden, out_dim), jnp.float32) / np.sqrt(hidden)

    @jax.jit
    def forward(x):
        h = jax.nn.relu(x.reshape(-1) @ w_in)
        for w in mids:
            h = jax.nn.relu(h @ w)
        return h @ w_out

    return forward


# ---------------------------------------------------------------------------
# MTCNN-style nets (E3): P-Net (fully conv), R-Net, O-Net
# ---------------------------------------------------------------------------

def make_pnet(key):
    params = _make_conv_params(key, (8, 16))
    k_head = jax.random.fold_in(key, 9)
    head = jax.random.normal(k_head, (3, 3, 16, 6), jnp.float32) * 0.05

    @jax.jit
    def forward(img):
        """img: (H,W,3) uint8 -> (h,w,6) map: [score, dx,dy,dw,dh, _]."""
        x = img[None].astype(jnp.float32) / 255.0
        for w in params:
            x = jax.nn.relu(_conv(x, w, stride=2))
        return _conv(x, head)[0]

    return forward


def make_rnet(key, patch=24):
    params = _make_conv_params(key, (16, 32))
    k_head = jax.random.fold_in(key, 9)
    head = jax.random.normal(k_head, (32, 5), jnp.float32) * 0.05

    @jax.jit
    def forward(patches):
        """patches: (N,24,24,3) -> (N,5): [score, dx,dy,dw,dh]."""
        x = patches.astype(jnp.float32) / 255.0
        for w in params:
            x = jax.nn.relu(_conv(x, w, stride=2))
        return x.mean(axis=(1, 2)) @ head

    return forward


def make_onet(key, patch=48):
    params = _make_conv_params(key, (16, 32, 64))
    k_head = jax.random.fold_in(key, 9)
    head = jax.random.normal(k_head, (64, 15), jnp.float32) * 0.05

    @jax.jit
    def forward(patches):
        """patches: (N,48,48,3) -> (N,15): score+bbr+landmarks."""
        x = patches.astype(jnp.float32) / 255.0
        for w in params:
            x = jax.nn.relu(_conv(x, w, stride=2))
        return x.mean(axis=(1, 2)) @ head

    return forward


# -- post-processing (the 1004-lines-of-C analog, in numpy) -------------------

def nms(boxes: np.ndarray, iou_thresh=0.5, top=16) -> np.ndarray:
    """boxes: (N,5) [x,y,w,h,score] -> kept boxes."""
    if len(boxes) == 0:
        return boxes
    order = np.argsort(-boxes[:, 4])
    keep = []
    for i in order:
        ok = True
        for j in keep:
            xx = max(boxes[i, 0], boxes[j, 0])
            yy = max(boxes[i, 1], boxes[j, 1])
            x2 = min(boxes[i, 0] + boxes[i, 2], boxes[j, 0] + boxes[j, 2])
            y2 = min(boxes[i, 1] + boxes[i, 3], boxes[j, 1] + boxes[j, 3])
            inter = max(x2 - xx, 0) * max(y2 - yy, 0)
            union = boxes[i, 2] * boxes[i, 3] + boxes[j, 2] * boxes[j, 3] - inter
            if union > 0 and inter / union > iou_thresh:
                ok = False
                break
        if ok:
            keep.append(i)
        if len(keep) >= top:
            break
    return boxes[keep]


def bbr(boxes: np.ndarray, deltas: np.ndarray) -> np.ndarray:
    """Bounding-box regression."""
    out = boxes.copy()
    out[:, 0] += deltas[:, 0] * boxes[:, 2]
    out[:, 1] += deltas[:, 1] * boxes[:, 3]
    out[:, 2] *= np.exp(np.clip(deltas[:, 2], -1, 1))
    out[:, 3] *= np.exp(np.clip(deltas[:, 3], -1, 1))
    return out


def image_patch(frame: np.ndarray, boxes: np.ndarray, size: int) -> np.ndarray:
    """Crop+resize (nearest) patches for the next cascade stage."""
    H, W = frame.shape[:2]
    out = np.zeros((max(len(boxes), 1), size, size, 3), frame.dtype)
    for i, (x, y, w, h, *_rest) in enumerate(boxes):
        x0, y0 = int(max(x, 0)), int(max(y, 0))
        x1 = int(min(x + max(w, 1), W))
        y1 = int(min(y + max(h, 1), H))
        if x1 <= x0 or y1 <= y0:
            continue
        crop = frame[y0:y1, x0:x1]
        yi = (np.arange(size) * crop.shape[0] // size).clip(0, crop.shape[0] - 1)
        xi = (np.arange(size) * crop.shape[1] // size).clip(0, crop.shape[1] - 1)
        out[i] = crop[yi][:, xi]
    return out


def pnet_map_to_boxes(pmap: np.ndarray, scale: float, stride=4, cell=12,
                      thresh=0.7) -> np.ndarray:
    """P-Net output map -> candidate boxes at this pyramid scale."""
    score = 1.0 / (1.0 + np.exp(-pmap[:, :, 0]))
    ys, xs = np.where(score > thresh)
    if len(ys) == 0:
        return np.zeros((0, 5), np.float32)
    boxes = np.stack([
        xs * stride / scale, ys * stride / scale,
        np.full(len(ys), cell / scale), np.full(len(ys), cell / scale),
        score[ys, xs]], axis=1).astype(np.float32)
    deltas = pmap[ys, xs, 1:5]
    return bbr(boxes, deltas)
