"""E3 — MTCNN cascaded pipeline (paper Table II, Fig 4).

Topology: frame -> 3-scale pyramid (tee) -> P-Net per scale -> NMS+BBR
merge -> image-patch -> R-Net -> NMS+BBR -> image-patch -> O-Net ->
overlay decoder -> sink.  Control: identical functions called serially.

Reports overall latency (1-frame-at-a-time), throughput (streaming), and
per-stage latencies (TensorFilter stats) — the rows of Table II.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.core import Buffer, parse_pipeline
from repro.core.elements.sources import VideoTestSrc

from .models_zoo import (bbr, image_patch, make_onet, make_pnet, make_rnet,
                         nms, pnet_map_to_boxes)

N_FRAMES = 60
W = H = 160
SCALES = (1.0, 0.7, 0.5)


def _build_fns(key):
    pnet, rnet, onet = make_pnet(key), make_rnet(jax.random.fold_in(key, 1)), \
        make_onet(jax.random.fold_in(key, 2))

    def scale_frame(frame, s):
        if s == 1.0:
            return frame
        hi = (np.arange(int(H * s)) / s).astype(int).clip(0, H - 1)
        wi = (np.arange(int(W * s)) / s).astype(int).clip(0, W - 1)
        return frame[hi][:, wi]

    def pnet_stage(frame):
        cands = []
        for s in SCALES:
            pmap = np.asarray(pnet(scale_frame(frame, s)))
            cands.append(pnet_map_to_boxes(pmap, s, thresh=0.5))
        boxes = np.concatenate(cands) if cands else np.zeros((0, 5), np.float32)
        return nms(boxes, top=12)

    def rnet_stage(frame, boxes):
        if len(boxes) == 0:
            return boxes
        patches = image_patch(frame, boxes, 24)
        out = np.asarray(rnet(patches))
        score = 1 / (1 + np.exp(-out[:, 0]))
        keep = score > 0.2
        boxes = bbr(boxes[keep], out[keep, 1:5])
        boxes[:, 4] = score[keep]
        return nms(boxes, top=6)

    def onet_stage(frame, boxes):
        if len(boxes) == 0:
            return boxes
        patches = image_patch(frame, boxes, 48)
        out = np.asarray(onet(patches))
        score = 1 / (1 + np.exp(-out[:, 0]))
        keep = score > 0.2
        boxes = bbr(boxes[keep], out[keep, 1:5])
        boxes[:, 4] = score[keep]
        return nms(boxes, top=4)

    return pnet_stage, rnet_stage, onet_stage


def _frames(n=N_FRAMES):
    src = VideoTestSrc("s", width=W, height=H)
    return [src.create(i).data for i in range(n)]


def control_serial(stages) -> Dict:
    pnet_stage, rnet_stage, onet_stage = stages
    frames = _frames()
    # latency: single frame end-to-end
    lat = []
    for f in frames[:10]:
        t0 = time.perf_counter()
        onet_stage(f, rnet_stage(f, pnet_stage(f)))
        lat.append(time.perf_counter() - t0)
    t0 = time.perf_counter()
    for f in frames:
        onet_stage(f, rnet_stage(f, pnet_stage(f)))
    wall = time.perf_counter() - t0
    return {"fps": len(frames) / wall, "latency_ms": 1e3 * np.mean(lat)}


def pipeline_run(stages) -> Dict:
    pnet_stage, rnet_stage, onet_stage = stages

    # custom filters carry (frame, boxes) tuples through the cascade
    def pnet_f(frame):
        return frame, pnet_stage(np.asarray(frame))

    def rnet_f(frame, boxes):
        return frame, rnet_stage(np.asarray(frame), np.asarray(boxes))

    def onet_f(frame, boxes):
        return onet_stage(np.asarray(frame), np.asarray(boxes))

    models = {"pnet_stage": pnet_f, "rnet_stage": rnet_f, "onet_stage": onet_f}
    desc = (
        "appsrc name=src ! queue max_size=4 ! "
        "tensor_filter framework=python model=pnet_stage name=fp ! queue max_size=4 ! "
        "tensor_filter framework=python model=rnet_stage name=fr ! queue max_size=4 ! "
        "tensor_filter framework=python model=onet_stage name=fo ! "
        "tensor_sink name=out keep=false")
    pipe = parse_pipeline(desc, models=models)
    frames = _frames()

    # latency: one frame through the quiet pipeline
    pipe.start()
    src = pipe["src"]
    out = pipe["out"]
    lat = []
    for f in frames[:10]:
        n0 = out.n_received
        t0 = time.perf_counter()
        src.push(f)
        while out.n_received == n0:
            time.sleep(0.0002)
        lat.append(time.perf_counter() - t0)
    # throughput: stream everything
    t0 = time.perf_counter()
    for f in frames:
        src.push(f)
    src.end_of_stream()
    out.eos_seen.wait(timeout=300)
    wall = time.perf_counter() - t0
    res = {"fps": len(frames) / wall, "latency_ms": 1e3 * np.mean(lat),
           "stage_ms": {n: 1e3 * pipe[f].mean_latency_s
                        for n, f in (("pnet", "fp"), ("rnet", "fr"),
                                     ("onet", "fo"))}}
    pipe.stop()
    return res


def run() -> List[str]:
    stages = _build_fns(jax.random.PRNGKey(3))
    # jit warmup
    f0 = _frames(1)[0]
    stages[2](f0, stages[1](f0, stages[0](f0)))

    ctrl = control_serial(stages)
    nns = pipeline_run(stages)
    rows = [
        f"e3_control,{1e6/max(ctrl['fps'],1e-9):.1f},fps={ctrl['fps']:.2f};latency={ctrl['latency_ms']:.1f}ms",
        f"e3_nnstreamer,{1e6/max(nns['fps'],1e-9):.1f},fps={nns['fps']:.2f};latency={nns['latency_ms']:.1f}ms;"
        f"thr_gain={100*(nns['fps']/ctrl['fps']-1):+.1f}%",
    ]
    for stage, ms in nns["stage_ms"].items():
        rows.append(f"e3_stage_{stage},{ms*1e3:.1f},per-invoke latency")
    return rows
