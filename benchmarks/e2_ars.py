"""E2 — Activity Recognition Sensor (multi-modal multi-model, paper Fig 3).

Pipeline: 3 sensor streams at different rates -> per-stream aggregators
(temporal windows) -> mux (slowest sync) -> activity-classifier model,
plus a side branch: raw stream -> anomaly model -> tensor_if gate.
Control: hand-written serial loop doing the same work.

Reports batch-processing rate (paper: +65.5%), CPU%, peak RSS delta.
"""
from __future__ import annotations

import resource
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core import parse_pipeline
from repro.core.elements.sources import SensorSrc

from .models_zoo import make_mlp

N_SAMPLES = 160
CHANNELS = 4
WINDOW = 8


def _streams():
    srcs = [SensorSrc(f"s{i}", channels=CHANNELS, seed=i) for i in range(3)]
    return [[s.create(j).data for j in range(N_SAMPLES)] for s in srcs]


def control_serial(act_model, anom_model) -> Dict:
    streams = _streams()

    def run():
        t0 = time.perf_counter()
        n_out = 0
        wins: List[List[np.ndarray]] = [[], [], []]
        for j in range(N_SAMPLES):
            for i in range(3):
                wins[i].append(streams[i][j])
            if len(wins[0]) >= WINDOW:
                feats = [np.concatenate(w[:WINDOW]) for w in wins]
                wins = [w[WINDOW:] for w in wins]
                fused = np.concatenate(feats)
                np.asarray(act_model(fused))
                n_out += 1
            np.asarray(anom_model(streams[0][j]))
        return n_out, time.perf_counter() - t0

    t0c = time.process_time()
    n, wall = run()
    cpu = time.process_time() - t0c
    return {"rate": n / wall, "cpu_pct": 100 * cpu / wall, "wall_s": wall}


def pipeline_run(act_model, anom_model) -> Dict:
    def act_fused(c0, c1, c2):
        return act_model(np.concatenate([np.ravel(c0), np.ravel(c1),
                                         np.ravel(c2)]))

    models = {"act": act_fused, "anom": anom_model}
    desc = f"""
    sensorsrc name=src0 channels={CHANNELS} num_buffers={N_SAMPLES} seed=0 ! tee name=t0 num_src_pads=2
    t0.src_0 ! queue ! tensor_aggregator frames_in={WINDOW} ! mux.sink_0
    t0.src_1 ! queue ! tensor_filter framework=python model=anom ! fakesink name=anom_sink
    sensorsrc name=src1 channels={CHANNELS} num_buffers={N_SAMPLES} seed=1 !
        tensor_aggregator frames_in={WINDOW} ! mux.sink_1
    sensorsrc name=src2 channels={CHANNELS} num_buffers={N_SAMPLES} seed=2 !
        tensor_aggregator frames_in={WINDOW} ! mux.sink_2
    tensor_mux name=mux num_sinks=3 sync=slowest !
        tensor_filter framework=python model=act ! fakesink name=act_sink
    """.replace("\n", " ")
    pipe = parse_pipeline(desc, models=models)
    t0w, t0c = time.perf_counter(), time.process_time()
    pipe.run_until_eos(timeout=180)
    wall = time.perf_counter() - t0w
    cpu = time.process_time() - t0c
    n = pipe["act_sink"].n_received
    return {"rate": n / wall, "cpu_pct": 100 * cpu / wall, "wall_s": wall,
            "anom": pipe["anom_sink"].n_received}


def run() -> List[str]:
    key = jax.random.PRNGKey(7)
    # realistically-sized nets: ms-scale work per window, so framework
    # overhead is measured as a fraction of real compute
    act = make_mlp(jax.random.fold_in(key, 0), 3 * WINDOW * CHANNELS, 1536, 8,
                   depth=3)
    anom = make_mlp(jax.random.fold_in(key, 1), CHANNELS, 512, 2, depth=1)
    np.asarray(act(np.zeros(3 * WINDOW * CHANNELS, np.float32)))
    np.asarray(anom(np.zeros(CHANNELS, np.float32)))

    ctrl = control_serial(act, anom)
    nns = pipeline_run(act, anom)
    gain = 100 * (nns["rate"] / ctrl["rate"] - 1)
    return [
        f"e2_control,{1e6/max(ctrl['rate'],1e-9):.1f},rate={ctrl['rate']:.1f}win/s;cpu={ctrl['cpu_pct']:.0f}%",
        f"e2_nnstreamer,{1e6/max(nns['rate'],1e-9):.1f},rate={nns['rate']:.1f}win/s;cpu={nns['cpu_pct']:.0f}%;vs_control={gain:+.1f}%",
    ]
