"""E8 — tensor-parallel paged serving: decode tokens/s over mesh sizes.

Sweeps the sharded ServeEngine over ``(1, N)`` serving meshes for
N = 1 / 2 / 4 / 8 and reports steady-state paged burst-decode
throughput at each width, plus a token-identity check: every mesh size
must decode exactly the tokens the single-device engine decodes (the
sharded-serving contract — see ``tests/test_mesh_serving.py``).

Mesh sizes > 1 need > 1 device, and the host-device-count flag must be
set *before* jax initializes — but the benchmark harness imports jax
long before this section runs.  So ``run()`` re-executes this module as
a **subprocess worker** with ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` and relays the worker's rows.  On CPU the simulated
devices share one socket, so the curve measures sharding *overhead*
(collective cost per token), not speedup — the number that transfers to
real accelerators is tokens/s staying flat-ish while per-device memory
drops by N.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time
from typing import List

BATCH = 8
PROMPT_LEN = 16
MAX_NEW = 40
CAPACITY = PROMPT_LEN + MAX_NEW
WINDOWS = 2
MESH_SIZES = (1, 2, 4, 8)


def _cfg():
    # e6's tiny dense model, TP-divisible everywhere at 8-way:
    # head_dim 16, d_ff 128, vocab 128
    from repro.models.config import ModelConfig
    return ModelConfig(
        arch_id="e8-tiny", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
        norm="rmsnorm", mlp_act="swiglu", rope="rope",
        param_dtype="float32", compute_dtype="float32")


def _make_engine(model, params, mesh):
    from repro.serving import ServeEngine
    return ServeEngine(model, params, batch_size=BATCH, capacity=CAPACITY,
                       max_new_tokens=MAX_NEW, paged=True, block_size=16,
                       prefill_chunk=PROMPT_LEN, burst=8, mesh=mesh)


def _decode_tok_s(eng) -> float:
    """e6-style steady-state window: prefill a full batch to completion,
    warm the burst path, then time pure-decode ticks (no admissions or
    evictions inside the timed region); best of WINDOWS."""
    import numpy as np
    rng = np.random.default_rng(0)
    k = eng.burst
    n_ticks = (MAX_NEW - 10 - k) // k
    best = 0.0
    for _ in range(WINDOWS):
        target = eng.n_prefills + BATCH
        for _ in range(BATCH):
            eng.submit(rng.integers(1, 127, PROMPT_LEN).astype(np.int32))
        while eng.n_prefills < target:
            eng.step()
        eng.step()
        s0 = eng.n_device_steps
        t0 = time.perf_counter()
        for _ in range(n_ticks):
            eng.step()
        wall = time.perf_counter() - t0
        steps = eng.n_device_steps - s0
        assert eng.n_active == BATCH, "slots evicted inside the window"
        best = max(best, steps * BATCH / wall)
        while eng.has_work:
            eng.step()
    return best


def _identity_tokens(eng):
    """Greedy-decode a fixed workload; returns {rid: token list}."""
    import numpy as np
    rng = np.random.default_rng(7)
    for n in (6, 12, 9, 14):
        eng.submit(rng.integers(1, 127, n).astype(np.int32))
    out = {}
    while eng.has_work:
        for r in eng.step():
            out[r.request_id] = list(r.tokens)
    return out


def worker() -> None:
    """Runs under the forced 8-device host platform; prints e8_ rows."""
    import jax
    from repro.launch.mesh import make_serving_mesh
    from repro.models import build_model

    model = build_model(_cfg())
    params = model.init(jax.random.PRNGKey(0))
    n_dev = jax.device_count()
    ref_tokens, ref_tok_s = None, None
    for n in MESH_SIZES:
        if n > n_dev:
            continue
        mesh = None if n == 1 else make_serving_mesh(model=n)
        tok_s = _decode_tok_s(_make_engine(model, params, mesh))
        tokens = _identity_tokens(_make_engine(model, params, mesh))
        if ref_tokens is None:
            ref_tokens, ref_tok_s = tokens, tok_s
        else:
            assert tokens == ref_tokens, \
                f"mesh={n} decoded different tokens than single-device"
        print(f"e8_mesh{n},{1e6 / tok_s:.1f},"
              f"tok_s={tok_s:.0f};devices={n};paged_burst_k8"
              f";vs_mesh1=x{tok_s / ref_tok_s:.2f};token_identical=True",
              flush=True)
    print(f"e8_summary,{n_dev:.1f},simulated_devices={n_dev}"
          f";mesh_sizes_token_identical=True;batch={BATCH}", flush=True)


def run() -> List[str]:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.e8_sharded"], env=env, cwd=root,
        capture_output=True, text=True, timeout=1200)
    rows = [l for l in out.stdout.splitlines() if l.startswith("e8_")]
    if out.returncode != 0 or not rows:
        raise RuntimeError(
            f"e8 worker failed (rc={out.returncode}):\n"
            f"{out.stdout[-2000:]}\n{out.stderr[-2000:]}")
    return rows


if __name__ == "__main__":
    worker()
