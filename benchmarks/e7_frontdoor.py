"""E7 — the tensor-query front door under open-loop load.

A loopback ``TensorQueryServer`` (serversrc ! batcher ! queue[workers]
! engine-filter ! unbatcher ! serversink) serves a paged ServeEngine
while two client populations hit it concurrently:

  * **batch lane** — Poisson open-loop arrivals (fixed-seed exponential
    gaps, submitted on schedule regardless of completions), the bulk
    work that keeps every slot busy;
  * **interactive lane** — sparse probes whose *time to first token*
    is the SLO.  The scheduler admits them ahead of queued batch work
    and preempts running batch slots when the pool is full, so their
    TTFT must stay bounded while batch TTFT absorbs the queueing.

Reported per lane: p50/p99 TTFT (measured at the client from the
streamed TOKENS frames), plus median time-per-output-token and total
goodput.  The asserted headline: interactive p99 TTFT under the batch
p99 — priority scheduling visible end-to-end through the socket.
"""
from __future__ import annotations

import threading
import time
from typing import List

import numpy as np

BATCH_SLOTS = 4
MAX_NEW = 32
PROMPT_LEN = 12
CAPACITY = 48
LOAD_S = 10.0              # open-loop window
BATCH_RATE = 50.0          # Poisson batch arrivals / s (saturating)
PROBE_GAP_S = 0.5          # interactive probe spacing


def _cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(
        arch_id="e7-tiny", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
        norm="rmsnorm", mlp_act="swiglu", rope="rope",
        param_dtype="float32", compute_dtype="float32")


def _percentile_us(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q) * 1e6)


def _submit_open_loop(client, rng, lane: str, gaps: List[float],
                      vocab: int, out: List[int]) -> None:
    """Submit one request per gap, on schedule (open loop: arrivals do
    not wait for completions)."""
    t_next = time.monotonic()
    for gap in gaps:
        t_next += gap
        lag = t_next - time.monotonic()
        if lag > 0:
            time.sleep(lag)
        prompt = rng.integers(1, vocab, PROMPT_LEN).astype(np.int32)
        out.append(client.submit(prompt, lane=lane))


def run():
    import jax
    from repro.models import build_model
    from repro.serving import (ServeEngine, TensorQueryClient,
                               TensorQueryServer)

    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=BATCH_SLOTS,
                      capacity=CAPACITY, max_new_tokens=MAX_NEW,
                      block_size=8, prefill_chunk=16)
    server = TensorQueryServer(eng, max_wait_ms=4.0, pad_to=PROMPT_LEN,
                               workers=4).start()
    try:
        warm = TensorQueryClient("127.0.0.1", server.port)
        wq = warm.submit(np.arange(1, PROMPT_LEN + 1, dtype=np.int32))
        warm.result(wq, timeout=120)   # compile prefill/decode paths
        warm.close()

        rng = np.random.default_rng(0)
        n_batch = max(1, int(LOAD_S * BATCH_RATE))
        batch_gaps = list(rng.exponential(1.0 / BATCH_RATE, n_batch))
        probe_gaps = [PROBE_GAP_S] * int(LOAD_S / PROBE_GAP_S)
        batch_cli = TensorQueryClient("127.0.0.1", server.port)
        probe_cli = TensorQueryClient("127.0.0.1", server.port)
        batch_qids: List[int] = []
        probe_qids: List[int] = []
        threads = [
            threading.Thread(target=_submit_open_loop,
                             args=(batch_cli, np.random.default_rng(1),
                                   "batch", batch_gaps, cfg.vocab_size,
                                   batch_qids)),
            threading.Thread(target=_submit_open_loop,
                             args=(probe_cli, np.random.default_rng(2),
                                   "interactive", probe_gaps,
                                   cfg.vocab_size, probe_qids)),
        ]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        batch_res = [batch_cli.result(q, timeout=300) for q in batch_qids]
        probe_res = [probe_cli.result(q, timeout=300) for q in probe_qids]
        wall = time.perf_counter() - t0
        batch_cli.close()
        probe_cli.close()
    finally:
        server.stop()

    assert all(r.status == "ok" for r in probe_res), \
        [r.status for r in probe_res]
    ok_batch = [r for r in batch_res if r.status == "ok"]
    assert len(ok_batch) >= 0.9 * len(batch_res), \
        f"only {len(ok_batch)}/{len(batch_res)} batch requests finished ok"

    ttft_i = [r.ttft_s for r in probe_res]
    ttft_b = [r.ttft_s for r in ok_batch]
    tpot = [(r.latency_s - r.ttft_s) / (len(r.tokens) - 1)
            for r in ok_batch + probe_res if len(r.tokens) > 1]
    total_tokens = sum(len(r.tokens) for r in ok_batch + probe_res)

    i_p50, i_p99 = _percentile_us(ttft_i, 50), _percentile_us(ttft_i, 99)
    b_p50, b_p99 = _percentile_us(ttft_b, 50), _percentile_us(ttft_b, 99)
    # the headline: priority lanes visible end-to-end over the socket
    assert i_p99 < b_p99, \
        f"interactive p99 TTFT {i_p99:.0f}us not under batch {b_p99:.0f}us"

    yield (f"e7_interactive_ttft_p99,{i_p99:.1f},"
           f"p50={i_p50 / 1e3:.1f}ms p99={i_p99 / 1e3:.1f}ms "
           f"n={len(ttft_i)}")
    yield (f"e7_batch_ttft_p99,{b_p99:.1f},"
           f"p50={b_p50 / 1e3:.1f}ms p99={b_p99 / 1e3:.1f}ms "
           f"n={len(ttft_b)} ok={len(ok_batch)}/{len(batch_res)}")
    yield (f"e7_tpot,{_percentile_us(tpot, 50):.1f},"
           f"median time/output-token; p99={_percentile_us(tpot, 99):.1f}us")
    yield (f"e7_goodput,0.0,{total_tokens / wall:.1f} tok/s over "
           f"{wall:.1f}s open-loop window")
    yield (f"e7_sched,0.0,preemptions={eng.n_preemptions} "
           f"restores={eng.n_restores} expired={eng.n_expired} "
           f"prefix_hits={eng.n_prefix_hits} joins={eng.n_joins}")


if __name__ == "__main__":
    for row in run():
        print(row)
