"""E6 — device-resident decode loop: fused megastep + decode bursts.

Steady-state decode throughput of the ServeEngine's hot path, before vs
after the device-resident rework, for both cache regimes:

  * **before** — a faithful replica of the per-step host loop the
    engine ran through PR 4: one jitted ``paged_step``/``decode_step``
    call per token, a *separate* jitted sampler dispatch fed via a
    per-row python dict, ``np.asarray`` token sync every step, and
    ``jnp.asarray`` re-upload of page_table / lengths / state_slots /
    tokens on every call (~6 host<->device transfers per token).
  * **megastep (K=1)** — the fused step: model + sampler + state update
    in one jit, slot state device-resident; one drain per token.
  * **burst (K=8)** — 8 fused steps per host round-trip through the
    ``lax.while_loop`` ring buffer; one drain per 8 tokens.

Reported: steady-state decode tokens/s at batch 8 on the e5 tiny
model, host syncs per decoded step, and the speedup of burst mode over
the per-step host loop (asserted >= 3x for the paged engine — the
headline number).  Each variant is timed over several windows and the
best is kept, so a host load spike cannot fake a regression.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

BATCH = 8
PROMPT_LEN = 16
MAX_NEW = 40              # per-window decode budget (window <= 30 steps)
CAPACITY = PROMPT_LEN + MAX_NEW
WINDOWS = 3               # best-of-N windows (robust to host load spikes)


def _cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(
        arch_id="e6-tiny", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
        norm="rmsnorm", mlp_act="swiglu", rope="rope",
        param_dtype="float32", compute_dtype="float32")


def _before_paged_tok_s(model, params) -> float:
    """The PR-4-era paged inner loop, re-created verbatim: every token
    pays 4 array re-uploads, a separate sampler dispatch (with the
    per-row dict build), and a blocking token fetch."""
    import jax
    import jax.numpy as jnp
    from repro.serving import make_slot_sampler

    B, bs = BATCH, 16
    P = -(-CAPACITY // bs)
    cache = model.init_paged_cache(B * P, bs, dtype=jnp.float32)
    paged_fn = jax.jit(model.paged_step, donate_argnums=(1,))
    sampler = make_slot_sampler(0, greedy=True)
    page_table = np.arange(B * P, dtype=np.int32).reshape(B, P)
    lengths = np.full((B,), PROMPT_LEN, np.int32)
    state_slots = np.zeros((B,), np.int32)
    tokens = [1] * B
    steps = [0] * B

    def one_step():
        nonlocal cache
        tok = np.asarray(tokens, np.int32)[:, None]
        t_valid = np.ones((B,), np.int32)
        logits, cache = paged_fn(
            params, cache, jnp.asarray(tok), jnp.asarray(page_table),
            jnp.asarray(lengths), jnp.asarray(t_valid),
            jnp.asarray(state_slots))
        rows = {i: (i, steps[i]) for i in range(B)}    # the old dict build
        rids = np.zeros((B,), np.int32)
        st = np.zeros((B,), np.int32)
        for i, (r, t) in rows.items():
            rids[i], st[i] = r, t
        toks = np.asarray(sampler(logits, jnp.asarray(rids),
                                  jnp.asarray(st)))
        for i in range(B):
            tokens[i] = int(toks[i])
            steps[i] += 1
            lengths[i] += 1

    one_step()                          # compile
    best = 0.0
    n = MAX_NEW - 10
    for _ in range(WINDOWS):
        lengths.fill(PROMPT_LEN)        # fresh window, same work per step
        t0 = time.perf_counter()
        for _ in range(n):
            one_step()
        best = max(best, n * B / (time.perf_counter() - t0))
    return best


def _before_dense_tok_s(model, params) -> float:
    """The dense per-step host loop: greedy jitted decode + np.asarray
    token sync + python feedback loop every token."""
    import jax
    import jax.numpy as jnp
    from repro.serving import make_decode_step

    B = BATCH
    prompts = np.ones((B, PROMPT_LEN), np.int32)
    _, cache = model.prefill(params, jnp.asarray(prompts),
                             capacity=CAPACITY, cache_dtype=jnp.float32)
    decode = jax.jit(make_decode_step(model, greedy=True))
    token = jnp.ones((B, 1), jnp.int32)
    pos = [PROMPT_LEN]

    def one_step():
        nonlocal cache, token
        tk, logits, cache = decode(params, cache, token, jnp.int32(pos[0]))
        tok = np.asarray(tk[:, 0])              # the per-token sync
        token = jnp.asarray(tok, jnp.int32)[:, None]
        pos[0] += 1

    one_step()
    best = 0.0
    n = MAX_NEW - 10
    for _ in range(WINDOWS):
        pos[0] = PROMPT_LEN                     # fresh window
        t0 = time.perf_counter()
        for _ in range(n):
            one_step()
        best = max(best, n * B / (time.perf_counter() - t0))
    return best


def _engine_tok_s(model, params, *, paged: bool, k: int):
    """Steady-state decode throughput of the reworked engine.  Each
    window serves one fresh full batch: prefill to completion, one
    warm-up tick, then timed pure-decode ticks (the batch keeps
    decoding through the whole window — no admissions or evictions
    land inside the timed region).  Returns (best tokens/s, host syncs
    per device step)."""
    from repro.serving import ServeEngine

    rng = np.random.default_rng(0)
    eng = ServeEngine(model, params, batch_size=BATCH, capacity=CAPACITY,
                      max_new_tokens=MAX_NEW, paged=paged, block_size=16,
                      prefill_chunk=PROMPT_LEN, burst=8)
    eng.burst = k
    n_ticks = (MAX_NEW - 10 - k) // k
    best, sync_rate = 0.0, 1.0
    for _ in range(WINDOWS):
        target = eng.n_prefills + (BATCH if paged else 1)
        for _ in range(BATCH):
            eng.submit(rng.integers(1, 127, PROMPT_LEN).astype(np.int32))
        while eng.n_prefills < target:
            eng.step()                  # consume prompts (+ compile)
        eng.step()                      # warm the burst path
        s0, y0 = eng.n_device_steps, eng.n_host_syncs
        t0 = time.perf_counter()
        for _ in range(n_ticks):
            eng.step()
        wall = time.perf_counter() - t0
        steps = eng.n_device_steps - s0
        assert eng.n_active == BATCH, "slots evicted inside the window"
        if steps * BATCH / wall > best:
            best = steps * BATCH / wall
            sync_rate = (eng.n_host_syncs - y0) / steps
        while eng.has_work:
            eng.step()                  # drain before the next window
    return best, sync_rate


def run() -> List[str]:
    import jax
    from repro.models import build_model

    model = build_model(_cfg())
    params = model.init(jax.random.PRNGKey(0))
    rows = []
    results = {}
    for mode, paged in (("paged", True), ("dense", False)):
        before = (_before_paged_tok_s if paged else _before_dense_tok_s)(
            model, params)
        k1, k1_sync = _engine_tok_s(model, params, paged=paged, k=1)
        k8, k8_sync = _engine_tok_s(model, params, paged=paged, k=8)
        results[mode] = (before, k1, k8, k8_sync)
        rows.append(f"e6_{mode}_before,{1e6 / before:.1f},"
                    f"tok_s={before:.0f};per_step_host_loop"
                    f";transfers_per_tok~6")
        rows.append(f"e6_{mode}_megastep_k1,{1e6 / k1:.1f},"
                    f"tok_s={k1:.0f};fused_megastep"
                    f";syncs_per_step={k1_sync:.2f}")
        rows.append(f"e6_{mode}_burst_k8,{1e6 / k8:.1f},"
                    f"tok_s={k8:.0f};device_burst"
                    f";syncs_per_step={k8_sync:.3f}")
        rows.append(f"e6_{mode}_summary,{k8 / before:.2f},"
                    f"burst8_vs_host_loop=x{k8 / before:.2f}"
                    f";megastep_vs_host_loop=x{k1 / before:.2f}"
                    f";batch={BATCH}")
    before, k1, k8, k8_sync = results["paged"]
    assert k8_sync <= 1 / 8 + 1e-9, f"burst drained {k8_sync:.3f}/step"
    assert k8 / before >= 3.0, \
        f"paged burst only x{k8 / before:.2f} over the per-step host loop"
    return rows
