"""Mamba (S6) block — the SSM layer of Jamba [arXiv:2403.19887].

TPU adaptation: the CUDA selective-scan becomes (a) a `lax.scan` linear
recurrence (reference / lowering path), (b) an optional chunked form
(`chunk_size`) that runs the recurrence at chunk granularity with
parallel intra-chunk compute — bigger matmuls for the MXU, shorter scan
— and (c) the Pallas `ssm_scan` kernel for the hot path.

State for decode: conv ring (B, d_conv-1, d_inner) + ssm state
(B, d_inner, d_state): constant memory per token — why Jamba runs
long_500k natively.

Serving entry points share one per-token step (``_ssm_step`` /
``_conv_taps``): ``mamba_decode`` is the T=1 case of
``mamba_paged_step``, so the block-paged engine's single-token step is
*bitwise* the dense decode step — the conformance suite relies on it.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import dense_init
from .config import ModelConfig


def mamba_params(key, cfg: ModelConfig, dtype):
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm.d_state
    dc, dtr = cfg.ssm.d_conv, cfg.dt_rank
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[1], (dc, di), dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * N), dtype=dtype),
        "dt_proj": dense_init(ks[3], (dtr, di), dtype=dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))).astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dtype=dtype),
    }


def _conv_taps(xp, w, b, T):
    """Depthwise causal conv over a left-extended input.

    xp: (B, dc-1+T, di) — the dc-1 tokens of history followed by the T
    new tokens; w: (dc, di).  Returns (B, T, di).  The unrolled tap sum
    (dc is 4) avoids conv layout shuffles on TPU, and — because prefill,
    dense decode, and the paged step all add taps in this exact order —
    keeps the three paths bitwise consistent per token.
    """
    dc = w.shape[0]
    out = sum(xp[:, i: i + T, :] * w[i][None, None, :] for i in range(dc))
    return out + b[None, None, :]


def _causal_conv(x, w, b):
    """Depthwise causal conv from zero history.  x: (B,S,di); w: (dc,di)."""
    dc = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    return _conv_taps(xp, w, b, x.shape[1])


def _ssm_inputs(p, cfg: ModelConfig, xs):
    """xs: (B,S,di) post-conv.  Returns dt (B,S,di), Bc, Cc (B,S,N)."""
    N, dtr = cfg.ssm.d_state, cfg.dt_rank
    proj = xs @ p["x_proj"]
    dt_in, Bc, Cc = jnp.split(proj, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])
    return dt, Bc, Cc


def _ssm_step(h, dt_t, x_t, b_t, c_t, A):
    """One float32 recurrence step: h' = exp(dt A) h + dt B x; y = C h'.

    Shared verbatim by ``selective_scan``, ``mamba_decode``, and
    ``mamba_paged_step`` so every serving path advances the state with
    bitwise-identical arithmetic.
    """
    decay = jnp.exp(dt_t[..., None] * A[None])       # (B,di,N)
    drive = (dt_t * x_t)[..., None] * b_t[:, None, :]
    h = decay * h + drive
    y_t = jnp.einsum("bdn,bn->bd", h, c_t)
    return h, y_t


def selective_scan(dt, Bc, Cc, xs, A, D, h0=None, *, use_kernel: bool = False,
                   chunk_size: int = 256, remat: bool = False,
                   unroll: bool = False):
    """h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t h_t + D x_t.

    dt, xs: (B,S,di); Bc, Cc: (B,S,N); A: (di,N).  Returns (y, h_last).

    Memory design: y is produced *inside* the time scan (never a stacked
    (B,S,di,N) state tensor), time runs in checkpointed chunks so the
    backward pass recomputes one chunk at a time — the pure-XLA analogue
    of the Pallas `ssm_scan` kernel (used when ``use_kernel``).
    """
    if use_kernel:
        from ..kernels.ssm_scan import ops as kops
        return kops.selective_scan(dt, Bc, Cc, xs, A, D, h0)
    B_, S, di = xs.shape
    N = Bc.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B_, di, N), jnp.float32)

    ct = min(chunk_size, S) if chunk_size else S
    if unroll:  # bound HLO size: at most 8 unrolled chunk bodies
        ct = max(ct, -(-S // 8))
    nc = -(-S // ct)
    pad = nc * ct - S
    dt32 = dt.astype(jnp.float32)
    xs32 = xs.astype(jnp.float32)
    Bc32 = Bc.astype(jnp.float32)
    Cc32 = Cc.astype(jnp.float32)
    if pad:  # dt=0 on padding => identity decay, zero drive
        dt32 = jnp.pad(dt32, ((0, 0), (0, pad), (0, 0)))
        xs32 = jnp.pad(xs32, ((0, 0), (0, pad), (0, 0)))
        Bc32 = jnp.pad(Bc32, ((0, 0), (0, pad), (0, 0)))
        Cc32 = jnp.pad(Cc32, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(a):  # (B, S', ...) -> (nc, B, ct, ...)
        return jnp.moveaxis(a.reshape(B_, nc, ct, *a.shape[2:]), 1, 0)

    xs_c = (to_chunks(dt32), to_chunks(xs32), to_chunks(Bc32), to_chunks(Cc32))

    def chunk_body(h, xs_):
        dt_c, x_c, b_c, c_c = xs_

        def step(h, t_):
            return _ssm_step(h, *t_, A)

        h, y_c = jax.lax.scan(
            step, h, (jnp.moveaxis(dt_c, 1, 0), jnp.moveaxis(x_c, 1, 0),
                      jnp.moveaxis(b_c, 1, 0), jnp.moveaxis(c_c, 1, 0)))
        return h, jnp.moveaxis(y_c, 0, 1)                     # (B,ct,di)

    if unroll:
        h, ys = h0, []
        for i in range(nc):
            h, y_c = chunk_body(h, jax.tree.map(lambda a: a[i], xs_c))
            ys.append(y_c)
        h_last, y = h, jnp.stack(ys, 0)
    else:
        body = chunk_body
        if remat:
            body = jax.checkpoint(chunk_body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        h_last, y = jax.lax.scan(body, h0, xs_c)
    y = jnp.moveaxis(y, 0, 1).reshape(B_, nc * ct, di)[:, :S]
    y = y + D[None, None] * xs.astype(jnp.float32)
    return y.astype(xs.dtype), h_last


def mamba_forward(p, cfg: ModelConfig, x, *, use_kernel: bool = False,
                  chunk_size: int = 256, remat: bool = False,
                  unroll: bool = False):
    """Training/prefill.  x: (B,S,d) -> (y, (conv_state, ssm_state))."""
    from .sharding import constrain
    di, dc = cfg.d_inner, cfg.ssm.d_conv
    xz = x @ p["in_proj"]
    xz = constrain(xz, ("pod", "data"), None, "model")
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_tail = xs[:, -(dc - 1):, :]                              # decode seed
    xs = jax.nn.silu(_causal_conv(xs, p["conv_w"], p["conv_b"]))
    dt, Bc, Cc = _ssm_inputs(p, cfg, xs)
    A = -jnp.exp(p["A_log"])
    y, h_last = selective_scan(dt, Bc, Cc, xs, A, p["D"],
                               use_kernel=use_kernel, chunk_size=chunk_size,
                               remat=remat, unroll=unroll)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], (conv_tail, h_last)


def mamba_paged_step(p, cfg: ModelConfig, x, conv_state, ssm_state, t_valid):
    """Advance each row by up to T tokens from carried per-row state.

    x: (B,T,d); conv_state: (B,dc-1,di); ssm_state: (B,di,N); t_valid:
    (B,) int32 — row ``b`` consumes only its first ``t_valid[b]``
    tokens: its state stops advancing there and outputs past it are
    garbage the caller must ignore.  One function covers block-paged
    decode (T=1) and chunked prefill (T=chunk) for the serving engine;
    per-token arithmetic is ``_conv_taps``/``_ssm_step``, the same ops
    in the same order as ``mamba_forward``'s scan, so a chunked prefill
    replays the dense prefill recurrence exactly.
    """
    di, dc = cfg.d_inner, cfg.ssm.d_conv
    B, T, _ = x.shape
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                             # (B,T,di)
    xp = jnp.concatenate([conv_state.astype(xs.dtype), xs], axis=1)
    # next conv window: the dc-1 inputs ending at each row's own valid
    # length (stream position t_valid-1 lives at xp index t_valid+dc-2)
    idx = t_valid[:, None] + jnp.arange(dc - 1, dtype=jnp.int32)[None, :]
    new_conv_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    xs = jax.nn.silu(_conv_taps(xp, p["conv_w"], p["conv_b"], T))
    dt, Bc, Cc = _ssm_inputs(p, cfg, xs)
    A = -jnp.exp(p["A_log"])
    if T == 1:
        # megastep fast path: the serving engine's decode-burst body is
        # T=1 by construction — skip the scan machinery, apply the same
        # _ssm_step once (bitwise identical to the scan's single step)
        h_new, y0 = _ssm_step(ssm_state, dt[:, 0].astype(jnp.float32),
                              xs[:, 0].astype(jnp.float32),
                              Bc[:, 0].astype(jnp.float32),
                              Cc[:, 0].astype(jnp.float32), A)
        h_last = jnp.where((t_valid > 0)[:, None, None], h_new, ssm_state)
        y = y0[:, None]                                           # (B,1,di)
    else:
        seq = (jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
               jnp.moveaxis(xs.astype(jnp.float32), 1, 0),
               jnp.moveaxis(Bc.astype(jnp.float32), 1, 0),
               jnp.moveaxis(Cc.astype(jnp.float32), 1, 0),
               jnp.arange(T, dtype=jnp.int32))

        def step(h, t_):
            dt_t, x_t, b_t, c_t, t = t_
            h_new, y_t = _ssm_step(h, dt_t, x_t, b_t, c_t, A)
            h = jnp.where((t < t_valid)[:, None, None], h_new, h)
            return h, y_t

        h_last, ys = jax.lax.scan(step, ssm_state, seq)
        y = jnp.moveaxis(ys, 0, 1)                                # (B,T,di)
    y = y + p["D"][None, None] * xs.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], (new_conv_state, h_last)


def mamba_decode(p, cfg: ModelConfig, x, conv_state, ssm_state):
    """One token.  x: (B,1,d); conv_state: (B,dc-1,di); ssm_state: (B,di,N).

    The T=1 case of ``mamba_paged_step`` — sharing the implementation is
    what makes the paged engine's decode bitwise equal to the dense one.
    """
    ones = jnp.ones((x.shape[0],), jnp.int32)
    return mamba_paged_step(p, cfg, x, conv_state, ssm_state, ones)
