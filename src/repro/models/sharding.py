"""Parameter/activation PartitionSpec rules (megatron TP + FSDP + EP).

Axes:
  * "model" — tensor parallel: attention heads, FFN hidden, vocab, experts
  * "data" (+ "pod" when multi-pod) — batch / FSDP shard of the non-TP dim

Rules are matched against the flattened param path; scan-stacked leaves
(under ``blocks/``) get a leading ``None`` for the period dim.
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# (regex on path, spec WITHOUT the stacked-leading-None)
# dp = FSDP axis name tuple; tp = "model"
def _rules(dp):
    return [
        # embeddings / lm head: vocab on tp, d_model on dp (FSDP)
        (r"embed$", P("model", dp)),
        (r"lm_head$", P(dp, "model")),
        (r"pos_emb$", P(None, dp)),
        # attention (GQA)
        (r"attn/w[qkv]$", P(dp, "model")),
        (r"attn/wo$", P("model", dp)),
        (r"attn/b[qkv]$", P("model")),
        # MLA
        (r"attn/wdq$", P(dp, None)),
        (r"attn/wuq$", P(None, "model")),
        (r"attn/wdkv$", P(dp, None)),
        (r"attn/wkr$", P(dp, None)),
        (r"attn/wuk$", P(None, "model")),
        (r"attn/wuv$", P(None, "model")),
        # dense MLP
        (r"mlp/w_gate$", P(dp, "model")),
        (r"mlp/w_up$", P(dp, "model")),
        (r"mlp/w_down$", P("model", dp)),
        # MoE (expert parallel over tp; FSDP over d inside each expert)
        (r"moe/router$", P(dp, None)),
        (r"moe/router_bias$", P()),
        (r"moe/w_gate$", P("model", dp, None)),
        (r"moe/w_up$", P("model", dp, None)),
        (r"moe/w_down$", P("model", None, dp)),
        (r"moe/shared/w_gate$", P(dp, "model")),
        (r"moe/shared/w_up$", P(dp, "model")),
        (r"moe/shared/w_down$", P("model", dp)),
        # mamba (shard d_inner on tp)
        (r"mamba/in_proj$", P(dp, "model")),
        (r"mamba/conv_w$", P(None, "model")),
        (r"mamba/conv_b$", P("model")),
        (r"mamba/x_proj$", P("model", None)),
        (r"mamba/dt_proj$", P(None, "model")),
        (r"mamba/dt_bias$", P("model")),
        (r"mamba/A_log$", P("model", None)),
        (r"mamba/D$", P("model")),
        (r"mamba/out_proj$", P("model", dp)),
        # xlstm (shard heads / d_inner on tp)
        (r"(mlstm|slstm)/up$", P(dp, "model")),
        (r"mlstm/w[qkv]$", P("model", None)),
        (r"mlstm/w_if$", P("model", None)),
        (r"mlstm/b_if$", P()),
        (r"slstm/W$", P("model", None)),
        # slstm R: H (4) not divisible by model axis -> replicate
        (r"slstm/b$", P()),
        (r"(mlstm|slstm)/down$", P("model", dp)),
        # mtp projection
        (r"mtp/proj$", P(dp, None)),
    ]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _axis_size(axis, axis_sizes):
    if axis is None or not axis_sizes:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= axis_sizes.get(a, 1)
        return n
    return axis_sizes.get(axis, 1)


def _filter_divisible(parts, shape, axis_sizes):
    """Drop mesh axes from dims they don't divide evenly (pjit argument
    shardings require divisibility; e.g. whisper's vocab 51865)."""
    if not axis_sizes:
        return parts
    out = []
    for i, a in enumerate(parts):
        if a is not None and shape[i] % _axis_size(a, axis_sizes) != 0:
            out.append(None)
        else:
            out.append(a)
    return tuple(out)


def param_specs(params, dp=("data",), axis_sizes=None):
    """PartitionSpec pytree matching ``params``.

    If "model" is part of ``dp`` (flat data parallelism), TP placements
    collapse into the FSDP axis: any "model" entry in a rule is dropped.
    """
    flat_dp = "model" in dp
    dp_axis = dp if len(dp) > 1 else dp[0]
    rules = _rules(dp_axis)

    def spec_of(path, leaf):
        s = _path_str(path)
        stacked = "blocks/" in s or s.startswith("blocks")
        for pat, spec in rules:
            if re.search(pat, s):
                parts = tuple(spec)
                if flat_dp:
                    parts = tuple(None if a == "model" else a for a in parts)
                if stacked:
                    parts = (None,) + parts
                # pad/trim to leaf rank
                parts = parts[: leaf.ndim] + (None,) * max(leaf.ndim - len(parts), 0)
                parts = _filter_divisible(parts, leaf.shape, axis_sizes)
                return P(*parts)
        # default: replicate (norm scales, biases, small tables)
        return P(*((None,) * leaf.ndim)) if leaf.ndim else P()

    return jax.tree_util.tree_map_with_path(spec_of, params)


def cache_specs(cache, dp=("data",), shard_seq_when_batch1: bool = True,
                axis_sizes=None):
    """KV/state caches: batch over dp; heads over model; for batch-1
    long-context, the cache *sequence* dim shards over dp instead."""
    flat_dp = "model" in dp
    dp_axis = dp if len(dp) > 1 else dp[0]

    def spec_of(path, leaf):
        s = _path_str(path)
        stacked = "blocks/" in s or s.startswith("blocks")
        lead = (None,) if stacked else ()
        name = s.rsplit("/", 1)[-1]
        if leaf.ndim == 0:
            return P()
        batch = leaf.shape[len(lead)] if leaf.ndim > len(lead) else 1
        if name in ("k", "v"):          # (B, C, KV, hd)
            # KV head counts (2..8) don't divide the 16-way model axis;
            # shard head_dim instead (always a multiple of 16) — decode
            # scores then psum over the model axis.
            if batch == 1 and shard_seq_when_batch1:
                spec = (None, dp_axis, None, "model")
            else:
                spec = (dp_axis, None, None, "model")
        elif name in ("c", "kr"):        # MLA latents (B, C, r)
            spec = (dp_axis, None, None) if batch > 1 or not shard_seq_when_batch1 \
                else (None, dp_axis, None)
        elif name == "conv":             # (B, dc-1, di)
            spec = (dp_axis, None, "model")
        elif name == "ssm":              # (B, di, N)
            spec = (dp_axis, "model", None)
        elif name in ("C",):             # mlstm (B,H,dk,dv): H=4 too small
            spec = (dp_axis, None, None, None)
        elif name in ("n",):
            spec = (dp_axis, None) + (None,) * (leaf.ndim - len(lead) - 2)
        elif name in ("m",):
            spec = (dp_axis,) + (None,) * (leaf.ndim - len(lead) - 1)
        elif name in ("h", "cs", "ns", "ms"):  # slstm (B, di)
            spec = (dp_axis, "model")
        elif name in ("cross_k", "cross_v"):   # whisper (B, T_enc, KV, hd)
            spec = (dp_axis, None, None, "model")
        else:
            spec = (dp_axis,) + (None,) * (leaf.ndim - len(lead) - 1)
        spec = lead + spec
        if flat_dp:
            spec = tuple(None if a == "model" else a for a in spec)
        spec = spec[: leaf.ndim] + (None,) * max(leaf.ndim - len(spec), 0)
        spec = _filter_divisible(spec, leaf.shape, axis_sizes)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_of, cache)


def paged_cache_specs(cache, axis_sizes=None):
    """PartitionSpec pytree for the serving engine's *paged* pool.

    Unlike the dense decode cache (``cache_specs``), the paged layout
    has no batch axis to data-shard: attention leaves are a single
    shared pool ``(num_blocks, block_size, KV, hd)`` addressed by
    host-side page tables, and recurrent slabs are ``(num_slots, ...)``
    addressed by host-side slot ids.  The block/slot axis must stay
    **replicated** — every device needs every page resident so a slot's
    page table works unchanged wherever its blocks landed — and tensor
    parallelism shards the *feature* dims on "model": head_dim for KV
    (KV head counts are too small to divide a large model axis),
    d_inner for mamba/xLSTM slab state.  Periodic stacked leaves (scan
    over layers) carry a leading replicated period dim.
    """

    def spec_of(path, leaf):
        s = _path_str(path)
        stacked = "blocks/" in s or s.startswith("blocks")
        lead = (None,) if stacked else ()
        name = s.rsplit("/", 1)[-1]
        rank = leaf.ndim - len(lead)
        if leaf.ndim == 0 or rank <= 0:
            return P(*((None,) * leaf.ndim))
        if name in ("k", "v"):           # (nb, bs, KV, hd): shard head_dim
            spec = (None, None, None, "model")
        elif name in ("k_scale", "v_scale"):
            # int8 pools' per-row scales (nb, bs, KV): head_dim is
            # already reduced away, and KV head counts are too small to
            # shard — replicate (a few bytes per block)
            spec = (None, None, None)
        elif name == "conv":             # (ns, dc-1, di)
            spec = (None, None, "model")
        elif name == "ssm":              # (ns, di, d_state)
            spec = (None, "model", None)
        elif name in ("h", "cs", "ns", "ms"):  # slstm (ns, di)
            spec = (None, "model")
        else:                            # mlstm C/n/m (head dims too small)
            spec = (None,) * rank
        spec = lead + spec
        spec = spec[: leaf.ndim] + (None,) * max(leaf.ndim - len(spec), 0)
        spec = _filter_divisible(spec, leaf.shape, axis_sizes)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_of, cache)


def _context_mesh():
    """The mesh installed by ``with mesh:`` (None outside a context)."""
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and m.axis_names:
            return m
    except Exception:  # noqa: BLE001
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:  # noqa: BLE001
        pass
    return None


def constrain(x, *spec_parts):
    """with_sharding_constraint if a concrete mesh context is active."""
    try:
        mesh = _context_mesh()
        if mesh is None:
            return x
        names = set(mesh.axis_names)
        flat = []
        for p in spec_parts:
            if p is None:
                flat.append(None)
            elif isinstance(p, tuple):
                kept = tuple(q for q in p if q in names)
                flat.append(kept if kept else None)
            else:
                flat.append(p if p in names else None)
        return jax.lax.with_sharding_constraint(x, P(*flat))
    except Exception:  # noqa: BLE001 — no mesh context: no-op
        return x
