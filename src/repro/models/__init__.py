"""Model zoo: build any assigned architecture from its ModelConfig."""
from __future__ import annotations

from .config import MLAConfig, ModelConfig, MoEConfig, SSMConfig, smoke_variant
from .transformer import TransformerLM
from .encdec import EncDecLM


def build_model(cfg: ModelConfig, **opts):
    """Returns a model object with init/apply/loss/prefill/decode_step."""
    if cfg.family == "audio" or cfg.n_enc_layers:
        return EncDecLM(cfg, **opts)
    return TransformerLM(cfg, **opts)


__all__ = ["ModelConfig", "MLAConfig", "MoEConfig", "SSMConfig",
           "smoke_variant", "build_model", "TransformerLM", "EncDecLM"]
