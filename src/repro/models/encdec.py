"""Whisper-style encoder–decoder [arXiv:2212.04356].

The audio frontend (mel spectrogram + 2×conv) is a STUB per the assigned
carve-out: the encoder consumes precomputed frame embeddings
(B, enc_seq, d_model) from ``frontends.audio_embeds``.  Everything after
that — sinusoidal encoder positions, bidirectional encoder stack, causal
decoder with cross-attention, tied LM head — is implemented.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as A
from .common import dense_init, dtype_of, embed_init, make_norm
from .config import ModelConfig
from .mlp import mlp_forward, mlp_params
from .sharding import constrain


def _sinusoid(length: int, d: int):
    pos = np.arange(length)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * dim / d))
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=1),
                       jnp.float32)


class EncDecLM:
    def __init__(self, cfg: ModelConfig, *, attn_impl: str = "auto",
                 use_kernels: bool = False, remat: bool = False,
                 unroll: bool = False, **_):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.remat = remat
        self.unroll = unroll

    def _stack_loop(self, body, x, blocks, n):
        """scan-over-layers, or Python loop when unroll (true HLO cost)."""
        import jax as _jax
        if self.unroll:
            ys = []
            for i in range(n):
                x, y = body(x, _jax.tree.map(lambda a: a[i], blocks))
                ys.append(y)
            if ys and ys[0] is not None:
                return x, _jax.tree.map(lambda *a: jnp.stack(a, 0), *ys)
            return x, None
        fn = _jax.checkpoint(body) if (self.remat and not self.unroll) else body
        return _jax.lax.scan(fn, x, blocks)

    def _impl(self, S):
        if self.attn_impl != "auto":
            return self.attn_impl
        return "chunked" if S > 2048 else "naive"

    # -- params ---------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = dtype_of(cfg.param_dtype)
        norm_params, _ = make_norm(cfg.norm)
        ks = jax.random.split(key, 6)

        def enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {"norm1": norm_params(cfg.d_model, dtype),
                    "attn": A.gqa_params(k1, cfg, dtype),
                    "norm2": norm_params(cfg.d_model, dtype),
                    "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)}

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"norm1": norm_params(cfg.d_model, dtype),
                    "attn": A.gqa_params(k1, cfg, dtype),
                    "norm_x": norm_params(cfg.d_model, dtype),
                    "xattn": A.gqa_params(k2, cfg, dtype),
                    "norm2": norm_params(cfg.d_model, dtype),
                    "mlp": mlp_params(k3, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)}

        return {
            "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
            "dec_pos": embed_init(ks[1], (cfg.max_seq, cfg.d_model), dtype),
            "enc_blocks": jax.vmap(enc_layer)(
                jax.random.split(ks[2], cfg.n_enc_layers)),
            "enc_norm": norm_params(cfg.d_model, dtype),
            "dec_blocks": jax.vmap(dec_layer)(
                jax.random.split(ks[3], cfg.n_layers)),
            "final_norm": norm_params(cfg.d_model, dtype),
        }

    # -- encoder ------------------------------------------------------------------
    def encode(self, params, frames):
        """frames: (B, T_enc, d) stub embeddings -> encoder states."""
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        T = frames.shape[1]
        x = frames.astype(dtype_of(cfg.compute_dtype))
        x = x + _sinusoid(T, cfg.d_model).astype(x.dtype)[None]
        x = constrain(x, ("pod", "data"), None, None)
        impl = self._impl(T)

        def body(x, p):
            h = norm(p["norm1"], x)
            x = x + A.gqa_forward(p["attn"], cfg, h,
                                  jnp.zeros(x.shape[:2], jnp.int32),
                                  causal=False, impl=impl)
            x = x + mlp_forward(p["mlp"], cfg.mlp_act, norm(p["norm2"], x))
            return x, None

        x, _ = self._stack_loop(body, x, params["enc_blocks"],
                                self.cfg.n_enc_layers)
        return norm(params["enc_norm"], x)

    # -- decoder ------------------------------------------------------------------
    def _dec_embed(self, params, tokens, pos0: int = 0):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        S = tokens.shape[1]
        pos_tab = params["dec_pos"]
        assert pos0 + S <= pos_tab.shape[0], \
            f"decoder pos table too small ({pos_tab.shape[0]} < {pos0 + S})"
        pe = pos_tab[pos0: pos0 + S]
        x = (x + pe[None]).astype(dtype_of(cfg.compute_dtype))
        return constrain(x, ("pod", "data"), None, None)

    def _dec_layer_full(self, p, x, enc, impl):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        B, S, _ = x.shape
        pos = jnp.zeros((B, S), jnp.int32)  # rope=none for whisper
        h = norm(p["norm1"], x)
        x = x + A.gqa_forward(p["attn"], cfg, h, pos, causal=True, impl=impl)
        h = norm(p["norm_x"], x)
        # cross attention: q from decoder, k/v from encoder states
        q, _, _ = A._project_qkv(p["xattn"], cfg, h)
        _, k, v = A._project_qkv(p["xattn"], cfg, enc)
        y = A.naive_attention(q, k, v, causal=False)
        x = x + y.reshape(B, S, -1) @ p["xattn"]["wo"]
        x = x + mlp_forward(p["mlp"], cfg.mlp_act, norm(p["norm2"], x))
        return x

    def apply(self, params, tokens, extra_embeds=None, positions=None):
        """Training forward.  extra_embeds = encoder frames (B,T_enc,d)."""
        cfg = self.cfg
        assert extra_embeds is not None, "enc-dec needs frontend frames"
        enc = self.encode(params, extra_embeds)
        x = self._dec_embed(params, tokens, 0)
        impl = self._impl(tokens.shape[1])

        def body(x, p):
            return self._dec_layer_full(p, x, enc, impl), None

        x, _ = self._stack_loop(body, x, params["dec_blocks"], cfg.n_layers)
        _, norm = make_norm(cfg.norm)
        h = norm(params["final_norm"], x)
        logits = h @ params["embed"].T.astype(h.dtype)  # tied head
        return constrain(logits, ("pod", "data"), None, "model"), \
            jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, aux = self.apply(params, batch["tokens"],
                                 batch.get("extra_embeds"))
        from .transformer import softmax_xent
        return softmax_xent(logits, batch["labels"]) + aux

    # -- serving ---------------------------------------------------------------------
    def init_cache(self, batch: int, capacity: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        L = cfg.n_layers
        return {
            "k": jnp.zeros((L, batch, capacity, kv, hd), dtype),
            "v": jnp.zeros((L, batch, capacity, kv, hd), dtype),
            "cross_k": jnp.zeros((L, batch, cfg.enc_seq, kv, hd), dtype),
            "cross_v": jnp.zeros((L, batch, cfg.enc_seq, kv, hd), dtype),
        }

    def prefill(self, params, tokens, capacity: int, extra_embeds=None,
                cache_dtype=jnp.bfloat16):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        enc = self.encode(params, extra_embeds)
        x = self._dec_embed(params, tokens, 0)
        B, S = tokens.shape
        impl = self._impl(S)
        pos = jnp.zeros((B, S), jnp.int32)

        def body(x, p):
            h = norm(p["norm1"], x)
            y, (k, v) = A.gqa_prefill(p["attn"], cfg, h, pos, impl=impl)
            x = x + y
            h = norm(p["norm_x"], x)
            q, _, _ = A._project_qkv(p["xattn"], cfg, h)
            _, ck, cv = A._project_qkv(p["xattn"], cfg, enc)
            y = A.naive_attention(q, ck, cv, causal=False)
            x = x + y.reshape(B, S, -1) @ p["xattn"]["wo"]
            x = x + mlp_forward(p["mlp"], cfg.mlp_act, norm(p["norm2"], x))
            from .transformer import _seed_cache
            return x, {"k": _seed_cache(k, capacity, cache_dtype, 0),
                       "v": _seed_cache(v, capacity, cache_dtype, 0),
                       "cross_k": ck.astype(cache_dtype),
                       "cross_v": cv.astype(cache_dtype)}

        if self.unroll:
            sts = []
            for i in range(cfg.n_layers):
                x, st = body(x, jax.tree.map(lambda a: a[i], params["dec_blocks"]))
                sts.append(st)
            cache = jax.tree.map(lambda *a: jnp.stack(a, 0), *sts)
        else:
            x, cache = jax.lax.scan(body, x, params["dec_blocks"])
        h = norm(params["final_norm"], x[:, -1:])
        logits = (h @ params["embed"].T.astype(h.dtype))[:, 0]
        return logits, cache

    def decode_step(self, params, cache, token, pos):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        x = self._dec_embed(params, token, pos) if isinstance(pos, int) else \
            self._dec_embed_dyn(params, token, pos)
        B = token.shape[0]

        def body(x, xs):
            p, cc = xs
            h = norm(p["norm1"], x)
            y, k, v = A.gqa_decode(p["attn"], cfg, h, cc["k"], cc["v"], pos)
            x = x + y
            h = norm(p["norm_x"], x)
            q, _, _ = A._project_qkv(p["xattn"], cfg, h)
            y = A.naive_attention(q, cc["cross_k"], cc["cross_v"], causal=False)
            x = x + y.reshape(B, 1, -1) @ p["xattn"]["wo"]
            x = x + mlp_forward(p["mlp"], cfg.mlp_act, norm(p["norm2"], x))
            return x, {"k": k, "v": v, "cross_k": cc["cross_k"],
                       "cross_v": cc["cross_v"]}

        if self.unroll:
            sts = []
            for i in range(cfg.n_layers):
                x, st = body(x, jax.tree.map(
                    lambda a: a[i], (params["dec_blocks"], cache)))
                sts.append(st)
            cache = jax.tree.map(lambda *a: jnp.stack(a, 0), *sts)
        else:
            x, cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
        h = norm(params["final_norm"], x)
        logits = (h @ params["embed"].T.astype(h.dtype))[:, 0]
        return logits, cache

    def _dec_embed_dyn(self, params, tokens, pos):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        pe = jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], jnp.minimum(pos, params["dec_pos"].shape[0] - 1),
            1, axis=0)
        x = (x + pe[None]).astype(dtype_of(cfg.compute_dtype))
        return constrain(x, ("pod", "data"), None, None)
