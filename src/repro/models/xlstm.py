"""xLSTM blocks — sLSTM (scalar memory, recurrent) + mLSTM (matrix memory)
[arXiv:2405.04517].

mLSTM is parallelizable (no hidden-to-hidden weights): we implement the
stabilized recurrent form via `lax.scan` for training/prefill and a
single-step update for decode.  State per layer: C (B,H,dk,dv),
n (B,H,dk), m (B,H) — constant size, so xlstm runs long_500k natively.

sLSTM has true recurrence (R matrices); it scans over time with
block-diagonal per-head recurrent weights.  State: (h, c, n, m) each (B,di).

Both blocks carry their own up/down projections (the assigned config has
d_ff = 0: no separate FFN).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init
from .config import ModelConfig

EXPAND = 2  # projection factor for both block types


def _dims(cfg: ModelConfig):
    di = EXPAND * cfg.d_model
    H = cfg.n_heads
    dh = di // H
    return di, H, dh


def mlstm_params(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di, H, dh = _dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "up": dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "wq": dense_init(ks[1], (di, di), dtype=dtype),
        "wk": dense_init(ks[2], (di, di), dtype=dtype),
        "wv": dense_init(ks[3], (di, di), dtype=dtype),
        "w_if": dense_init(ks[4], (di, 2 * H), dtype=jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), jnp.full((H,), 3.0)]).astype(jnp.float32),
        "down": dense_init(ks[5], (di, d), dtype=dtype),
    }


def _mlstm_step(carry, xs):
    C, n, m = carry                                     # (B,H,dk,dv),(B,H,dk),(B,H)
    q_t, k_t, v_t, li_t, lf_t = xs
    m_new = jnp.maximum(lf_t + m, li_t)
    i_t = jnp.exp(li_t - m_new)                         # (B,H)
    f_t = jnp.exp(lf_t + m - m_new)
    C = f_t[..., None, None] * C + i_t[..., None, None] * \
        (k_t[..., :, None] * v_t[..., None, :])
    n = f_t[..., None] * n + i_t[..., None] * k_t
    num = jnp.einsum("bhkv,bhk->bhv", C, q_t)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)), 1.0)
    h_t = num / den[..., None]
    return (C, n, m_new), h_t


def mlstm_forward(p, cfg: ModelConfig, x, *, chunk_size: int = 64,
                  remat: bool = False, unroll: bool = False):
    """x: (B,S,d) -> (y, state).  Stabilized recurrence in checkpointed
    time chunks: backward never holds more than one chunk of per-step
    (B,H,dk,dv) matrix-memory residuals."""
    B, S, d = x.shape
    di, H, dh = _dims(cfg)
    up = x @ p["up"]
    xi, z = jnp.split(up, 2, axis=-1)                      # (B,S,di)
    q = (xi @ p["wq"]).reshape(B, S, H, dh) / np.sqrt(dh)
    k = (xi @ p["wk"]).reshape(B, S, H, dh) / np.sqrt(dh)
    v = (xi @ p["wv"]).reshape(B, S, H, dh)
    gates = xi.astype(jnp.float32) @ p["w_if"] + p["b_if"]  # (B,S,2H)
    log_i, log_f = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])

    ct = min(chunk_size, S)
    if unroll:  # bound HLO size: at most 8 unrolled chunk bodies
        ct = max(ct, -(-S // 8))
    nc = -(-S // ct)
    pad = nc * ct - S

    def prep(a):  # (B,S,...) -> (nc, ct, B, ...)
        if pad:  # log_f=0 => f=1 identity; log_i=-inf => i=0 no write
            fill = 0.0 if a is log_f else (NEG_PAD if a is log_i else 0.0)
            a = jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2),
                        constant_values=fill)
        a = jnp.moveaxis(a, 1, 0).reshape(nc, ct, B, *a.shape[2:])
        return a

    xs = tuple(prep(a) for a in
               (q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), log_i, log_f))

    def chunk_body(carry, xs_c):
        carry, h_c = jax.lax.scan(_mlstm_step, carry, xs_c)
        return carry, h_c                                   # (ct,B,H,dv)

    if unroll:
        st, hs = _mlstm_init(B, H, dh), []
        for i in range(nc):
            st, h_c = chunk_body(st, jax.tree.map(lambda a: a[i], xs))
            hs.append(h_c)
        state, h_seq = st, jnp.concatenate(hs, 0)
    else:
        body = chunk_body
        if remat:
            body = jax.checkpoint(chunk_body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        state, h_seq = jax.lax.scan(body, _mlstm_init(B, H, dh), xs)
        h_seq = h_seq.reshape(nc * ct, B, H, dh)
    h = jnp.moveaxis(h_seq.reshape(nc * ct, B, H, dh), 0, 1)[:, :S]
    h = h.reshape(B, S, di).astype(x.dtype)
    y = (h * jax.nn.silu(z)) @ p["down"]
    return y, state


NEG_PAD = -1e30


def _mlstm_init(B, H, dh):
    return (jnp.zeros((B, H, dh, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.zeros((B, H), jnp.float32))


def _mask_carry(new, old, keep):
    """Per-row select over a tuple-of-arrays carry: row ``b`` advances
    iff ``keep[b]`` (shared by the paged steps of both block types)."""
    return tuple(jnp.where(keep.reshape((-1,) + (1,) * (a.ndim - 1)), a, b)
                 for a, b in zip(new, old))


def mlstm_paged_step(p, cfg: ModelConfig, x, state, t_valid):
    """Advance each row by up to T tokens from carried per-row state.

    x: (B,T,d); state: (C, n, m) float32; t_valid: (B,) int32 — row
    ``b`` consumes only its first ``t_valid[b]`` tokens (outputs past
    that are garbage the caller ignores).  Runs the same ``_mlstm_step``
    as ``mlstm_forward``'s scan, so chunked prefill replays the dense
    prefill recurrence exactly; ``mlstm_decode`` is the T=1 case.
    """
    B, T, d = x.shape
    di, H, dh = _dims(cfg)
    up = x @ p["up"]
    xi, z = jnp.split(up, 2, axis=-1)                      # (B,T,di)
    q = (xi @ p["wq"]).reshape(B, T, H, dh) / np.sqrt(dh)
    k = (xi @ p["wk"]).reshape(B, T, H, dh) / np.sqrt(dh)
    v = (xi @ p["wv"]).reshape(B, T, H, dh)
    gates = xi.astype(jnp.float32) @ p["w_if"] + p["b_if"]  # (B,T,2H)
    log_i, log_f = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])
    if T == 1:
        # megastep fast path: decode-burst bodies are T=1 — one direct
        # _mlstm_step, bitwise identical to the length-1 scan
        new, h0 = _mlstm_step(state, (q[:, 0].astype(jnp.float32),
                                      k[:, 0].astype(jnp.float32),
                                      v[:, 0].astype(jnp.float32),
                                      log_i[:, 0], log_f[:, 0]))
        state = _mask_carry(new, state, t_valid > 0)
        h = h0.reshape(B, T, di).astype(x.dtype)
    else:
        seq = (jnp.moveaxis(q.astype(jnp.float32), 1, 0),
               jnp.moveaxis(k.astype(jnp.float32), 1, 0),
               jnp.moveaxis(v.astype(jnp.float32), 1, 0),
               jnp.moveaxis(log_i, 1, 0), jnp.moveaxis(log_f, 1, 0),
               jnp.arange(T, dtype=jnp.int32))

        def step(carry, xs_):
            t = xs_[-1]
            new, h_t = _mlstm_step(carry, xs_[:-1])
            return _mask_carry(new, carry, t < t_valid), h_t

        state, hs = jax.lax.scan(step, state, seq)
        h = jnp.moveaxis(hs, 0, 1).reshape(B, T, di).astype(x.dtype)
    y = (h * jax.nn.silu(z)) @ p["down"]
    return y, state


def mlstm_decode(p, cfg: ModelConfig, x, state):
    """One token: x (B,1,d).  The T=1 case of ``mlstm_paged_step``."""
    ones = jnp.ones((x.shape[0],), jnp.int32)
    return mlstm_paged_step(p, cfg, x, state, ones)


def slstm_params(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di, H, dh = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "up": dense_init(ks[0], (d, di), dtype=dtype),
        "W": dense_init(ks[1], (di, 4 * di), dtype=dtype),
        # block-diagonal recurrent weights: (H, dh, 4*dh)
        "R": dense_init(ks[2], (H, dh, 4 * dh), in_axis=1, dtype=jnp.float32),
        "b": jnp.zeros((4 * di,), jnp.float32),
        "down": dense_init(ks[3], (di, d), dtype=dtype),
    }


def _slstm_step(p, cfg: ModelConfig, wx_t, state):
    """wx_t: (B,4di) precomputed W x_t.  state: (h,c,n,m) each (B,di)."""
    di, H, dh = _dims(cfg)
    h, c, n, m = state
    rh = jnp.einsum("bhk,hkg->bhg", h.reshape(-1, H, dh), p["R"]).reshape(-1, 4 * di)
    pre = wx_t.astype(jnp.float32) + rh + p["b"]
    zi, ii, fi, oi = jnp.split(pre, 4, axis=-1)
    z_t = jnp.tanh(zi)
    o_t = jax.nn.sigmoid(oi)
    li = ii                                   # log-space input gate
    lf = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(lf + m, li)
    i_t = jnp.exp(li - m_new)
    f_t = jnp.exp(lf + m - m_new)
    c_new = f_t * c + i_t * z_t
    n_new = f_t * n + i_t
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new)


def slstm_forward(p, cfg: ModelConfig, x, *, chunk_size: int = 64,
                  remat: bool = False, unroll: bool = False):
    B, S, d = x.shape
    di, H, dh = _dims(cfg)
    xi = x @ p["up"]
    wx = xi @ p["W"]                                        # (B,S,4di)
    state0 = tuple(jnp.zeros((B, di), jnp.float32) for _ in range(4))

    ct = min(chunk_size, S)
    if unroll:  # bound HLO size
        ct = max(ct, -(-S // 8))
    nc = -(-S // ct)
    pad = nc * ct - S
    if pad:
        wx = jnp.pad(wx, ((0, 0), (0, pad), (0, 0)))
    wx_c = jnp.moveaxis(wx, 1, 0).reshape(nc, ct, B, 4 * di)

    def chunk_body(state, wx_chunk):
        def step(st, wx_t):
            new = _slstm_step(p, cfg, wx_t, st)
            return new, new[0]
        state, h_c = jax.lax.scan(step, state, wx_chunk)
        return state, h_c

    if unroll:
        st, hs = state0, []
        for i in range(nc):
            st, h_c = chunk_body(st, wx_c[i])
            hs.append(h_c)
        state, h_seq = st, jnp.concatenate(hs, 0)
    else:
        body = chunk_body
        if remat:
            body = jax.checkpoint(chunk_body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        state, h_seq = jax.lax.scan(body, state0, wx_c)
        h_seq = h_seq.reshape(nc * ct, B, di)
    h = jnp.moveaxis(h_seq.reshape(nc * ct, B, di), 0, 1)[:, :S].astype(x.dtype)
    return h @ p["down"], state


def slstm_paged_step(p, cfg: ModelConfig, x, state, t_valid):
    """Advance each row by up to T tokens from carried per-row state.

    x: (B,T,d); state: (h, c, n, m) each (B,di) float32; t_valid: (B,)
    int32 caps how many of the T tokens are real per row.  Same
    ``_slstm_step`` as ``slstm_forward``; ``slstm_decode`` is T=1.
    """
    B, T, _ = x.shape
    di, H, dh = _dims(cfg)
    xi = x @ p["up"]
    wx = xi @ p["W"]                                        # (B,T,4di)
    if T == 1:
        # megastep fast path: one direct _slstm_step for decode bursts
        new = _slstm_step(p, cfg, wx[:, 0], state)
        state = _mask_carry(new, state, t_valid > 0)
        h = new[0][:, None].astype(x.dtype)                 # (B,1,di)
    else:
        seq = (jnp.moveaxis(wx, 1, 0), jnp.arange(T, dtype=jnp.int32))

        def step(st, xs_):
            wx_t, t = xs_
            new = _slstm_step(p, cfg, wx_t, st)
            return _mask_carry(new, st, t < t_valid), new[0]

        state, hs = jax.lax.scan(step, state, seq)
        h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)          # (B,T,di)
    return h @ p["down"], state


def slstm_decode(p, cfg: ModelConfig, x, state):
    """One token: x (B,1,d).  The T=1 case of ``slstm_paged_step``."""
    ones = jnp.ones((x.shape[0],), jnp.int32)
    return slstm_paged_step(p, cfg, x, state, ones)
