"""Feed-forward blocks: swiglu / squared-ReLU / gelu."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, mlp_act_fn
from .config import ModelConfig


def mlp_params(key, d_model: int, d_ff: int, act: str, dtype):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype=dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype=dtype),
    }


def mlp_forward(p, act: str, x):
    if act == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return mlp_act_fn(act)(x @ p["w_up"]) @ p["w_down"]
