"""Modality frontend STUBS (the one allowed carve-out).

The audio path (mel spectrogram + conv codec) and vision path (ViT/SigLIP
encoder + projector) are not implemented; ``input_specs()`` supplies
precomputed frame/patch embeddings of the correct shape, and these
helpers synthesize deterministic fake embeddings for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def audio_frames_shape(cfg: ModelConfig, batch: int):
    """Whisper: post-conv frame embeddings (B, enc_seq, d_model)."""
    return (batch, cfg.enc_seq, cfg.d_model)


def vision_patches_shape(cfg: ModelConfig, batch: int):
    """VLM: projected patch embeddings (B, vision_seq, d_model)."""
    return (batch, cfg.vision_seq, cfg.d_model)


def fake_audio_frames(cfg: ModelConfig, batch: int, key=None, dtype=jnp.float32):
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.random.normal(key, audio_frames_shape(cfg, batch), dtype) * 0.02


def fake_vision_patches(cfg: ModelConfig, batch: int, key=None, dtype=jnp.float32):
    key = key if key is not None else jax.random.PRNGKey(1)
    return jax.random.normal(key, vision_patches_shape(cfg, batch), dtype) * 0.02
