"""Shared layers: norms, rotary embeddings, activations, init helpers.

Params are plain pytrees (nested dicts of jnp arrays); every function is
functional and jit/scan-friendly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# -- initializers -----------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# -- norms --------------------------------------------------------------------

def rmsnorm_params(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_params(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mean) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dt)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_params, rmsnorm
    if kind == "layernorm":
        return layernorm_params, layernorm
    raise ValueError(kind)


# -- rotary ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, rope_pct: float = 1.0):
    """Inverse frequencies for the rotated fraction of head_dim."""
    rot = int(head_dim * rope_pct)
    rot -= rot % 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float64) / rot))
    return jnp.asarray(inv, jnp.float32), rot


def apply_rope(x, positions, theta: float, rope_pct: float = 1.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    inv, rot = rope_freqs(head_dim, theta, rope_pct)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, rot/2)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., seq, 1, rot/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y.astype(x.dtype), x_pass], axis=-1)


def apply_mrope(x, positions3, theta: float, sections: Tuple[int, int, int]):
    """Qwen2-VL M-RoPE [arXiv:2409.12191].

    x: (..., seq, heads, head_dim); positions3: (3, ..., seq) — separate
    temporal/height/width position streams.  Frequency bands are split
    into three sections, each rotated by its own position stream.
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))
    inv = jnp.asarray(inv, jnp.float32)  # (half,)
    # static one-hot: which of the 3 position streams drives each band
    sec_id = np.concatenate([np.full((s,), i) for i, s in enumerate(sections)])
    onehot = jnp.asarray(np.eye(3)[sec_id].T, jnp.float32)  # (3, half)
    pos = positions3.astype(jnp.float32)                     # (3, ..., seq)
    ang_all = pos[..., :, None] * inv                        # (3, ..., seq, half)
    bshape = (3,) + (1,) * (ang_all.ndim - 2) + (half,)
    ang = (ang_all * onehot.reshape(bshape)).sum(axis=0)     # (..., seq, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape).astype(x.dtype)


# -- activations -------------------------------------------------------------------

def relu2(x):
    r = jax.nn.relu(x)
    return r * r


def mlp_act_fn(name: str):
    return {"relu2": relu2, "gelu": jax.nn.gelu,
            "silu": jax.nn.silu}[name]
