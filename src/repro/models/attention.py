"""Attention: GQA/MHA (RoPE / M-RoPE / partial rotary / sliding window)
and DeepSeek-V3 MLA (multi-head latent attention).

Three execution paths:
  * naive    — materialize (q, k) score matrix (small seq)
  * chunked  — lax.scan over KV blocks with online softmax (memory-bounded;
               the pure-XLA analogue of flash attention for long prefill)
  * decode   — single query token against a KV cache (full or ring-buffer
               sliding window)

Shapes: hidden (B, S, D); q/k/v (B, S, H, hd).  GQA repeats KV heads by
group broadcast (no materialized repeat: einsum over grouped heads).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import apply_mrope, apply_rope, dense_init
from .config import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------

def gqa_params(key, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (h * hd, d), in_axis=0, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def mla_params(key, cfg: ModelConfig, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wdq": dense_init(ks[0], (d, m.q_lora_rank), dtype=dtype),
        "q_norm": {"scale": jnp.ones((m.q_lora_rank,), dtype)},
        "wuq": dense_init(ks[1], (m.q_lora_rank, h * qk_head), dtype=dtype),
        "wdkv": dense_init(ks[2], (d, m.kv_lora_rank), dtype=dtype),
        "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), dtype)},
        "wkr": dense_init(ks[3], (d, m.qk_rope_head_dim), dtype=dtype),
        "wuk": dense_init(ks[4], (m.kv_lora_rank, h * m.qk_nope_head_dim), dtype=dtype),
        "wuv": dense_init(ks[5], (m.kv_lora_rank, h * m.v_head_dim), dtype=dtype),
        "wo": dense_init(ks[6], (h * m.v_head_dim, d), dtype=dtype),
    }


# ---------------------------------------------------------------------------
# score computation cores
# ---------------------------------------------------------------------------

def _grouped_scores(q, k):
    """q: (B,S,H,hd) k: (B,T,KV,hd) -> (B, KV, G, S, T) with H = KV*G."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k)


def _grouped_out(probs, v):
    """probs: (B,KV,G,S,T) v: (B,T,KV,hd) -> (B,S,H,hd)."""
    B, KV, G, S, T = probs.shape
    hd = v.shape[-1]
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, KV * G, hd)


def naive_attention(q, k, v, *, causal: bool, q_offset=0,
                    kv_len: Optional[jnp.ndarray] = None,
                    sliding_window: int = 0, scale: Optional[float] = None):
    """Full-score attention.  q:(B,S,H,hd) k,v:(B,T,KV,hd_{k,v})."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(q.shape[-1])
    scores = _grouped_scores(q * scale, k).astype(jnp.float32)  # (B,KV,G,S,T)
    q_pos = jnp.arange(S)[:, None] + q_offset
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos <= q_pos
    if sliding_window:
        mask &= k_pos > q_pos - sliding_window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    if kv_len is not None:  # (B,) valid lengths in cache
        valid = k_pos < kv_len[:, None]
        scores = jnp.where(valid[:, None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return _grouped_out(probs, v)


def chunked_attention(q, k, v, *, causal: bool, chunk: int = 1024,
                      sliding_window: int = 0, scale: Optional[float] = None,
                      remat: bool = False, unroll: bool = False,
                      acc_bf16: bool = False, probs_bf16: bool = False):
    """Two-level blockwise attention (flash-style, pure XLA).

    Outer scan over q chunks, inner scan over kv chunks with online
    softmax.  With ``remat`` the q-chunk body is checkpointed so the
    backward pass never holds more than one q-chunk's score blocks —
    the memory shape that makes 32k-seq training lower within HBM.
    (On real TPU the Pallas flash kernel replaces this; this is the
    GSPMD-partitionable fallback with the same asymptotics.)
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    hv = v.shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    ck = min(chunk, T)
    cq = min(chunk, S)
    if unroll:  # bound HLO size: at most 8x8 unrolled blocks
        ck = max(ck, -(-T // 8))
        cq = max(cq, -(-S // 8))
    nk = -(-T // ck)
    nq = -(-S // cq)
    pad_k = nk * ck - T
    pad_q = nq * cq - S
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kc = jnp.moveaxis(k.reshape(B, nk, ck, KV, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, ck, KV, hv), 1, 0)
    qg = jnp.moveaxis((q * scale).reshape(B, nq, cq, KV, G, hd), 1, 0)

    def q_body(carry, q_xs):
        qb, iq = q_xs                                # (B,cq,KV,G,hd)
        q_pos = iq * cq + jnp.arange(cq)[:, None]

        def kv_body(inner, xs):
            m, l, acc = inner
            kb, vb, ik = xs                          # (B,ck,KV,hd)
            s = jnp.einsum("bskgd,btkd->bkgst", qb, kb).astype(jnp.float32)
            k_pos = ik * ck + jnp.arange(ck)[None, :]
            mask = k_pos < T
            if causal:
                mask &= k_pos <= q_pos
            if sliding_window:
                mask &= k_pos > q_pos - sliding_window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            if probs_bf16:  # halve softmax-prob HBM traffic (doc'd error)
                p = p.astype(jnp.bfloat16)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1).astype(jnp.float32)
            pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(vb.dtype), vb)
            acc_new = acc * corr[..., None].astype(acc.dtype) + \
                pv.astype(acc.dtype)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, cq), jnp.float32)
        acc0 = jnp.zeros((B, KV, G, cq, hv),
                         jnp.bfloat16 if acc_bf16 else v.dtype)
        if unroll:
            inner = (m0, l0, acc0)
            for ik in range(nk):
                inner, _ = kv_body(inner, (kc[ik], vc[ik], jnp.int32(ik)))
            m, l, acc = inner
        else:
            (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, acc0),
                                          (kc, vc, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return carry, jnp.moveaxis(out.reshape(B, KV * G, cq, hv), 1, 2)

    if unroll:
        # Python-loop variant: every block lands in the HLO, so
        # cost_analysis counts true totals (XLA visits while bodies once)
        outs = []
        for iq in range(nq):
            _, o = q_body(0.0, (qg[iq], jnp.int32(iq)))
            outs.append(o)
        out = jnp.concatenate(outs, axis=1)
        return out[:, :S]
    body = q_body
    if remat:
        body = jax.checkpoint(q_body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(body, 0.0, (qg, jnp.arange(nq)))  # (nq,B,cq,H,hv)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * cq, H, hv)
    return out[:, :S]


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Per-layer stacked cache.  k/v: (L, B, C, KV, hd); length: (B,)."""
    k: jnp.ndarray
    v: jnp.ndarray
    length: jnp.ndarray           # current fill (same for all b in batch)
    window: int = 0               # 0 = full cache; else ring buffer size

    @property
    def capacity(self) -> int:
        return self.k.shape[2]


def init_kv_cache(cfg: ModelConfig, n_attn_layers: int, batch: int,
                  capacity: int, window: int = 0, dtype=jnp.bfloat16,
                  k_dim: Optional[int] = None, v_dim: Optional[int] = None,
                  kv_heads: Optional[int] = None) -> KVCache:
    kv = kv_heads if kv_heads is not None else cfg.n_kv_heads
    hd_k = k_dim if k_dim is not None else cfg.resolved_head_dim
    hd_v = v_dim if v_dim is not None else cfg.resolved_head_dim
    cap = min(capacity, window) if window else capacity
    return KVCache(
        k=jnp.zeros((n_attn_layers, batch, cap, kv, hd_k), dtype),
        v=jnp.zeros((n_attn_layers, batch, cap, kv, hd_v), dtype),
        length=jnp.zeros((batch,), jnp.int32),
        window=window,
    )


def cache_update_one(k_cache, v_cache, k_new, v_new, pos, window: int):
    """Insert one token at `pos` (ring index if window).  k_cache:(B,C,KV,hd)."""
    cap = k_cache.shape[1]
    idx = jnp.mod(pos, cap) if window else pos
    k_cache = _dynamic_token_update(k_cache, k_new, idx)
    v_cache = _dynamic_token_update(v_cache, v_new, idx)
    return k_cache, v_cache


def _dynamic_token_update(cache, new, idx):
    """cache: (B, C, KV, hd); new: (B, 1, KV, hd); idx scalar."""
    return jax.lax.dynamic_update_slice(
        cache, new.astype(cache.dtype), (0, idx, 0, 0))


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     scale: Optional[float] = None):
    """One-token attention over the cache.

    q: (B,1,H,hd); caches (B,C,KV,hd); pos = tokens generated so far
    (the new token's position).  With a ring buffer (window), all slots
    are valid once pos >= capacity; masking handles partial fill.
    """
    B, _, H, hd = q.shape
    C = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    scores = _grouped_scores(q * scale, k_cache).astype(jnp.float32)  # (B,KV,G,1,C)
    slot = jnp.arange(C)[None, :]
    n_valid = jnp.minimum(pos + 1, C)  # includes the just-inserted token
    valid = slot < n_valid
    scores = jnp.where(valid[:, None, None, None] if valid.ndim == 2
                       else valid[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    return _grouped_out(probs, v_cache)


def paged_gather(storage, page_table):
    """Materialize per-slot logical views of a shared block pool.

    storage: (num_blocks, block_size, ...); page_table: (B, P) int32.
    Returns (B, P * block_size, ...) — row ``b`` holds slot ``b``'s
    logical positions 0..P*bs-1 in order.  Entries past the slot's true
    length are whatever the pointed-to blocks hold; callers mask by
    length.
    """
    B, P = page_table.shape
    g = storage[page_table]                       # (B, P, bs, ...)
    return g.reshape((B, P * storage.shape[1]) + storage.shape[2:])


def paged_scatter(storage, vals, page_table, lengths, t_valid):
    """Write per-slot token runs into the shared block pool.

    storage: (num_blocks, block_size, ...); vals: (B, T, ...).
    Token ``t`` of row ``b`` lands at logical position ``lengths[b] + t``
    iff ``t < t_valid[b]``; invalid tokens (padding, inactive slots,
    positions past the page table) are dropped, not written.
    """
    nb, bs = storage.shape[:2]
    B, T = vals.shape[:2]
    P = page_table.shape[1]
    pos = lengths[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]   # (B,T)
    page = pos // bs
    block = jnp.take_along_axis(page_table, jnp.clip(page, 0, P - 1), axis=1)
    ok = (jnp.arange(T)[None, :] < t_valid[:, None]) & (page < P)
    flat_idx = jnp.where(ok, block * bs + pos % bs, nb * bs)  # OOB -> drop
    flat = storage.reshape((nb * bs,) + storage.shape[2:])
    flat = flat.at[flat_idx.reshape(-1)].set(
        vals.astype(storage.dtype).reshape((B * T,) + vals.shape[2:]),
        mode="drop")
    return flat.reshape(storage.shape)


def paged_attention(q, k_gath, v_gath, positions, *,
                    scale: Optional[float] = None):
    """Per-slot attention over page-table-gathered caches.

    q: (B,T,H,hd) — T query tokens per slot; k_gath/v_gath: (B,C,KV,hd)
    logical views from ``paged_gather``; positions: (B,T) each query's
    absolute position in its own sequence.  Query t of slot b attends
    to logical slots l <= positions[b, t] — per-slot causal masking with
    true lengths, no shared-position left padding.  For T=1 this is the
    same einsum/mask/softmax chain as ``decode_attention``, so paged
    and dense decode agree bit-for-bit on identical cache content.
    """
    hd = q.shape[-1]
    C = k_gath.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    scores = _grouped_scores(q * scale, k_gath).astype(jnp.float32)  # (B,KV,G,T,C)
    mask = jnp.arange(C)[None, None, :] <= positions[:, :, None]     # (B,T,C)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_gath.dtype)
    return _grouped_out(probs, v_gath)


def gqa_paged_step(p, cfg: ModelConfig, x, k_store, v_store, page_table,
                   lengths, t_valid):
    """Process T tokens per slot through a block-paged KV cache.

    x: (B,T,D); k_store/v_store: (num_blocks, block_size, KV, hd) shared
    pools; page_table: (B,P) int32; lengths: (B,) tokens already cached
    per slot; t_valid: (B,) how many of this call's T tokens are real
    for each slot (0 = slot idle this step).

    One function covers both serving phases: decode is T=1/t_valid=1,
    chunked prefill is T=chunk with t_valid up to chunk — slots may mix
    phases freely within a call.  K/V are scattered through the page
    table *before* the gather, so in-chunk causal self-attention falls
    out of the position mask.  Returns (out (B,T,D), k_store, v_store).
    """
    from .sharding import constrain
    B, T, _ = x.shape
    positions = lengths[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(p, cfg, x)
    q, k = _rope_qk(cfg, q, k, positions)
    k_store = paged_scatter(k_store, k, page_table, lengths, t_valid)
    v_store = paged_scatter(v_store, v, page_table, lengths, t_valid)
    # under a mesh: the pool stays block-replicated / head_dim-sharded
    # through the scatter, so XLA never resorts to resharding the whole
    # pool around the donated update (no-op without a mesh context)
    k_store = constrain(k_store, None, None, None, "model")
    v_store = constrain(v_store, None, None, None, "model")
    out = paged_attention(q, paged_gather(k_store, page_table),
                          paged_gather(v_store, page_table), positions)
    return out.reshape(B, T, -1) @ p["wo"], k_store, v_store


# ---------------------------------------------------------------------------
# int8 block-quantized paged KV
# ---------------------------------------------------------------------------

QUANT_EPS = 1e-8


def quantize_kv(x):
    """Symmetric per-row-per-head int8 quantization over head_dim.

    x: (..., hd) float -> (q (..., hd) int8, scale (...) float32) with
    ``dequant = q.astype(f32) * scale[..., None]``.  The scale is
    amax/127 over the head_dim axis only, so every (token, head) row
    carries its own scale: a row written once is never requantized when
    later tokens land in the same block (incremental prefill/decode
    appends stay exact per-row, which a whole-block scale could not
    guarantee).
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, QUANT_EPS) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale):
    """Inverse of ``quantize_kv``: (..., hd) int8 × (...) f32 -> f32."""
    return q.astype(jnp.float32) * scale[..., None]


def gqa_paged_step_quant(p, cfg: ModelConfig, x, k_store, v_store,
                         k_scale, v_scale, page_table, lengths, t_valid):
    """Int8 variant of ``gqa_paged_step``.

    k_store/v_store: (num_blocks, block_size, KV, hd) int8 pools;
    k_scale/v_scale: (num_blocks, block_size, KV) float32 per-row scale
    pools that ride the same page-table indirection.  New K/V rows are
    quantized post-RoPE and scattered alongside their scales; the gather
    dequantizes back to f32 before the (unchanged) ``paged_attention``
    core, so the only numeric difference from the f32 path is the int8
    round-trip on cached keys/values.  Returns
    (out, k_store, v_store, k_scale, v_scale).
    """
    from .sharding import constrain
    B, T, _ = x.shape
    positions = lengths[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(p, cfg, x)
    q, k = _rope_qk(cfg, q, k, positions)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    k_store = paged_scatter(k_store, kq, page_table, lengths, t_valid)
    v_store = paged_scatter(v_store, vq, page_table, lengths, t_valid)
    # scale rows (B,T,KV) take the same flat-scatter path — paged_scatter
    # is generic over trailing dims, so the (nb,bs,KV) scale pool is just
    # a storage with one fewer trailing axis
    k_scale = paged_scatter(k_scale, ks, page_table, lengths, t_valid)
    v_scale = paged_scatter(v_scale, vs, page_table, lengths, t_valid)
    k_store = constrain(k_store, None, None, None, "model")
    v_store = constrain(v_store, None, None, None, "model")
    k_scale = constrain(k_scale, None, None, None)
    v_scale = constrain(v_scale, None, None, None)
    k_gath = dequantize_kv(paged_gather(k_store, page_table),
                           paged_gather(k_scale, page_table))
    v_gath = dequantize_kv(paged_gather(v_store, page_table),
                           paged_gather(v_scale, page_table))
    out = paged_attention(q, k_gath, v_gath, positions)
    return (out.reshape(B, T, -1) @ p["wo"],
            k_store, v_store, k_scale, v_scale)


# ---------------------------------------------------------------------------
# full attention layers (projection + rope + core) — GQA
# ---------------------------------------------------------------------------

def _project_qkv(p, cfg: ModelConfig, x):
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return (q.reshape(B, S, h, hd), k.reshape(B, S, kv, hd),
            v.reshape(B, S, kv, hd))


def _rope_qk(cfg: ModelConfig, q, k, positions):
    if cfg.rope == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_pct)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_pct)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k


def gqa_forward(p, cfg: ModelConfig, x, positions, *, causal: bool = True,
                impl: str = "naive", chunk: int = 1024, remat: bool = False,
                unroll: bool = False, acc_bf16: bool = False,
                probs_bf16: bool = False):
    """Training/prefill forward.  positions: (B,S) or (3,B,S) for mrope."""
    q, k, v = _project_qkv(p, cfg, x)
    q, k = _rope_qk(cfg, q, k, positions)
    if impl == "chunked":
        out = chunked_attention(q, k, v, causal=causal, chunk=chunk,
                                sliding_window=cfg.sliding_window, remat=remat,
                                unroll=unroll, acc_bf16=acc_bf16,
                                probs_bf16=probs_bf16)
    else:
        out = naive_attention(q, k, v, causal=causal,
                              sliding_window=cfg.sliding_window)
    B, S = x.shape[:2]
    return out.reshape(B, S, -1) @ p["wo"]


def gqa_prefill(p, cfg: ModelConfig, x, positions, *, impl: str = "chunked",
                chunk: int = 1024, unroll: bool = False,
                probs_bf16: bool = False):
    """Prefill: returns (out, (k, v)) for cache seeding."""
    q, k, v = _project_qkv(p, cfg, x)
    q, k = _rope_qk(cfg, q, k, positions)
    if impl == "chunked":
        out = chunked_attention(q, k, v, causal=True, chunk=chunk,
                                sliding_window=cfg.sliding_window,
                                unroll=unroll, probs_bf16=probs_bf16)
    else:
        out = naive_attention(q, k, v, causal=True,
                              sliding_window=cfg.sliding_window)
    B, S = x.shape[:2]
    return out.reshape(B, S, -1) @ p["wo"], (k, v)


def gqa_decode(p, cfg: ModelConfig, x, k_cache, v_cache, pos):
    """Decode one token.  x: (B,1,D); pos: scalar position of this token.

    Returns (out, k_cache, v_cache) with the new token inserted.
    """
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
    q, k, v = _project_qkv(p, cfg, x)
    q, k = _rope_qk(cfg, q, k, positions)
    window = cfg.sliding_window
    cap = k_cache.shape[1]
    idx = jnp.mod(pos, cap) if window else pos
    k_cache = _dynamic_token_update(k_cache, k, idx)
    v_cache = _dynamic_token_update(v_cache, v, idx)
    out = decode_attention(q, k_cache, v_cache, pos, window=window)
    B = x.shape[0]
    return out.reshape(B, 1, -1) @ p["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3) — cache holds (c_kv, k_rope): the latent compression
# ---------------------------------------------------------------------------

def _mla_qkv(p, cfg: ModelConfig, x, positions):
    m = cfg.mla
    h = cfg.n_heads
    B, S, _ = x.shape
    from .common import rmsnorm
    cq = rmsnorm(p["q_norm"], x @ p["wdq"])
    q = (cq @ p["wuq"]).reshape(B, S, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = rmsnorm(p["kv_norm"], x @ p["wdkv"])          # (B,S,rank)
    k_rope = apply_rope((x @ p["wkr"]).reshape(B, S, 1, m.qk_rope_head_dim),
                        positions, cfg.rope_theta)        # shared single head
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand_kv(p, cfg: ModelConfig, c_kv):
    m = cfg.mla
    B, T = c_kv.shape[:2]
    h = cfg.n_heads
    k_nope = (c_kv @ p["wuk"]).reshape(B, T, h, m.qk_nope_head_dim)
    v = (c_kv @ p["wuv"]).reshape(B, T, h, m.v_head_dim)
    return k_nope, v


def mla_forward(p, cfg: ModelConfig, x, positions, *, causal: bool = True,
                impl: str = "naive", chunk: int = 1024, remat: bool = False,
                unroll: bool = False, acc_bf16: bool = False,
                probs_bf16: bool = False):
    m = cfg.mla
    B, S, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    k_nope, v = _mla_expand_kv(p, cfg, c_kv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, k_nope.shape[:3] + (m.qk_rope_head_dim,))],
                        axis=-1)
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    if impl == "chunked":
        out = chunked_attention(q, k, v, causal=causal, chunk=chunk,
                                scale=scale, remat=remat, unroll=unroll,
                                acc_bf16=acc_bf16, probs_bf16=probs_bf16)
    else:
        out = naive_attention(q, k, v, causal=causal, scale=scale)
    return out.reshape(B, S, -1) @ p["wo"]


def mla_prefill(p, cfg: ModelConfig, x, positions, *, impl: str = "chunked",
                chunk: int = 1024, unroll: bool = False,
                probs_bf16: bool = False):
    """Returns (out, (c_kv, k_rope)) — the latent cache (the MLA memory win)."""
    m = cfg.mla
    B, S, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    k_nope, v = _mla_expand_kv(p, cfg, c_kv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, k_nope.shape[:3] + (m.qk_rope_head_dim,))],
                        axis=-1)
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    if impl == "chunked":
        out = chunked_attention(q, k, v, causal=True, chunk=chunk, scale=scale,
                                unroll=unroll, probs_bf16=probs_bf16)
    else:
        out = naive_attention(q, k, v, causal=True, scale=scale)
    return out.reshape(B, S, -1) @ p["wo"], (c_kv, k_rope.reshape(B, S, m.qk_rope_head_dim))


def mla_decode(p, cfg: ModelConfig, x, c_cache, kr_cache, pos,
               absorb: bool = False):
    """Decode with latent cache.  c_cache: (B,C,rank); kr_cache: (B,C,rd).

    ``absorb=True`` folds W_uk into the query (q_nope @ W_uk^T per head)
    so attention runs directly in the latent space — the beyond-paper
    decode optimization; ``False`` re-expands K from the cache (naive).
    """
    m = cfg.mla
    h = cfg.n_heads
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    c_cache = jax.lax.dynamic_update_slice(
        c_cache, c_kv.astype(c_cache.dtype), (0, pos, 0))
    kr_cache = jax.lax.dynamic_update_slice(
        kr_cache, k_rope.reshape(B, 1, m.qk_rope_head_dim).astype(kr_cache.dtype),
        (0, pos, 0))
    C = c_cache.shape[1]
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    slot = jnp.arange(C)[None, :]
    valid = slot <= pos
    if absorb:
        # q_lat: (B,1,h,rank) = q_nope @ W_uk (absorbed)
        wuk = p["wuk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wuk)
        s_lat = jnp.einsum("bshr,btr->bhst", q_lat, c_cache)
        s_rope = jnp.einsum("bshd,btd->bhst", q_rope, kr_cache)
        scores = ((s_lat + s_rope) * scale).astype(jnp.float32)
        scores = jnp.where(valid[:, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(c_cache.dtype)
        o_lat = jnp.einsum("bhst,btr->bshr", probs, c_cache)  # (B,1,h,rank)
        wuv = p["wuv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        out = jnp.einsum("bshr,rhd->bshd", o_lat, wuv)
    else:
        k_nope, v = _mla_expand_kv(p, cfg, c_cache)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_cache[:, :, None, :],
                                      k_nope.shape[:3] + (m.qk_rope_head_dim,))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        scores = (jnp.einsum("bshd,bthd->bhst", q * scale, k)).astype(jnp.float32)
        scores = jnp.where(valid[:, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out.reshape(B, 1, -1) @ p["wo"], c_cache, kr_cache
