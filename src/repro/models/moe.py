"""Mixture-of-Experts: routed top-k + shared experts, expert parallelism.

Routing variants:
  * "softmax"      — softmax over logits, top-k, renormalized (DBRX, Jamba)
  * "sigmoid_bias" — DeepSeek-V3: sigmoid scores, top-k over (score + bias),
                     weights = score/top-sum × routed_scale; the bias is a
                     *non-gradient* balance term (aux-loss-free balancing).

Dispatch is capacity-based gather/scatter (NOT one-hot einsum): HLO FLOPs
then reflect ~active expert compute only (× capacity factor), which keeps
the roofline analysis honest.

Sharding design: routing and dispatch are computed *per sequence* (per
batch row), so every scatter/cumsum stays local to the data shard that
owns the row — no global cumsum across the sharded token dim.  Expert
weights shard over the "model" axis (expert parallelism): the expert
batched-matmul is local per expert shard and the combine scatter-add
reduces over the expert axis, which GSPMD lowers to an all-reduce over
"model" — the TPU-native analogue of GPU MoE all-to-all.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init
from .config import ModelConfig, MoEConfig


def moe_params(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d, de = cfg.d_model, m.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (m.n_experts, d, de), in_axis=1, dtype=dtype),
        "w_up": dense_init(ks[2], (m.n_experts, d, de), in_axis=1, dtype=dtype),
        "w_down": dense_init(ks[3], (m.n_experts, de, d), in_axis=1, dtype=dtype),
    }
    if m.router == "sigmoid_bias":
        p["router_bias"] = jnp.zeros((m.n_experts,), jnp.float32)
    if m.n_shared:
        from .mlp import mlp_params
        p["shared"] = mlp_params(ks[4], d, de * m.n_shared, "swiglu", dtype)
    return p


def route(p, m: MoEConfig, x, use_kernel: bool = False):
    """x: (B,S,d) -> weights (B,S,k), idx (B,S,k), aux_loss scalar."""
    logits = x.astype(jnp.float32) @ p["router"]            # (B,S,E)
    if m.router == "sigmoid_bias":
        scores = jax.nn.sigmoid(logits)
        sel = scores + jax.lax.stop_gradient(p["router_bias"])
        if use_kernel:
            from ..kernels.moe_gating import ops as gops
            _, idx = gops.topk(sel, m.top_k)
        else:
            _, idx = jax.lax.top_k(sel, m.top_k)
        w = jnp.take_along_axis(scores, idx, axis=-1)
        w = w / (w.sum(axis=-1, keepdims=True) + 1e-20) * m.routed_scale
        probs = scores / (scores.sum(-1, keepdims=True) + 1e-20)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        if use_kernel:
            from ..kernels.moe_gating import ops as gops
            w, idx = gops.topk(probs, m.top_k)
        else:
            w, idx = jax.lax.top_k(probs, m.top_k)
        w = w / (w.sum(axis=-1, keepdims=True) + 1e-20)
    # switch-style load-balance aux loss (mean over batch rows).
    # counts via scatter-add, NOT one_hot: a (B,S,k,E) one-hot would be
    # hundreds of GiB at dsv3 train scale.
    B, S, k = idx.shape
    me = probs.mean(axis=(0, 1))                             # (E,)
    counts = jnp.zeros((m.n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    ce = counts / (B * S * k)
    aux = m.n_experts * jnp.sum(me * ce) * m.aux_loss_coef
    return w.astype(x.dtype), idx, aux


def _position_in_expert(flat_idx, E: int):
    """Rank of each assignment within its expert's queue — O(Tk log Tk)
    sort-based (a (Tk, E) one-hot cumsum would be O(Tk*E) memory)."""
    Tk = flat_idx.shape[0]
    order = jnp.argsort(flat_idx, stable=True)               # (Tk,)
    sorted_eid = flat_idx[order]
    group_start = jnp.searchsorted(sorted_eid, jnp.arange(E), side="left")
    pos_sorted = jnp.arange(Tk) - group_start[sorted_eid]
    return jnp.zeros((Tk,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))


def _dispatch_one_row(xf, idx, w, E: int, C: int):
    """Per-sequence dispatch.  xf: (T,d); idx/w: (T,k).  Returns
    (xe (E,C,d), slot (T*k,), keep (T*k,), token_of (T*k,))."""
    T, d = xf.shape
    k = idx.shape[-1]
    flat_idx = idx.reshape(-1)                               # (T*k,)
    pos = _position_in_expert(flat_idx, E)
    keep = pos < C
    slot = jnp.where(keep, flat_idx * C + pos, E * C)        # overflow -> dump row
    token_of = jnp.arange(T * k) // k
    disp = jnp.zeros((E * C + 1, d), xf.dtype).at[slot].set(xf[token_of])
    return disp[: E * C].reshape(E, C, d), slot, keep, token_of


def _combine_one_row(ye, slot, keep, token_of, w, T: int):
    """ye: (E,C,d) -> y (T,d) weighted scatter-add."""
    E, C, d = ye.shape
    ye_flat = jnp.concatenate([ye.reshape(E * C, d),
                               jnp.zeros((1, d), ye.dtype)], axis=0)
    per_slot = ye_flat[jnp.where(keep, slot, E * C)]
    wf = (w.reshape(-1) * keep).astype(per_slot.dtype)
    return jnp.zeros((T, d), per_slot.dtype).at[token_of].add(per_slot * wf[:, None])


def moe_forward(p, cfg: ModelConfig, x, *, capacity_factor: Optional[float] = None,
                use_kernel: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d) -> (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    w, idx, aux = route(p, m, x, use_kernel=use_kernel)      # (B,S,k)
    E, k = m.n_experts, m.top_k
    cf = capacity_factor if capacity_factor is not None else m.capacity_factor
    C = int(np.ceil(S * k / E * cf))
    C = max(min(C, S), 1)

    xe, slot, keep, token_of = jax.vmap(
        lambda xf, i, ww: _dispatch_one_row(xf, i, ww, E, C))(x, idx, w)
    # expert parallelism: dispatch buffers co-shard E with the weights
    from .sharding import constrain
    xe = constrain(xe, ("pod", "data"), "model", None, None)
    # expert FFN (swiglu) batched over (B, E)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", xe, p["w_up"])
    h = constrain(h, ("pod", "data"), "model", None, None)
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"])        # (B,E,C,d)
    ye = constrain(ye, ("pod", "data"), "model", None, None)

    y = jax.vmap(lambda yee, s, kp, t, ww: _combine_one_row(yee, s, kp, t, ww, S)
                 )(ye, slot, keep, token_of, w)
    if m.n_shared:
        from .mlp import mlp_forward
        y = y + mlp_forward(p["shared"], "swiglu", x)
    return y.astype(x.dtype), aux
