"""ModelConfig — one dataclass describes every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims [arXiv:2412.19437]."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_expert: int = 0              # expert FFN hidden dim
    n_shared: int = 0              # shared (always-on) experts
    router: str = "softmax"        # "softmax" | "sigmoid_bias" (dsv3)
    routed_scale: float = 1.0      # dsv3 routed_scaling_factor
    capacity_factor: float = 1.25
    first_dense_layers: int = 0    # dsv3: first 3 layers dense
    layer_period: int = 1          # jamba: MoE every `period` layers
    layer_offset: int = 0
    aux_loss_coef: float = 0.01    # load-balance loss (training)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    # mamba (jamba) [arXiv:2403.19887]
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 = ceil(d_model/16)
    # xlstm [arXiv:2405.04517]
    slstm_every: int = 0           # pattern period for sLSTM blocks; 0 = none


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 = d_model // n_heads
    # blocks / norms / activations
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    mlp_act: str = "swiglu"        # swiglu | relu2 | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    # positions
    rope: str = "rope"             # rope | mrope | none | learned
    rope_theta: float = 10000.0
    rope_pct: float = 1.0          # partial rotary (nemotron/glm 0.5)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # qwen2-vl t/h/w split
    # attention variants
    mla: Optional[MLAConfig] = None
    sliding_window: int = 0        # 0 = full attention
    # mixture of experts
    moe: Optional[MoEConfig] = None
    # ssm / hybrid
    ssm: Optional[SSMConfig] = None
    attn_layer_period: int = 0     # jamba: 1 attn per `period` layers
    attn_layer_offset: int = 0
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0               # stub frontend output length (1500 whisper)
    # multi-token prediction (dsv3)
    mtp_depth: int = 0
    # dsv3: dense-FFN width for the un-scanned prefix layers (0 = d_ff)
    prefix_d_ff: int = 0
    # frontends (stub): number of modality embedding positions for vlm
    vision_seq: int = 0
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # misc
    max_seq: int = 8192            # for learned position tables only
    source: str = ""               # citation

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def dt_rank(self) -> int:
        if self.ssm is None:
            return 0
        return self.ssm.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    def is_attn_layer(self, i: int) -> bool:
        """Hybrid interleave: True if layer i is attention (else SSM)."""
        if self.family != "hybrid":
            return True
        return i % self.attn_layer_period == self.attn_layer_offset

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        if i < self.moe.first_dense_layers:
            return False
        return (i - self.moe.layer_offset) % self.moe.layer_period == 0 \
            if self.moe.layer_period > 1 else True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: <=2 layers, d_model<=512, <=4 experts."""
    n_heads = min(cfg.n_heads, 4)
    # keep GQA ratio alive where possible
    ratio = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
    n_kv = max(n_heads // min(ratio, n_heads), 1)
    d_model = min(cfg.d_model, 256)
    head_dim = min(cfg.resolved_head_dim, 64)
    kw = dict(
        n_layers=2, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        max_seq=256,
        param_dtype="float32", compute_dtype="float32",
    )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                              qk_nope_head_dim=32, qk_rope_head_dim=16,
                              v_head_dim=32)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2),
            d_expert=min(cfg.moe.d_expert or 256, 256),
            n_shared=min(cfg.moe.n_shared, 1),
            first_dense_layers=min(cfg.moe.first_dense_layers, 1))
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=8, d_conv=4, expand=2,
            # keep both xlstm block kinds alive in a 2-layer smoke stack
            slstm_every=2 if cfg.ssm.slstm_every else 0)
    if cfg.family == "hybrid":
        kw["n_layers"] = max(cfg.attn_layer_period, 2)  # one full period
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = 2
        kw["enc_seq"] = min(cfg.enc_seq, 64)
    if cfg.vision_seq:
        kw["vision_seq"] = 16
    if cfg.rope == "mrope":
        half = head_dim // 2
        hw = (half * 3) // 8
        kw["mrope_sections"] = (half - 2 * hw, hw, hw)
    return cfg.replace(**kw)
