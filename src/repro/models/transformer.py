"""Unified decoder-only model covering dense / MoE / hybrid / SSM / VLM.

A config expands to a *layer pattern*: an optional unrolled ``prefix``
(e.g. DeepSeek-V3's first 3 dense layers) plus a repeating ``period`` of
sub-layer descriptors scanned ``n_periods`` times (scan-over-layers keeps
HLO size ~O(period), essential for 61-96 layer dry-runs).

Sub-layer descriptor: (block, mlp) with
  block ∈ {attn, mla, mamba, mlstm, slstm};  mlp ∈ {dense, moe, none}.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as A
from . import mamba as M
from . import xlstm as X
from .common import dense_init, dtype_of, embed_init, make_norm
from .config import ModelConfig
from .mlp import mlp_forward, mlp_params
from .moe import moe_forward, moe_params
from .sharding import constrain

Desc = Tuple[str, str]


def layer_pattern(cfg: ModelConfig) -> Tuple[List[Desc], List[Desc], int]:
    """Returns (prefix_descs, period_descs, n_periods)."""
    if cfg.family in ("dense", "vlm"):
        return [], [("attn", "dense")], cfg.n_layers
    if cfg.family == "moe":
        attn = "mla" if cfg.mla is not None else "attn"
        nd = cfg.moe.first_dense_layers
        prefix = [(attn, "dense")] * nd
        return prefix, [(attn, "moe")], cfg.n_layers - nd
    if cfg.family == "hybrid":
        period = []
        for i in range(cfg.attn_layer_period):
            block = "attn" if cfg.is_attn_layer(i) else "mamba"
            mlp = "moe" if cfg.is_moe_layer(i) else "dense"
            period.append((block, mlp))
        assert cfg.n_layers % cfg.attn_layer_period == 0
        return [], period, cfg.n_layers // cfg.attn_layer_period
    if cfg.family == "ssm":
        every = cfg.ssm.slstm_every or 4
        period = [("mlstm", "none")] * (every - 1) + [("slstm", "none")]
        assert cfg.n_layers % every == 0
        return [], period, cfg.n_layers // every
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# sub-layer init / apply
# ---------------------------------------------------------------------------

def _sublayer_params(key, cfg: ModelConfig, desc: Desc, dtype, dense_ff: int):
    block, mlp = desc
    norm_params, _ = make_norm(cfg.norm)
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": norm_params(cfg.d_model, dtype)}
    if block == "attn":
        p["attn"] = A.gqa_params(ks[0], cfg, dtype)
    elif block == "mla":
        p["attn"] = A.mla_params(ks[0], cfg, dtype)
    elif block == "mamba":
        p["mamba"] = M.mamba_params(ks[0], cfg, dtype)
    elif block == "mlstm":
        p["mlstm"] = X.mlstm_params(ks[0], cfg, dtype)
    elif block == "slstm":
        p["slstm"] = X.slstm_params(ks[0], cfg, dtype)
    if mlp == "dense":
        p["norm2"] = norm_params(cfg.d_model, dtype)
        p["mlp"] = mlp_params(ks[1], cfg.d_model, dense_ff, cfg.mlp_act, dtype)
    elif mlp == "moe":
        p["norm2"] = norm_params(cfg.d_model, dtype)
        p["moe"] = moe_params(ks[1], cfg, dtype)
    return p


def _sublayer_state(cfg: ModelConfig, desc: Desc, batch: int, capacity: int,
                    dtype) -> Optional[Dict[str, jnp.ndarray]]:
    """Decode-time state for one sub-layer (None if stateless)."""
    block, _ = desc
    if block == "attn":
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        cap = min(capacity, cfg.sliding_window) if cfg.sliding_window else capacity
        return {"k": jnp.zeros((batch, cap, kv, hd), dtype),
                "v": jnp.zeros((batch, cap, kv, hd), dtype)}
    if block == "mla":
        m = cfg.mla
        return {"c": jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
                "kr": jnp.zeros((batch, capacity, m.qk_rope_head_dim), dtype)}
    if block == "mamba":
        return {"conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, cfg.d_inner), dtype),
                "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm.d_state), jnp.float32)}
    if block == "mlstm":
        di, H, dh = X._dims(cfg)
        return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
                "n": jnp.zeros((batch, H, dh), jnp.float32),
                "m": jnp.zeros((batch, H), jnp.float32)}
    if block == "slstm":
        di, H, dh = X._dims(cfg)
        return {"h": jnp.zeros((batch, di), jnp.float32),
                "cs": jnp.zeros((batch, di), jnp.float32),
                "ns": jnp.zeros((batch, di), jnp.float32),
                "ms": jnp.zeros((batch, di), jnp.float32)}
    raise ValueError(block)


def _apply_sublayer(p, cfg: ModelConfig, desc: Desc, x, positions, *,
                    attn_impl: str, use_kernels: bool, remat: bool = False,
                    unroll: bool = False, attn_chunk: int = 1024,
                    acc_bf16: bool = False, probs_bf16: bool = False,
                    seq_parallel: bool = False):
    """Training/full-sequence forward.  Returns (x, aux)."""
    block, mlp = desc
    _, norm = make_norm(cfg.norm)
    aux = jnp.zeros((), jnp.float32)
    h = norm(p["norm1"], x)
    if block == "attn":
        y = A.gqa_forward(p["attn"], cfg, h, positions, impl=attn_impl,
                          remat=remat, unroll=unroll, chunk=attn_chunk,
                          acc_bf16=acc_bf16, probs_bf16=probs_bf16)
    elif block == "mla":
        y = A.mla_forward(p["attn"], cfg, h, positions, impl=attn_impl,
                          remat=remat, unroll=unroll, chunk=attn_chunk,
                          acc_bf16=acc_bf16, probs_bf16=probs_bf16)
    elif block == "mamba":
        y, _ = M.mamba_forward(p["mamba"], cfg, h, use_kernel=False,
                               remat=remat, unroll=unroll)
    elif block == "mlstm":
        y, _ = X.mlstm_forward(p["mlstm"], cfg, h, remat=remat, unroll=unroll)
    elif block == "slstm":
        y, _ = X.slstm_forward(p["slstm"], cfg, h, remat=remat, unroll=unroll)
    x = x + y
    # sequence parallelism: keep the residual sharded over "model" on the
    # seq dim between blocks (all-reduce -> reduce-scatter + all-gather)
    seq_spec = "model" if seq_parallel else None
    x = constrain(x, ("pod", "data"), seq_spec, None)
    if mlp != "none":
        h = norm(p["norm2"], x)
        if mlp == "dense":
            x = x + mlp_forward(p["mlp"], cfg.mlp_act, h)
        else:
            y, aux = moe_forward(p["moe"], cfg, h, use_kernel=use_kernels)
            x = x + y
        x = constrain(x, ("pod", "data"), seq_spec, None)
    return x, aux


def _prefill_sublayer(p, cfg: ModelConfig, desc: Desc, x, positions, *,
                      capacity: int, cache_dtype, attn_impl: str,
                      unroll: bool = False, attn_chunk: int = 1024,
                      probs_bf16: bool = False, seq_parallel: bool = False):
    """Full-sequence forward that also emits decode state."""
    block, mlp = desc
    _, norm = make_norm(cfg.norm)
    h = norm(p["norm1"], x)
    B, S, _ = x.shape
    if block == "attn":
        y, (k, v) = A.gqa_prefill(p["attn"], cfg, h, positions, impl=attn_impl,
                                  unroll=unroll, chunk=attn_chunk,
                                  probs_bf16=probs_bf16)
        cap = min(capacity, cfg.sliding_window) if cfg.sliding_window else capacity
        state = {"k": _seed_cache(k, cap, cache_dtype, cfg.sliding_window),
                 "v": _seed_cache(v, cap, cache_dtype, cfg.sliding_window)}
    elif block == "mla":
        y, (c, kr) = A.mla_prefill(p["attn"], cfg, h, positions, impl=attn_impl,
                                   unroll=unroll, chunk=attn_chunk,
                                   probs_bf16=probs_bf16)
        state = {"c": _seed_cache(c, capacity, cache_dtype, 0),
                 "kr": _seed_cache(kr, capacity, cache_dtype, 0)}
    elif block == "mamba":
        y, (conv, ssm) = M.mamba_forward(p["mamba"], cfg, h, unroll=unroll)
        state = {"conv": conv.astype(cache_dtype), "ssm": ssm}
    elif block == "mlstm":
        y, (C, n, m) = X.mlstm_forward(p["mlstm"], cfg, h, unroll=unroll)
        state = {"C": C, "n": n, "m": m}
    elif block == "slstm":
        y, (hh, cc, nn, mm) = X.slstm_forward(p["slstm"], cfg, h, unroll=unroll)
        state = {"h": hh, "cs": cc, "ns": nn, "ms": mm}
    x = x + y
    # NOTE (measured, EXPERIMENTS.md H3): a blanket sharding constraint
    # here acts as a fusion barrier and doubles prefill HBM traffic;
    # constrain only when sequence parallelism actually changes layout.
    if seq_parallel:
        x = constrain(x, ("pod", "data"), "model", None)
    if mlp != "none":
        h = norm(p["norm2"], x)
        if mlp == "dense":
            x = x + mlp_forward(p["mlp"], cfg.mlp_act, h)
        else:
            y, _ = moe_forward(p["moe"], cfg, h)
            x = x + y
        if seq_parallel:
            x = constrain(x, ("pod", "data"), "model", None)
    return x, state


def _seed_cache(seq_kv, capacity: int, dtype, window: int):
    """Embed prefill K/V (B,S,...) into a capacity-C cache buffer.

    For sliding windows keeps the last ``capacity`` tokens (ring order is
    position % capacity, consistent with decode inserts).
    """
    B, S = seq_kv.shape[:2]
    if window and S > capacity:
        # last `capacity` tokens, placed at their ring slots
        tail = seq_kv[:, S - capacity:]
        pos = jnp.arange(S - capacity, S)
        slots = jnp.mod(pos, capacity)
        buf = jnp.zeros((B, capacity) + seq_kv.shape[2:], dtype)
        return buf.at[:, slots].set(tail.astype(dtype))
    if S >= capacity:
        return seq_kv[:, :capacity].astype(dtype)
    pad = [(0, 0), (0, capacity - S)] + [(0, 0)] * (seq_kv.ndim - 2)
    return jnp.pad(seq_kv.astype(dtype), pad)


def _decode_sublayer(p, cfg: ModelConfig, desc: Desc, x, state, pos, *,
                     mla_absorb: bool = False):
    block, mlp = desc
    _, norm = make_norm(cfg.norm)
    h = norm(p["norm1"], x)
    if block == "attn":
        y, k, v = A.gqa_decode(p["attn"], cfg, h, state["k"], state["v"], pos)
        state = {"k": k, "v": v}
    elif block == "mla":
        y, c, kr = A.mla_decode(p["attn"], cfg, h, state["c"], state["kr"], pos,
                                absorb=mla_absorb)
        state = {"c": c, "kr": kr}
    elif block == "mamba":
        y, (conv, ssm) = M.mamba_decode(p["mamba"], cfg, h, state["conv"], state["ssm"])
        state = {"conv": conv, "ssm": ssm}
    elif block == "mlstm":
        y, (C, n, m) = X.mlstm_decode(p["mlstm"], cfg, h, (state["C"], state["n"], state["m"]))
        state = {"C": C, "n": n, "m": m}
    elif block == "slstm":
        y, (hh, cc, nn, mm) = X.slstm_decode(
            p["slstm"], cfg, h, (state["h"], state["cs"], state["ns"], state["ms"]))
        state = {"h": hh, "cs": cc, "ns": nn, "ms": mm}
    x = x + y
    x = constrain(x, ("pod", "data"), None, None)
    if mlp != "none":
        h = norm(p["norm2"], x)
        if mlp == "dense":
            x = x + mlp_forward(p["mlp"], cfg.mlp_act, h)
        else:
            y, _ = moe_forward(p["moe"], cfg, h)
            x = x + y
        x = constrain(x, ("pod", "data"), None, None)
    return x, state


RECURRENT_BLOCKS = ("mamba", "mlstm", "slstm")


def _paged_sublayer(p, cfg: ModelConfig, desc: Desc, x, state, page_table,
                    lengths, t_valid, state_slots):
    """Multi-token step through the paged serving cache.

    Attention blocks read/write the shared block pool through the page
    table; recurrent blocks (mamba/mlstm/slstm) read/write their rows of
    the per-slot **state slabs**: gather by ``state_slots``, zero rows
    whose sequence starts this step (``lengths == 0`` — a slab recycled
    from an evicted request must never leak state into its successor),
    advance by up to ``t_valid`` tokens, scatter back (idle rows are
    dropped, so a stale slab id on an evicted slot cannot clobber the
    slab's new owner).  Mirrors ``_decode_sublayer`` exactly
    (norm/residual/constrain order) so a T=1 paged step is numerically
    identical to a dense decode step on the same cache content.
    """
    block, mlp = desc
    _, norm = make_norm(cfg.norm)
    h = norm(p["norm1"], x)
    if block == "attn":
        if "k_scale" in state:   # int8 block-quantized pool (+ scale pools)
            y, k, v, ks, vs = A.gqa_paged_step_quant(
                p["attn"], cfg, h, state["k"], state["v"],
                state["k_scale"], state["v_scale"],
                page_table, lengths, t_valid)
            state = {"k": k, "v": v, "k_scale": ks, "v_scale": vs}
        else:
            y, k, v = A.gqa_paged_step(p["attn"], cfg, h,
                                       state["k"], state["v"],
                                       page_table, lengths, t_valid)
            state = {"k": k, "v": v}
    else:
        ns = jax.tree.leaves(state)[0].shape[0]
        gathered = jax.tree.map(
            lambda a: a[jnp.clip(state_slots, 0, ns - 1)], state)
        fresh = lengths == 0

        def blank(a):
            return jnp.where(fresh.reshape((-1,) + (1,) * (a.ndim - 1)),
                             jnp.zeros_like(a), a)

        st = jax.tree.map(blank, gathered)
        if block == "mamba":
            y, (conv, ssm) = M.mamba_paged_step(
                p["mamba"], cfg, h, st["conv"], st["ssm"], t_valid)
            new = {"conv": conv, "ssm": ssm}
        elif block == "mlstm":
            y, (C, n, m) = X.mlstm_paged_step(
                p["mlstm"], cfg, h, (st["C"], st["n"], st["m"]), t_valid)
            new = {"C": C, "n": n, "m": m}
        elif block == "slstm":
            y, (hh, cc, nn, mm) = X.slstm_paged_step(
                p["slstm"], cfg, h,
                (st["h"], st["cs"], st["ns"], st["ms"]), t_valid)
            new = {"h": hh, "cs": cc, "ns": nn, "ms": mm}
        else:
            raise ValueError(block)
        idx = jnp.where(t_valid > 0, state_slots, ns)   # idle rows: OOB, drop
        state = jax.tree.map(
            lambda a, b: a.at[idx].set(b.astype(a.dtype), mode="drop"),
            state, new)
    x = x + y
    x = constrain(x, ("pod", "data"), None, None)
    if mlp != "none":
        h = norm(p["norm2"], x)
        if mlp == "dense":
            x = x + mlp_forward(p["mlp"], cfg.mlp_act, h)
        else:
            y, _ = moe_forward(p["moe"], cfg, h)
            x = x + y
        x = constrain(x, ("pod", "data"), None, None)
    return x, state


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------

def make_positions(cfg: ModelConfig, B: int, S: int, offset: int = 0):
    """(B,S) int32, or (3,B,S) for mrope (vision grid then text)."""
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None] + offset, (B, S))
    if cfg.rope != "mrope":
        return pos
    vs = cfg.vision_seq
    if vs == 0 or S <= vs:
        return jnp.broadcast_to(pos[None], (3, B, S))
    # vision prefix: t=0, h=i//g, w=i%g on a sqrt grid; text: shared index
    g = max(int(np.sqrt(vs)), 1)
    vis_i = np.arange(vs)
    t = np.zeros(vs, np.int32)
    hh = (vis_i // g).astype(np.int32)
    ww = (vis_i % g).astype(np.int32)
    text = np.arange(S - vs, dtype=np.int32) + int(np.max(hh)) + 1
    p_t = np.concatenate([t, text])
    p_h = np.concatenate([hh, text])
    p_w = np.concatenate([ww, text])
    pos3 = jnp.asarray(np.stack([p_t, p_h, p_w]), jnp.int32) + offset
    return jnp.broadcast_to(pos3[:, None, :], (3, B, S))


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class TransformerLM:
    def __init__(self, cfg: ModelConfig, *, attn_impl: str = "auto",
                 use_kernels: bool = False, remat: bool = False,
                 mla_absorb: bool = False, unroll: bool = False,
                 attn_chunk: int = 1024, acc_bf16: bool = False,
                 probs_bf16: bool = False, seq_parallel: bool = False):
        self.cfg = cfg
        self.prefix_descs, self.period_descs, self.n_periods = layer_pattern(cfg)
        self.attn_impl = attn_impl
        self.use_kernels = use_kernels
        self.remat = remat
        self.mla_absorb = mla_absorb
        self.unroll = unroll  # Python-loop layers/chunks: true HLO cost totals
        self.attn_chunk = attn_chunk
        self.acc_bf16 = acc_bf16
        self.probs_bf16 = probs_bf16
        self.seq_parallel = seq_parallel

    # -- params -------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = dtype_of(cfg.param_dtype)
        dense_ff = cfg.d_ff
        norm_params, _ = make_norm(cfg.norm)
        k_embed, k_prefix, k_blocks, k_head, k_mtp = jax.random.split(key, 5)
        params: Dict[str, Any] = {
            "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
            "final_norm": norm_params(cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                           dtype=dtype)
        if self.prefix_descs:
            pf = []
            for i, desc in enumerate(self.prefix_descs):
                kk = jax.random.fold_in(k_prefix, i)
                # dsv3 prefix dense layers use the big dense FFN
                ff = cfg.prefix_d_ff or dense_ff
                pf.append(_sublayer_params(kk, cfg, desc, dtype, ff))
            params["prefix"] = pf
        # periodic blocks: vmap init over periods -> stacked leaves
        blocks: Dict[str, Any] = {}
        for j, desc in enumerate(self.period_descs):
            kj = jax.random.fold_in(k_blocks, j)
            keys = jax.random.split(kj, self.n_periods)
            blocks[f"s{j}"] = jax.vmap(
                lambda k: _sublayer_params(k, cfg, desc, dtype, dense_ff))(keys)
        params["blocks"] = blocks
        if cfg.mtp_depth:
            params["mtp"] = {
                "norm_h": norm_params(cfg.d_model, dtype),
                "norm_e": norm_params(cfg.d_model, dtype),
                "proj": dense_init(k_mtp, (2 * cfg.d_model, cfg.d_model), dtype=dtype),
                "layer": _sublayer_params(
                    jax.random.fold_in(k_mtp, 1), cfg,
                    (self.period_descs[0][0], "dense"), dtype,
                    cfg.prefix_d_ff or dense_ff),
            }
        return params

    # -- embedding / head ------------------------------------------------------
    def _embed(self, params, tokens, extra_embeds=None):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(dtype_of(cfg.compute_dtype))
        if extra_embeds is not None:
            # modality stub: overwrite the first vision_seq positions
            vs = extra_embeds.shape[1]
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x[:, vs:]], axis=1)
        return constrain(x, ("pod", "data"), None, None)

    def _head(self, params, x):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        h = norm(params["final_norm"], x)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = h @ w.astype(h.dtype)
        return constrain(logits, ("pod", "data"), None, "model")

    # -- full-sequence forward ----------------------------------------------------
    def apply(self, params, tokens, extra_embeds=None, positions=None):
        """Training forward -> (logits, aux_loss)."""
        cfg = self.cfg
        B, S = tokens.shape
        impl = self._impl(S)
        if positions is None:
            positions = make_positions(cfg, B, S)
        x = self._embed(params, tokens, extra_embeds)
        aux = jnp.zeros((), jnp.float32)
        for i, desc in enumerate(self.prefix_descs):
            x, a = _apply_sublayer(params["prefix"][i], cfg, desc, x, positions,
                                   attn_impl=impl, use_kernels=self.use_kernels,
                                   remat=self.remat, attn_chunk=self.attn_chunk,
                                   acc_bf16=self.acc_bf16,
                                   probs_bf16=self.probs_bf16,
                                   seq_parallel=self.seq_parallel)
            aux = aux + a

        def period_body(carry, pp):
            x, aux = carry
            for j, desc in enumerate(self.period_descs):
                x, a = _apply_sublayer(pp[f"s{j}"], cfg, desc, x, positions,
                                       attn_impl=impl,
                                       use_kernels=self.use_kernels,
                                       remat=self.remat, unroll=self.unroll,
                                       attn_chunk=self.attn_chunk,
                                       acc_bf16=self.acc_bf16,
                                       probs_bf16=self.probs_bf16,
                                       seq_parallel=self.seq_parallel)
                aux = aux + a
            return (x, aux), None

        if self.unroll:
            carry = (x, aux)
            for i in range(self.n_periods):
                carry, _ = period_body(
                    carry, jax.tree.map(lambda a: a[i], params["blocks"]))
            x, aux = carry
        else:
            body = period_body
            if self.remat:
                body = jax.checkpoint(
                    period_body, policy=jax.checkpoint_policies.nothing_saveable)
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])
        return self._head(params, x), aux

    def _impl(self, S: int) -> str:
        if self.attn_impl != "auto":
            return self.attn_impl
        return "chunked" if S > 2048 else "naive"

    # -- mtp auxiliary head (dsv3) ---------------------------------------------------
    def mtp_logits(self, params, hidden, tokens_next, positions):
        """Predict t+2 from final hidden + embedding of token t+1."""
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        p = params["mtp"]
        e = jnp.take(params["embed"], tokens_next, axis=0).astype(hidden.dtype)
        h = jnp.concatenate([norm(p["norm_h"], hidden), norm(p["norm_e"], e)], axis=-1)
        h = h @ p["proj"]
        h, _ = _apply_sublayer(p["layer"], cfg, (self.period_descs[0][0], "dense"),
                               h, positions, attn_impl=self._impl(h.shape[1]),
                               use_kernels=False)
        return self._head(params, h)

    # -- serving ------------------------------------------------------------------
    def init_cache(self, batch: int, capacity: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        cache: Dict[str, Any] = {}
        if self.prefix_descs:
            cache["prefix"] = [
                _sublayer_state(cfg, d, batch, capacity, dtype)
                for d in self.prefix_descs]
        blocks = {}
        for j, desc in enumerate(self.period_descs):
            one = _sublayer_state(cfg, desc, batch, capacity, dtype)
            blocks[f"s{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (self.n_periods,) + a.shape).copy(),
                one)
        cache["blocks"] = blocks
        return cache

    def prefill(self, params, tokens, capacity: int, extra_embeds=None,
                cache_dtype=jnp.bfloat16):
        """-> (last-token logits (B,V), cache)."""
        cfg = self.cfg
        B, S = tokens.shape
        impl = self._impl(S)
        positions = make_positions(cfg, B, S)
        x = self._embed(params, tokens, extra_embeds)
        cache: Dict[str, Any] = {}
        if self.prefix_descs:
            pc = []
            for i, desc in enumerate(self.prefix_descs):
                x, st = _prefill_sublayer(params["prefix"][i], cfg, desc, x,
                                          positions, capacity=capacity,
                                          cache_dtype=cache_dtype,
                                          attn_impl=impl,
                                          attn_chunk=self.attn_chunk,
                                          probs_bf16=self.probs_bf16,
                                          seq_parallel=self.seq_parallel)
                pc.append(st)
            cache["prefix"] = pc

        def body(x, pp):
            states = {}
            for j, desc in enumerate(self.period_descs):
                x, st = _prefill_sublayer(pp[f"s{j}"], cfg, desc, x, positions,
                                          capacity=capacity,
                                          cache_dtype=cache_dtype,
                                          attn_impl=impl, unroll=self.unroll,
                                          attn_chunk=self.attn_chunk,
                                          probs_bf16=self.probs_bf16,
                                          seq_parallel=self.seq_parallel)
                states[f"s{j}"] = st
            return x, states

        if self.unroll:
            per = []
            for i in range(self.n_periods):
                x, st = body(x, jax.tree.map(lambda a: a[i], params["blocks"]))
                per.append(st)
            blocks = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *per)
        else:
            x, blocks = jax.lax.scan(body, x, params["blocks"])
        cache["blocks"] = blocks
        logits = self._head(params, x[:, -1:, :])[:, 0]
        return logits, cache

    def decode_step(self, params, cache, token, pos):
        """token: (B,1) int32; pos: scalar int32.  -> (logits (B,V), cache)."""
        cfg = self.cfg
        x = self._embed(params, token)
        new_cache: Dict[str, Any] = {}
        if self.prefix_descs:
            pc = []
            for i, desc in enumerate(self.prefix_descs):
                x, st = _decode_sublayer(params["prefix"][i], cfg, desc, x,
                                         cache["prefix"][i], pos,
                                         mla_absorb=self.mla_absorb)
                pc.append(st)
            new_cache["prefix"] = pc

        def body(x, xs):
            pp, cc = xs
            states = {}
            for j, desc in enumerate(self.period_descs):
                x, st = _decode_sublayer(pp[f"s{j}"], cfg, desc, x, cc[f"s{j}"],
                                         pos, mla_absorb=self.mla_absorb)
                states[f"s{j}"] = st
            return x, states

        if self.unroll:
            per = []
            for i in range(self.n_periods):
                x, st = body(x, jax.tree.map(
                    lambda a: a[i], (params["blocks"], cache["blocks"])))
                per.append(st)
            blocks = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *per)
        else:
            x, blocks = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = blocks
        logits = self._head(params, x)[:, 0]
        return logits, new_cache

    # -- paged serving ------------------------------------------------------
    def supports_paged(self) -> bool:
        """Block-paged serving covers GQA attention plus the recurrent
        block types (mamba/mlstm/slstm — per-slot state slabs), i.e.
        dense, ssm, and hybrid stacks.  MLA latent caches, sliding
        windows, and mrope remain dense-only."""
        cfg = self.cfg
        descs = list(self.prefix_descs) + list(self.period_descs)
        return (all(d[0] == "attn" or d[0] in RECURRENT_BLOCKS
                    for d in descs)
                and not cfg.sliding_window and cfg.rope != "mrope")

    def has_recurrent_state(self) -> bool:
        """True if any layer carries per-sequence recurrent state (the
        serving engine must then provision a ``StateStore``)."""
        return any(d[0] in RECURRENT_BLOCKS
                   for d in list(self.prefix_descs) + list(self.period_descs))

    def supports_prefix_sharing(self) -> bool:
        """KV pages are position-indexed and sharable; recurrent state
        is a running summary of the *whole* prefix and cannot be mapped
        mid-sequence, so any recurrent layer disables prefix sharing."""
        return self.supports_paged() and not self.has_recurrent_state()

    def supports_speculative(self) -> bool:
        """Speculative (draft-verify) decoding rolls rejected tokens
        back by arithmetic on the per-slot ``lengths`` vector — KV pages
        past the new length are simply never attended again.  Recurrent
        state has no such cheap rollback: a slab advanced through
        rejected tokens is irreversibly polluted, so any recurrent layer
        disables speculative mode (mirrors ``supports_prefix_sharing``)."""
        return self.supports_paged() and not self.has_recurrent_state()

    def init_paged_cache(self, num_blocks: int, block_size: int,
                         dtype=jnp.bfloat16, num_state_slots: int = 0,
                         shardings=None, kv_dtype: Optional[str] = None):
        """Shared block pool + recurrent state slabs.

        Every attn layer gets (nb, bs, KV, hd) K/V stores with no batch
        axis — slots share the pool through page tables.  Every
        recurrent layer gets fixed-size state slabs with a leading
        ``num_state_slots`` axis — slots own exactly one slab each (the
        engine's ``StateStore`` hands them out).  Periodic layers stack
        either kind on a leading scan axis.

        ``kv_dtype="int8"`` switches the attn K/V stores to int8 with
        per-(block, row, head) float32 scale pools ``k_scale``/
        ``v_scale`` of shape (nb, bs, KV) living in the same state dict
        — they share the leading block axis, so COW forks, spill/restore
        gathers/scatters, and mesh placement all ride the existing
        pytree traversals untouched.  Recurrent slabs are never
        quantized (they are running f32 summaries, not token caches).

        ``shardings`` (a matching pytree of ``jax.sharding.Sharding``,
        see :func:`repro.models.sharding.paged_cache_specs`) places each
        leaf at creation, so a mesh-sharded pool never materializes
        single-device first.
        """
        cfg = self.cfg
        if not self.supports_paged():
            raise NotImplementedError(
                f"paged cache needs an attn/mamba/mlstm/slstm stack without "
                f"sliding window/mrope (family={cfg.family!r})")
        if self.has_recurrent_state() and num_state_slots < 1:
            raise ValueError(
                f"family {cfg.family!r} has recurrent layers: "
                "init_paged_cache needs num_state_slots >= 1")
        if kv_dtype not in (None, "int8"):
            raise ValueError(f"kv_dtype must be None or 'int8', "
                             f"got {kv_dtype!r}")
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim

        def store(desc):
            if desc[0] in RECURRENT_BLOCKS:
                return _sublayer_state(cfg, desc, num_state_slots, 0, dtype)
            if kv_dtype == "int8":
                return {
                    "k": jnp.zeros((num_blocks, block_size, kv, hd),
                                   jnp.int8),
                    "v": jnp.zeros((num_blocks, block_size, kv, hd),
                                   jnp.int8),
                    "k_scale": jnp.zeros((num_blocks, block_size, kv),
                                         jnp.float32),
                    "v_scale": jnp.zeros((num_blocks, block_size, kv),
                                         jnp.float32),
                }
            return {"k": jnp.zeros((num_blocks, block_size, kv, hd), dtype),
                    "v": jnp.zeros((num_blocks, block_size, kv, hd), dtype)}

        cache: Dict[str, Any] = {}
        if self.prefix_descs:
            cache["prefix"] = [store(d) for d in self.prefix_descs]
        blocks = {}
        for j, desc in enumerate(self.period_descs):
            one = store(desc)
            blocks[f"s{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None], (self.n_periods,) + a.shape).copy(), one)
        cache["blocks"] = blocks
        if shardings is not None:
            cache = jax.device_put(cache, shardings)
        return cache

    def copy_paged_block(self, cache, src, dst):
        """COW fork: duplicate physical block ``src`` into ``dst`` across
        every attn layer's K/V store (prefix layers keyed on axis 0,
        periodic layers behind their leading scan axis).  Recurrent
        slabs are left untouched — they are never shared (prefix sharing
        is disabled for recurrent stacks), so a fork cannot involve
        them."""
        out: Dict[str, Any] = {}
        if "prefix" in cache:
            out["prefix"] = [
                jax.tree.map(lambda a: a.at[dst].set(a[src]), st)
                if d[0] == "attn" else st
                for d, st in zip(self.prefix_descs, cache["prefix"])]
        blocks = {}
        for j, d in enumerate(self.period_descs):
            st = cache["blocks"][f"s{j}"]
            blocks[f"s{j}"] = jax.tree.map(
                lambda a: a.at[:, dst].set(a[:, src]), st) \
                if d[0] == "attn" else st
        out["blocks"] = blocks
        return out

    def gather_paged_pages(self, cache, blocks, slab):
        """Spill read: pull physical blocks ``blocks`` ((n,) int32) out
        of every attn layer's K/V store, and state slab ``slab`` (scalar
        int32) out of every recurrent layer, into a standalone pytree
        the engine can ``device_get`` and park in host memory while the
        slot is preempted.  Layout mirrors ``copy_paged_block``: prefix
        attn leaves index axis 0, periodic attn leaves index behind the
        leading scan axis; recurrent slabs likewise.
        """
        def take(st, d, idx_attn, idx_state):
            return jax.tree.map(idx_attn, st) if d[0] == "attn" \
                else jax.tree.map(idx_state, st)

        out: Dict[str, Any] = {}
        if "prefix" in cache:
            out["prefix"] = [
                take(st, d, lambda a: a[blocks], lambda a: a[slab])
                for d, st in zip(self.prefix_descs, cache["prefix"])]
        out["blocks"] = {
            f"s{j}": take(cache["blocks"][f"s{j}"], d,
                          lambda a: a[:, blocks], lambda a: a[:, slab])
            for j, d in enumerate(self.period_descs)}
        return out

    def scatter_paged_pages(self, cache, payload, blocks, slab):
        """Spill write: the inverse of ``gather_paged_pages`` — place a
        spilled payload at (possibly different) physical ``blocks`` and
        ``slab``.  Attention reads go through the page table and
        recurrent reads through the slot->slab map, so restoring to new
        physical homes is invisible to the model: restored decode is
        bit-identical to never having been preempted."""
        def put(st, pst, d, set_attn, set_state):
            return jax.tree.map(set_attn, st, pst) if d[0] == "attn" \
                else jax.tree.map(set_state, st, pst)

        out: Dict[str, Any] = {}
        if "prefix" in cache:
            out["prefix"] = [
                put(st, pst, d, lambda a, p: a.at[blocks].set(p),
                    lambda a, p: a.at[slab].set(p))
                for d, st, pst in zip(self.prefix_descs, cache["prefix"],
                                      payload["prefix"])]
        out["blocks"] = {
            f"s{j}": put(cache["blocks"][f"s{j}"], payload["blocks"][f"s{j}"],
                         d, lambda a, p: a.at[:, blocks].set(p),
                         lambda a, p: a.at[:, slab].set(p))
            for j, d in enumerate(self.period_descs)}
        return out

    def paged_step(self, params, cache, tokens, page_table, lengths, t_valid,
                   state_slots=None, *, all_logits: bool = False):
        """Advance each slot by up to T tokens through the paged cache.

        tokens: (B,T) int32; page_table: (B,P) int32; lengths: (B,)
        tokens already cached per slot; t_valid: (B,) in [0,T] tokens of
        this call that are real per slot; state_slots: (B,) int32 slab
        of each slot's recurrent state (defaults to the identity map —
        row ``b`` owns slab ``b`` — for direct model-level use; the
        engine passes its ``StateStore`` assignment).  Covers decode
        (T=1) and chunked prefill (T=chunk) uniformly; slots may mix
        phases.  Returns (logits (B,V) at each slot's last valid token,
        cache) — or (logits (B,T,V) at *every* position, cache) under
        ``all_logits`` (the speculative verify step scores all drafted
        positions from one call; rows past ``t_valid`` are garbage and
        must be masked by the caller).
        """
        if state_slots is None:
            state_slots = jnp.arange(tokens.shape[0], dtype=jnp.int32)
        x = self._embed(params, tokens)
        new_cache: Dict[str, Any] = {}
        if self.prefix_descs:
            pc = []
            for i, desc in enumerate(self.prefix_descs):
                x, st = _paged_sublayer(params["prefix"][i], self.cfg, desc, x,
                                        cache["prefix"][i], page_table,
                                        lengths, t_valid, state_slots)
                pc.append(st)
            new_cache["prefix"] = pc

        def body(x, xs):
            pp, cc = xs
            states = {}
            for j, desc in enumerate(self.period_descs):
                x, st = _paged_sublayer(pp[f"s{j}"], self.cfg, desc, x,
                                        cc[f"s{j}"], page_table, lengths,
                                        t_valid, state_slots)
                states[f"s{j}"] = st
            return x, states

        if self.unroll:
            per = []
            for i in range(self.n_periods):
                x, st = body(x, jax.tree.map(
                    lambda a: a[i], (params["blocks"], cache["blocks"])))
                per.append(st)
            blocks = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *per)
        else:
            x, blocks = jax.lax.scan(body, x, (params["blocks"],
                                               cache["blocks"]))
        new_cache["blocks"] = blocks
        if all_logits:
            return self._head(params, x), new_cache
        if tokens.shape[1] == 1:
            # megastep fast path: decode bursts are T=1, the only valid
            # token is position 0 — skip the gather (bitwise identical)
            x_last = x
        else:
            last = jnp.clip(t_valid - 1, 0, None)                # (B,)
            x_last = jnp.take_along_axis(x, last[:, None, None],
                                         axis=1)                 # (B,1,D)
        logits = self._head(params, x_last)[:, 0]
        return logits, new_cache

    # -- loss ---------------------------------------------------------------------
    def loss(self, params, batch):
        """batch: {"tokens": (B,S), "labels": (B,S), ["extra_embeds"]}."""
        cfg = self.cfg
        logits, aux = self.apply(params, batch["tokens"],
                                 batch.get("extra_embeds"))
        ce = softmax_xent(logits, batch["labels"])
        total = ce + aux
        if cfg.mtp_depth:
            B, S = batch["tokens"].shape
            # hidden for MTP: reuse logits path is wasteful; recompute head input
            # cheaply by rerunning embed+blocks is too costly — instead MTP uses
            # the *shifted tokens* directly as a one-layer LM (standard depth-1).
            positions = make_positions(cfg, B, S - 1)
            hidden = self._embed(params, batch["tokens"][:, :-1])
            mtp_logits = self.mtp_logits(params, hidden, batch["tokens"][:, 1:],
                                         positions)
            total = total + 0.3 * softmax_xent(mtp_logits, batch["labels"][:, 1:])
        return total


def softmax_xent(logits, labels):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
