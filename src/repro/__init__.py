"""repro — NNStreamer reproduced as a JAX stream-pipeline framework."""

__version__ = "1.0.0"
