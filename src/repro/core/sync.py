"""Synchronization policies for TensorMux / TensorMerge (paper §III).

  * ``slowest`` — emit at the rate of the slowest source; faster sources
    drop stale frames (keep the one closest to the chosen timestamp).
  * ``fastest`` — emit at the rate of the fastest source; slower sources
    duplicate their most recent frame.
  * ``base(i)`` — lock the output rate to designated source *i*.

All merging elements stamp the output with the *latest* input timestamp,
as the paper specifies.
"""
from __future__ import annotations

import collections
import threading
from typing import Deque, List, Optional

from .stream import Buffer


class SyncPolicy:
    SLOWEST = "slowest"
    FASTEST = "fastest"
    BASE = "base"

    @classmethod
    def parse(cls, text: str):
        """Parse "slowest" | "fastest" | "base:<idx>"."""
        if text.startswith(cls.BASE):
            idx = int(text.split(":", 1)[1]) if ":" in text else 0
            return cls.BASE, idx
        if text in (cls.SLOWEST, cls.FASTEST):
            return text, 0
        raise ValueError(f"unknown sync policy {text!r}")


class SyncCollector:
    """Aligns N input streams into synchronized frame sets.

    Thread-safe: mux inputs arrive from different upstream threads.
    ``offer`` returns a list of per-pad buffers when a synchronized set
    is ready, else None.
    """

    def __init__(self, num_pads: int, policy: str = SyncPolicy.SLOWEST,
                 base_index: int = 0, max_queue: int = 32):
        self.num_pads = num_pads
        self.policy = policy
        self.base_index = base_index
        self.queues: List[Deque[Buffer]] = [collections.deque() for _ in range(num_pads)]
        self.latest: List[Optional[Buffer]] = [None] * num_pads
        self.max_queue = max_queue
        self.lock = threading.Lock()
        self.eos = [False] * num_pads

    def offer(self, index: int, buf: Buffer) -> Optional[List[Buffer]]:
        with self.lock:
            if buf.eos:
                self.eos[index] = True
                return None
            self.latest[index] = buf
            self.queues[index].append(buf)
            if len(self.queues[index]) > self.max_queue:
                self.queues[index].popleft()  # leaky: drop oldest
            return self._try_collect()

    def all_eos(self) -> bool:
        with self.lock:
            return all(self.eos)

    def exhausted(self) -> bool:
        """True when no future ``offer`` can ever complete a frame set,
        so the owning element may forward EOS early.

          * BASE    — the base pad ended and its queue drained; other
                      pads alone can never trigger an emission.
          * SLOWEST — any pad ended with an empty queue (every set needs
                      one frame from every pad).
          * FASTEST — a pad that ended without ever producing can never
                      supply a latest frame to duplicate; otherwise only
                      when every pad ended.
        """
        with self.lock:
            if self.policy == SyncPolicy.BASE:
                return (self.eos[self.base_index]
                        and not self.queues[self.base_index])
            if self.policy == SyncPolicy.SLOWEST:
                return any(e and not q for e, q in zip(self.eos, self.queues))
            return all(self.eos) or any(
                e and latest is None
                for e, latest in zip(self.eos, self.latest))

    # -- policy engines ----------------------------------------------------
    def _try_collect(self) -> Optional[List[Buffer]]:
        if self.policy == SyncPolicy.SLOWEST:
            return self._collect_slowest()
        if self.policy == SyncPolicy.FASTEST:
            return self._collect_fastest()
        return self._collect_base()

    def _collect_slowest(self) -> Optional[List[Buffer]]:
        # need at least one frame on every pad; pick target = min of heads'
        # newest available, drop frames older than target on faster pads
        if any(not q for q in self.queues):
            return None
        target = max(q[0].pts for q in self.queues)  # slowest source's head
        out: List[Buffer] = []
        for q in self.queues:
            # drop frames clearly older than target (faster sources)
            while len(q) > 1 and abs(q[1].pts - target) <= abs(q[0].pts - target):
                q.popleft()
            out.append(q.popleft())
        return out

    def _collect_fastest(self) -> Optional[List[Buffer]]:
        # fire whenever any pad has a fresh frame, provided all pads have
        # seen at least one frame; slower pads duplicate their latest
        if any(b is None for b in self.latest):
            return None
        out: List[Buffer] = []
        for q, latest in zip(self.queues, self.latest):
            out.append(q.popleft() if q else latest)
        return out

    def _collect_base(self) -> Optional[List[Buffer]]:
        # fire only when the base pad has a frame; others use nearest/latest
        base_q = self.queues[self.base_index]
        if not base_q or any(b is None for b in self.latest):
            return None
        base = base_q.popleft()
        out: List[Buffer] = []
        for i, (q, latest) in enumerate(zip(self.queues, self.latest)):
            if i == self.base_index:
                out.append(base)
                continue
            # choose queued frame with pts closest to base, else latest
            best = latest
            while q:
                cand = q[0]
                if len(q) > 1 and abs(q[1].pts - base.pts) <= abs(cand.pts - base.pts):
                    q.popleft()
                    continue
                best = cand
                q.popleft()
                break
            out.append(best)
        return out


def stamp_latest(buffers: List[Buffer]) -> float:
    """Merging filters choose the latest timestamp (paper §III)."""
    return max(b.pts for b in buffers)
