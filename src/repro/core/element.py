"""Element/Pad graph primitives — the GStreamer skeleton of the framework.

Scheduling model (faithful to GStreamer's push model):
  * Sources run in their own thread (started by the Pipeline).
  * ``push`` on a source pad synchronously invokes the peer element's
    ``chain`` in the caller's thread — *unless* the peer is a Queue,
    which enqueues and lets its own worker thread continue downstream.
    Queues are therefore the thread (pipeline-parallelism) boundaries,
    exactly as in the paper's E1/E3 discussions.
  * Caps ("specs") are negotiated at link time and re-checked at the
    first buffer.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional

from .stream import AnySpec, Buffer, specs_compatible


class PadDirection:
    SRC = "src"
    SINK = "sink"


class Pad:
    def __init__(self, element: "Element", name: str, direction: str,
                 spec: Optional[AnySpec] = None):
        self.element = element
        self.name = name
        self.direction = direction
        self.spec = spec            # None = ANY
        self.peer: Optional["Pad"] = None

    # -- linking ----------------------------------------------------------
    def link(self, other: "Pad") -> None:
        if self.direction != PadDirection.SRC or other.direction != PadDirection.SINK:
            raise ValueError(f"can only link src->sink pads "
                             f"({self.qualname()} -> {other.qualname()})")
        if self.peer is not None or other.peer is not None:
            raise ValueError(f"pad already linked: {self.qualname()} or {other.qualname()}")
        if not specs_compatible(self.spec, other.spec):
            raise ValueError(
                f"caps negotiation failed: {self.qualname()}({self.spec}) !~ "
                f"{other.qualname()}({other.spec})")
        self.peer = other
        other.peer = self

    def qualname(self) -> str:
        return f"{self.element.name}.{self.name}"

    # -- dataflow ---------------------------------------------------------
    def push(self, buf: Buffer) -> None:
        """Push a buffer downstream (src pads only)."""
        if self.peer is None:
            return  # unlinked src pad: drop (like gst fakesink-less leaf)
        self.peer.element.chain(self.peer, buf)


class Element:
    """Base pipeline element."""

    def __init__(self, name: str):
        self.name = name
        self.sinkpads: Dict[str, Pad] = {}
        self.srcpads: Dict[str, Pad] = {}
        self.pipeline = None          # set by Pipeline.add
        self._lock = threading.Lock()

    # -- pad management ---------------------------------------------------
    def add_sink_pad(self, name: str = "sink", spec: Optional[AnySpec] = None) -> Pad:
        pad = Pad(self, name, PadDirection.SINK, spec)
        self.sinkpads[name] = pad
        return pad

    def add_src_pad(self, name: str = "src", spec: Optional[AnySpec] = None) -> Pad:
        pad = Pad(self, name, PadDirection.SRC, spec)
        self.srcpads[name] = pad
        return pad

    @property
    def sinkpad(self) -> Pad:
        if len(self.sinkpads) != 1:
            raise ValueError(f"{self.name} has {len(self.sinkpads)} sink pads")
        return next(iter(self.sinkpads.values()))

    @property
    def srcpad(self) -> Pad:
        if len(self.srcpads) != 1:
            raise ValueError(f"{self.name} has {len(self.srcpads)} src pads")
        return next(iter(self.srcpads.values()))

    def link(self, downstream: "Element", srcpad: Optional[str] = None,
             sinkpad: Optional[str] = None) -> "Element":
        src = self.srcpads[srcpad] if srcpad else self.srcpad
        # auto-pick first unlinked sink pad
        if sinkpad:
            snk = downstream.sinkpads[sinkpad]
        else:
            free = [p for p in downstream.sinkpads.values() if p.peer is None]
            if not free:
                snk = downstream.request_sink_pad()
            else:
                snk = free[0]
        src.link(snk)
        return downstream

    def request_sink_pad(self) -> Pad:
        """Elements with request pads (mux, merge) override this."""
        raise ValueError(f"{self.name}: no free sink pad and no request pads")

    def request_src_pad(self) -> Pad:
        raise ValueError(f"{self.name}: no request src pads")

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        """Transition READY->PLAYING (allocate threads/state)."""

    def stop(self) -> None:
        """Transition PLAYING->NULL (join threads, free state)."""

    # -- dataflow ----------------------------------------------------------
    def chain(self, pad: Pad, buf: Buffer) -> None:
        """Receive a buffer on a sink pad.  Default: transform + push."""
        if buf.eos:
            self.handle_eos(pad, buf)
            return
        out = self.transform(pad, buf)
        if out is not None:
            self.srcpad.push(out)

    def transform(self, pad: Pad, buf: Buffer) -> Optional[Buffer]:
        raise NotImplementedError(f"{type(self).__name__}.transform")

    def handle_eos(self, pad: Pad, buf: Buffer) -> None:
        """Default EOS: forward on all src pads."""
        for p in self.srcpads.values():
            p.push(buf)

    def post_error(self, exc: BaseException) -> None:
        if self.pipeline is not None:
            self.pipeline.post_error(self.name, exc)
        else:
            raise exc

    def __repr__(self):
        return f"<{type(self).__name__} {self.name!r}>"
