"""repro.core — the stream-pipeline framework (the paper's contribution)."""
from .stream import (Buffer, MediaSpec, TensorSpec, TensorsSpec,
                     specs_compatible)
from .element import Element, Pad
from .pipeline import Pipeline, PipelineError
from .parser import parse_pipeline
from .registry import make_element, register_element
from . import elements

__all__ = [
    "Buffer", "MediaSpec", "TensorSpec", "TensorsSpec", "specs_compatible",
    "Element", "Pad", "Pipeline", "PipelineError", "parse_pipeline",
    "make_element", "register_element", "elements",
]
