"""Stream data types — the `other/tensor(s)` caps of NNStreamer.

A ``TensorSpec`` is the capability ("caps") of a single tensor stream:
element dtype, dimensions, and a nominal frame rate.  A ``TensorsSpec``
bundles up to ``MAX_TENSORS`` specs with a synchronized frame rate
(NNStreamer's ``other/tensors``).  Rank is *not* semantically significant:
``640:480`` and ``640:480:1:1`` negotiate as equivalent, exactly as the
paper describes, unless a filter explicitly pins the rank
(``require_rank=True`` — the TensorRT-style escape hatch).

A ``Buffer`` is one frame travelling through the pipeline: a tuple of
array chunks (each tensor its own memory chunk, so mux/demux never copy),
a presentation timestamp, and a metadata dict.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional, Sequence, Tuple

import numpy as np

MAX_TENSORS = 16  # default limit of memory chunks in a frame (paper §III)

_DTYPE_ALIASES = {
    "float32": "float32", "f32": "float32",
    "float16": "float16", "f16": "float16",
    "bfloat16": "bfloat16", "bf16": "bfloat16",
    "float64": "float64", "f64": "float64",
    "int8": "int8", "uint8": "uint8",
    "int16": "int16", "uint16": "uint16",
    "int32": "int32", "uint32": "uint32",
    "int64": "int64", "uint64": "uint64",
    "bool": "bool",
}


def canonical_dtype(name: str) -> str:
    key = str(name).lower()
    if key not in _DTYPE_ALIASES:
        raise ValueError(f"unsupported tensor element type: {name!r}")
    return _DTYPE_ALIASES[key]


def _strip_rank(dims: Sequence[int]) -> Tuple[int, ...]:
    """Canonical dims: drop trailing 1s (rank-agnostic negotiation)."""
    dims = tuple(int(d) for d in dims)
    while len(dims) > 1 and dims[-1] == 1:
        dims = dims[:-1]
    return dims


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """Caps of one tensor stream: ``other/tensor``."""

    dims: Tuple[int, ...]            # innermost-first, gst style "640:480:3"
    dtype: str = "float32"
    framerate: Optional[float] = None  # Hz; None = variable/don't-care
    require_rank: bool = False         # pin exact rank (TensorRT-style NNFWs)

    def __post_init__(self):
        object.__setattr__(self, "dims", tuple(int(d) for d in self.dims))
        object.__setattr__(self, "dtype", canonical_dtype(self.dtype))
        if len(self.dims) == 0:
            raise ValueError("TensorSpec needs at least one dimension")
        if len(self.dims) > 8:
            raise ValueError("TensorSpec supports at most rank 8")
        if any(d <= 0 for d in self.dims):
            raise ValueError(f"dims must be positive, got {self.dims}")

    # -- negotiation ------------------------------------------------------
    def canonical_dims(self) -> Tuple[int, ...]:
        return _strip_rank(self.dims)

    def compatible(self, other: "TensorSpec") -> bool:
        if self.dtype != other.dtype:
            return False
        if self.require_rank or other.require_rank:
            if self.dims != other.dims:
                return False
        elif self.canonical_dims() != other.canonical_dims():
            return False
        if (self.framerate is not None and other.framerate is not None
                and abs(self.framerate - other.framerate) > 1e-9):
            return False
        return True

    # -- conversions ------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        """numpy-style shape (outermost first)."""
        return tuple(reversed(self.dims))

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.dims)) * np.dtype(self.dtype).itemsize

    @classmethod
    def from_array(cls, arr, framerate: Optional[float] = None) -> "TensorSpec":
        return cls(dims=tuple(reversed(arr.shape)) or (1,),
                   dtype=str(np.asarray(arr).dtype), framerate=framerate)

    @classmethod
    def parse(cls, text: str, dtype: str = "float32",
              framerate: Optional[float] = None) -> "TensorSpec":
        """Parse gst-style "640:480:3" dimension strings."""
        dims = tuple(int(tok) for tok in text.split(":"))
        return cls(dims=dims, dtype=dtype, framerate=framerate)

    def __str__(self) -> str:
        fr = f",framerate={self.framerate}" if self.framerate else ""
        return f"other/tensor,dims={':'.join(map(str, self.dims))},type={self.dtype}{fr}"


@dataclasses.dataclass(frozen=True)
class TensorsSpec:
    """Caps of a bundled multi-tensor stream: ``other/tensors``."""

    tensors: Tuple[TensorSpec, ...]
    framerate: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "tensors", tuple(self.tensors))
        if not (1 <= len(self.tensors) <= MAX_TENSORS):
            raise ValueError(
                f"other/tensors bundles 1..{MAX_TENSORS} tensors, got {len(self.tensors)}")

    @property
    def num_tensors(self) -> int:
        return len(self.tensors)

    def compatible(self, other: "TensorsSpec") -> bool:
        if self.num_tensors != other.num_tensors:
            return False
        if (self.framerate is not None and other.framerate is not None
                and abs(self.framerate - other.framerate) > 1e-9):
            return False
        return all(a.compatible(b) for a, b in zip(self.tensors, other.tensors))

    def __str__(self) -> str:
        inner = ";".join(str(t) for t in self.tensors)
        return f"other/tensors,n={self.num_tensors}[{inner}]"


AnySpec = Any  # TensorSpec | TensorsSpec | MediaSpec


@dataclasses.dataclass(frozen=True)
class MediaSpec:
    """Conventional media caps (video/audio/text) — inputs to TensorConverter."""

    media: str                      # "video/x-raw", "audio/x-raw", "text/x-raw"
    format: str = "RGB"             # video: RGB/GRAY8; audio: S16LE/F32LE
    width: int = 0
    height: int = 0
    channels: int = 0
    rate: Optional[float] = None    # fps or sample rate

    def compatible(self, other: "MediaSpec") -> bool:
        return (self.media == other.media and self.format == other.format
                and self.width == other.width and self.height == other.height
                and self.channels == other.channels)


def specs_compatible(a: AnySpec, b: AnySpec) -> bool:
    """Run-time caps negotiation between two pads."""
    if a is None or b is None:  # ANY caps
        return True
    if isinstance(a, TensorSpec) and isinstance(b, TensorSpec):
        return a.compatible(b)
    if isinstance(a, TensorsSpec) and isinstance(b, TensorsSpec):
        return a.compatible(b)
    # promote single tensor <-> 1-element bundle
    if isinstance(a, TensorSpec) and isinstance(b, TensorsSpec) and b.num_tensors == 1:
        return a.compatible(b.tensors[0])
    if isinstance(a, TensorsSpec) and isinstance(b, TensorSpec) and a.num_tensors == 1:
        return a.tensors[0].compatible(b)
    if isinstance(a, MediaSpec) and isinstance(b, MediaSpec):
        return a.compatible(b)
    return False


class Buffer:
    """One frame: chunked arrays + pts + metadata.

    Each tensor lives in its own chunk so TensorMux/Demux are zero-copy
    (they only re-bundle the chunk tuple).
    """

    __slots__ = ("chunks", "pts", "meta", "eos")

    def __init__(self, chunks, pts: Optional[float] = None, meta=None, eos=False):
        if not isinstance(chunks, (tuple, list)):
            chunks = (chunks,)
        self.chunks: Tuple[Any, ...] = tuple(chunks)
        self.pts: float = time.monotonic() if pts is None else float(pts)
        self.meta: dict = dict(meta) if meta else {}
        self.eos: bool = bool(eos)

    @classmethod
    def eos_buffer(cls, pts: Optional[float] = None) -> "Buffer":
        return cls((), pts=pts, eos=True)

    @property
    def data(self):
        """The sole chunk (single-tensor streams)."""
        if len(self.chunks) != 1:
            raise ValueError(f"Buffer holds {len(self.chunks)} chunks, not 1")
        return self.chunks[0]

    def with_chunks(self, chunks) -> "Buffer":
        return Buffer(chunks, pts=self.pts, meta=self.meta)

    def spec(self) -> AnySpec:
        if len(self.chunks) == 1:
            return TensorSpec.from_array(np.asarray(self.chunks[0]))
        return TensorsSpec(tuple(TensorSpec.from_array(np.asarray(c))
                                 for c in self.chunks))

    def __repr__(self) -> str:
        if self.eos:
            return f"Buffer(EOS, pts={self.pts:.4f})"
        shapes = ",".join(str(tuple(np.asarray(c).shape)) for c in self.chunks)
        return f"Buffer([{shapes}], pts={self.pts:.4f})"
