"""Pipeline — element container, state machine, and message bus.

States follow GStreamer: NULL -> READY -> PLAYING -> NULL.  ``start``
launches queue workers first (downstream threads must be live before
sources push), then sources.  The bus collects errors posted by elements
running in any thread; ``run_until_eos`` re-raises them.
"""
from __future__ import annotations

import queue as _queue
import threading
import time
from typing import Dict, List, Optional

from .element import Element
from .elements.queue import Queue
from .elements.sources import SourceElement


class PipelineError(RuntimeError):
    pass


class Pipeline:
    NULL, READY, PLAYING = "NULL", "READY", "PLAYING"

    def __init__(self, name: str = "pipeline"):
        self.name = name
        self.elements: Dict[str, Element] = {}
        self.state = self.NULL
        self.bus: _queue.Queue = _queue.Queue()

    # -- construction -------------------------------------------------------
    def add(self, *elements: Element) -> "Pipeline":
        for el in elements:
            if el.name in self.elements:
                raise ValueError(f"duplicate element name {el.name!r}")
            self.elements[el.name] = el
            el.pipeline = self
        return self

    def __getitem__(self, name: str) -> Element:
        return self.elements[name]

    def link(self, *names: str) -> "Pipeline":
        """Link a chain of elements by name."""
        for up, down in zip(names, names[1:]):
            self.elements[up].link(self.elements[down])
        return self

    # -- bus ------------------------------------------------------------------
    def post_error(self, element_name: str, exc: BaseException) -> None:
        self.bus.put(("error", element_name, exc))

    def check_bus(self) -> None:
        try:
            kind, el, exc = self.bus.get_nowait()
        except _queue.Empty:
            return
        raise PipelineError(f"element {el!r} failed: {exc!r}") from exc

    # -- state ------------------------------------------------------------------
    def start(self) -> "Pipeline":
        if self.state == self.PLAYING:
            return self
        # non-source elements first (queues spawn workers), sources last
        for el in self.elements.values():
            if not isinstance(el, SourceElement):
                el.start()
        for el in self.elements.values():
            if isinstance(el, SourceElement):
                el.start()
        self.state = self.PLAYING
        return self

    def stop(self) -> "Pipeline":
        for el in self.elements.values():
            if isinstance(el, SourceElement):
                el.stop()
        for el in self.elements.values():
            if not isinstance(el, SourceElement):
                el.stop()
        self.state = self.NULL
        return self

    # -- execution helpers -------------------------------------------------------
    def sinks(self) -> List[Element]:
        return [el for el in self.elements.values()
                if el.srcpads == {} and hasattr(el, "eos_seen")]

    def run_until_eos(self, timeout: float = 60.0) -> "Pipeline":
        """start(), wait for EOS on every sink (or error), stop()."""
        self.start()
        deadline = time.monotonic() + timeout
        try:
            sinks = self.sinks()
            if not sinks:
                raise PipelineError("pipeline has no sinks with EOS tracking")
            for sink in sinks:
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not sink.eos_seen.wait(timeout=max(remaining, 0.01)):
                    self.check_bus()
                    raise PipelineError(
                        f"timeout waiting for EOS on {sink.name!r} "
                        f"(received so far: {getattr(sink, 'n_received', '?')})")
                self.check_bus()
        finally:
            self.stop()
        self.check_bus()
        return self
