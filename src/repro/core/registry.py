"""Element registry — maps gst-launch element type names to factories.

Plugin-style: anything can register new element types at run-time
(``register_element``), mirroring GStreamer's plugin registry.
"""
from __future__ import annotations

from typing import Callable, Dict

from .element import Element
from . import elements as E

_ELEMENTS: Dict[str, Callable[..., Element]] = {}


def register_element(type_name: str, factory: Callable[..., Element]) -> None:
    _ELEMENTS[type_name] = factory


def make_element(type_name: str, name: str, **props) -> Element:
    if type_name not in _ELEMENTS:
        raise ValueError(f"unknown element type {type_name!r}; "
                         f"known: {sorted(_ELEMENTS)}")
    return _ELEMENTS[type_name](name=name, **props)


def _register_builtins() -> None:
    register_element("queue", lambda name, **p: E.Queue(
        name, max_size=int(p.get("max_size", 16)), leaky=p.get("leaky", "no"),
        workers=int(p.get("workers", 1))))
    register_element("appsrc", lambda name, **p: E.AppSrc(name))
    register_element("videotestsrc", lambda name, **p: E.VideoTestSrc(
        name, width=int(p.get("width", 224)), height=int(p.get("height", 224)),
        channels=int(p.get("channels", 3)),
        num_buffers=int(p.get("num_buffers", -1)),
        rate=float(p["rate"]) if "rate" in p else None,
        seed=int(p.get("seed", 0))))
    register_element("sensorsrc", lambda name, **p: E.SensorSrc(
        name, channels=int(p.get("channels", 3)),
        num_buffers=int(p.get("num_buffers", -1)),
        rate=float(p["rate"]) if "rate" in p else None,
        seed=int(p.get("seed", 0))))
    register_element("tensor_src_iio", lambda name, **p: E.TensorSrcIIO(
        name, channels=int(p.get("channels", 3)),
        num_buffers=int(p.get("num_buffers", -1)),
        rate=float(p["rate"]) if "rate" in p else None,
        seed=int(p.get("seed", 0))))
    register_element("appsink", lambda name, **p: E.AppSink(
        name, max_size=int(p.get("max_size", 0)),
        drop=str(p.get("drop", "false")).lower() == "true"))
    register_element("tensor_sink", lambda name, **p: E.TensorSink(
        name, keep=str(p.get("keep", "false")).lower() == "true"))
    register_element("fakesink", lambda name, **p: E.FakeSink(name))
    register_element("tensor_converter", lambda name, **p: E.TensorConverter(
        name, mode=p.get("mode", "video"),
        to_float=str(p.get("to_float", "false")).lower() == "true",
        text_size=int(p.get("text_size", 256))))
    register_element("tensor_decoder", lambda name, **p: E.TensorDecoder(
        name, mode=p.get("mode", "argmax_label"),
        width=int(p.get("width", 0)), height=int(p.get("height", 0))))
    register_element("tensor_filter", lambda name, **p: E.TensorFilter(
        name, model=p.get("model"), framework=p.get("framework", "python"),
        max_batch=int(p.get("max_batch", 8)),
        pass_meta=str(p.get("pass_meta", "false")).lower() == "true"))
    register_element("tensor_batcher", lambda name, **p: E.TensorBatcher(
        name, max_batch=int(p.get("max_batch", 8)),
        max_wait_ms=float(p["max_wait_ms"]) if "max_wait_ms" in p else None))
    register_element("tensor_unbatcher", lambda name, **p: E.TensorUnbatcher(name))
    register_element("tee", lambda name, **p: E.Tee(
        name, num_src_pads=int(p.get("num_src_pads", 0))))
    register_element("tensor_mux", lambda name, **p: E.TensorMux(
        name, num_sinks=int(p["num_sinks"]), sync=p.get("sync", "slowest")))
    register_element("tensor_demux", lambda name, **p: E.TensorDemux(
        name, num_src_pads=int(p["num_src_pads"]),
        tensorpick=[int(x) for x in str(p["tensorpick"]).split(".")]
        if "tensorpick" in p else None))
    register_element("tensor_merge", lambda name, **p: E.TensorMerge(
        name, num_sinks=int(p["num_sinks"]), mode=p.get("mode", "concat:0"),
        sync=p.get("sync", "slowest")))
    register_element("tensor_split", lambda name, **p: E.TensorSplit(
        name, tensorseg=[int(x) for x in str(p["tensorseg"]).split(".")],
        gst_dim=int(p.get("dim", 0))))
    register_element("input_selector", lambda name, **p: E.InputSelector(
        name, num_sinks=int(p["num_sinks"]), active=int(p.get("active", 0))))
    register_element("output_selector", lambda name, **p: E.OutputSelector(
        name, num_srcs=int(p["num_srcs"]), active=int(p.get("active", 0))))
    register_element("valve", lambda name, **p: E.Valve(
        name, drop=str(p.get("drop", "false")).lower() == "true"))
    register_element("tensor_aggregator", lambda name, **p: E.TensorAggregator(
        name, frames_in=int(p.get("frames_in", 2)),
        frames_flush=int(p["frames_flush"]) if "frames_flush" in p else None,
        concat_axis=int(p.get("concat_axis", 0)),
        stack=str(p.get("stack", "false")).lower() == "true"))
    register_element("tensor_rate", lambda name, **p: E.TensorRate(
        name, framerate=float(p["framerate"]),
        throttle=str(p.get("throttle", "true")).lower() == "true"))
    register_element("tensor_transform", lambda name, **p: E.TensorTransform(
        name, option=p["option"], backend=p.get("backend", "numpy")))
    register_element("tensor_if", lambda name, **p: E.TensorIf(
        name, reduction=p.get("reduction", "mean"),
        compare=p.get("compare", "gt"), value=float(p.get("value", 0.0)),
        behavior=p.get("behavior", "route")))
    register_element("tensor_reposink", lambda name, **p: E.TensorRepoSink(
        name, slot=p["slot"]))
    register_element("tensor_query_serversrc", lambda name, **p:
        E.TensorQueryServerSrc(
            name, host=p.get("host", "127.0.0.1"), port=int(p.get("port", 0)),
            pad_to=int(p.get("pad_to", 64)),
            backlog=int(p.get("backlog", 16))))
    register_element("tensor_query_serversink", lambda name, **p:
        E.TensorQueryServerSink(name))
    register_element("tensor_reposrc", lambda name, **p: E.TensorRepoSrc(
        name, slot=p["slot"],
        seed_shape=tuple(int(x) for x in str(p["seed_shape"]).split(":"))
        if "seed_shape" in p else None,
        seed_dtype=p.get("seed_dtype", "float32")))


_register_builtins()
