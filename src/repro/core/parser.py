"""gst-launch-style textual pipeline syntax.

Supported grammar (a practical subset of gst-launch-1.0):

    pipeline  := chain (WS chain)*
    chain     := endpoint ( '!' endpoint )*
    endpoint  := element | padref
    element   := TYPE (prop '=' value)*
    padref    := NAME '.' [PADNAME]          # reference an existing element

Examples::

    videotestsrc num_buffers=10 ! tensor_converter ! tensor_filter
        framework=python model=identity ! tensor_sink name=out

    tee name=t num_src_pads=2  t.src_0 ! queue ! fakesink name=a
        t.src_1 ! queue ! fakesink name=b

    sensorsrc num_buffers=8 ! mux.sink_0  sensorsrc num_buffers=8 seed=3 !
        mux.sink_1  tensor_mux name=mux num_sinks=2 ! tensor_sink name=out

Chains may reference elements defined later (two-pass link resolution),
matching gst-launch ergonomics.
"""
from __future__ import annotations

import re
import shlex
from typing import Dict, List, Optional, Tuple

from .pipeline import Pipeline
from .registry import make_element

_PADREF = re.compile(r"^([A-Za-z_][\w\-]*)\.([\w\-]*)$")
_PROP = re.compile(r"^([\w\-]+)=(.*)$")
_TYPE = re.compile(r"^[A-Za-z_][\w\-]*$")


class _Endpoint:
    def __init__(self, element_name: str, pad: Optional[str] = None):
        self.element_name = element_name
        self.pad = pad  # None = default/auto


def parse_pipeline(description: str, name: str = "pipeline",
                   models: Optional[Dict[str, object]] = None) -> Pipeline:
    """Parse a textual description into a ready-to-start Pipeline.

    ``models`` optionally maps model names to callables, registered into
    the model registry before tensor_filters resolve.
    """
    if models:
        from ..registry import register_model
        for mname, fn in models.items():
            register_model(mname, fn)

    tokens = shlex.split(description.replace("!", " ! "))
    pipe = Pipeline(name)
    auto_idx = 0

    # pass 1: create elements, record link requests
    links: List[Tuple[_Endpoint, _Endpoint]] = []
    prev: Optional[_Endpoint] = None
    pending_link = False
    i = 0
    while i < len(tokens):
        tok = tokens[i]
        if tok == "!":
            if prev is None:
                raise ValueError("'!' with no upstream element")
            pending_link = True
            i += 1
            continue
        m = _PADREF.match(tok)
        if m:  # padref may reference an element defined later (pass-2 resolve)
            ep = _Endpoint(m.group(1), m.group(2) or None)
        elif _TYPE.match(tok) and not _PROP.match(tok):
            # element instantiation: gather props
            type_name = tok
            props: Dict[str, str] = {}
            j = i + 1
            while j < len(tokens):
                pm = _PROP.match(tokens[j])
                if not pm or tokens[j] == "!":
                    break
                props[pm.group(1).replace("-", "_")] = pm.group(2)
                j += 1
            i = j - 1
            el_name = props.pop("name", None)
            if el_name is None:
                el_name = f"{type_name}{auto_idx}"
                auto_idx += 1
            pipe.add(make_element(type_name, el_name, **props))
            ep = _Endpoint(el_name, None)
        else:
            raise ValueError(f"cannot parse token {tok!r}")
        if pending_link:
            links.append((prev, ep))
            pending_link = False
        prev = ep
        i += 1

    if pending_link:
        raise ValueError("dangling '!' at end of description")

    # pass 2: resolve links
    for up, down in links:
        src_el = pipe.elements[up.element_name]
        dst_el = pipe.elements[down.element_name]
        src_el.link(dst_el, srcpad=up.pad or None, sinkpad=down.pad or None)
    return pipe
