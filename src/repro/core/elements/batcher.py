"""TensorBatcher / TensorUnbatcher — adaptive micro-batching elements.

``TensorBatcher`` accumulates stream frames and emits one *batched*
buffer whose chunks gained a new leading batch axis.  A batch closes
when either cap is hit (NNStreamer-style "whichever first" semantics):

  * ``max_batch``    — the batch is full, or
  * ``max_wait_ms``  — the oldest queued frame has waited this long
                       (rate-adaptive: light traffic still gets bounded
                       latency, heavy traffic gets full batches).

Per-frame ``pts`` and ``meta`` are preserved in the batch metadata under
``meta["batch"]`` so a downstream ``TensorUnbatcher`` can reconstruct
the original per-frame buffers exactly.  EOS flushes any partial batch
before being forwarded, so no frame is ever lost at stream end.

The unbatch side is zero-copy: splitting along the leading axis yields
numpy views into the batched chunk, never copies.
"""
from __future__ import annotations

import threading
from typing import List, Optional

import numpy as np

from ..element import Element, Pad
from ..stream import Buffer

BATCH_META_KEY = "batch"


class TensorBatcher(Element):
    def __init__(self, name: str, max_batch: int = 8,
                 max_wait_ms: Optional[float] = None):
        super().__init__(name)
        self.add_sink_pad()
        self.add_src_pad()
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = int(max_batch)
        self.max_wait_s = None if max_wait_ms is None else float(max_wait_ms) / 1e3
        # serializes batch close + downstream push across the upstream
        # thread and the timeout thread, so batches leave in order, never
        # after EOS, and downstream elements see no concurrency from here
        self._flush_lock = threading.RLock()
        self._pending: List[Buffer] = []
        self._deadline: Optional[float] = None   # monotonic flush deadline
        self._timer: Optional[threading.Thread] = None
        self._wake = threading.Event()
        self._running = False
        self.n_batches = 0
        self.n_timeout_flushes = 0
        self.n_eos_flushes = 0

    # -- accumulation -------------------------------------------------------
    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos:
            with self._flush_lock:
                with self._lock:
                    out = self._close_batch() if self._pending else None
                    if out is not None:
                        self.n_eos_flushes += 1
                if out is not None:
                    self.srcpad.push(out)
                self.handle_eos(pad, buf)
            return
        with self._flush_lock:
            with self._lock:
                if self._pending and len(buf.chunks) != len(self._pending[0].chunks):
                    raise ValueError(
                        f"{self.name}: frame chunk arity changed mid-batch "
                        f"({len(self._pending[0].chunks)} -> {len(buf.chunks)})")
                self._pending.append(buf)
                if len(self._pending) == 1 and self.max_wait_s is not None:
                    import time
                    self._deadline = time.monotonic() + self.max_wait_s
                    self._wake.set()
                out = (self._close_batch()
                       if len(self._pending) >= self.max_batch else None)
            if out is not None:
                self.srcpad.push(out)

    def _close_batch(self) -> Optional[Buffer]:
        """Stack pending frames; caller must hold self._lock."""
        if not self._pending:
            return None
        frames, self._pending = self._pending, []
        self._deadline = None
        n_chunks = len(frames[0].chunks)
        stacked = tuple(
            np.stack([np.asarray(f.chunks[i]) for f in frames], axis=0)
            for i in range(n_chunks))
        meta = {BATCH_META_KEY: {
            "size": len(frames),
            "pts": [f.pts for f in frames],
            "meta": [dict(f.meta) for f in frames],
        }}
        self.n_batches += 1
        # batch pts = latest input, like every merging element (paper §III)
        return Buffer(stacked, pts=max(f.pts for f in frames), meta=meta)

    # -- timeout flush ------------------------------------------------------
    def _watch(self) -> None:
        import time
        while self._running:
            with self._lock:
                deadline = self._deadline
            if deadline is None:
                self._wake.wait(timeout=0.05)
                self._wake.clear()
                continue
            delay = deadline - time.monotonic()
            if delay > 0:
                self._wake.wait(timeout=delay)
                self._wake.clear()
                continue
            with self._flush_lock:
                with self._lock:
                    # re-check under lock: chain() may have just flushed
                    out = None
                    if (self._deadline is not None
                            and time.monotonic() >= self._deadline):
                        out = self._close_batch()
                        if out is not None:
                            self.n_timeout_flushes += 1
                if out is not None:
                    try:
                        self.srcpad.push(out)
                    except BaseException as exc:  # noqa: BLE001 - bus-reported
                        self.post_error(exc)
                        return

    def start(self) -> None:
        if self.max_wait_s is None:
            return
        self._running = True
        self._timer = threading.Thread(target=self._watch,
                                       name=f"batcher:{self.name}", daemon=True)
        self._timer.start()

    def stop(self) -> None:
        self._running = False
        self._wake.set()
        if self._timer is not None:
            self._timer.join(timeout=2.0)
            self._timer = None
        with self._lock:
            self._pending.clear()
            self._deadline = None


class TensorUnbatcher(Element):
    """Split a batched buffer back into per-frame buffers (zero-copy).

    With ``meta["batch"]`` present (produced by TensorBatcher), original
    per-frame ``pts``/``meta`` are restored.  Otherwise the leading axis
    is treated as the batch axis and frames inherit the batch pts/meta.
    """

    def __init__(self, name: str):
        super().__init__(name)
        self.add_sink_pad()
        self.add_src_pad()
        self.n_frames = 0

    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos:
            self.handle_eos(pad, buf)
            return
        info = buf.meta.get(BATCH_META_KEY)
        chunks = [np.asarray(c) for c in buf.chunks]
        if info is not None:
            n = int(info["size"])
            pts_list, meta_list = info["pts"], info["meta"]
        else:
            n = chunks[0].shape[0]
            pts_list = [buf.pts] * n
            meta_list = [buf.meta] * n
        for j in range(n):
            # chunk[j] is a view into the batched array — no copy
            self.srcpad.push(Buffer(tuple(c[j] for c in chunks),
                                    pts=pts_list[j], meta=meta_list[j]))
            self.n_frames += 1
