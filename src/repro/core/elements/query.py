"""tensor_query — network-transparent pipeline edges (paper §III-C).

NNStreamer's ``tensor_query_serversrc``/``tensor_query_serversink``
let a pipeline serve requests from *other* processes/devices: tensors
arrive over a socket, flow through the pipeline like any local stream,
and results return to the requesting peer.  This module reproduces the
pair for the LLM serving path: prompts come in as int32 token tensors,
per-request token deltas stream back as they are generated, and a DONE
frame carries the final sequence plus terminal status.

Wire format (one TCP connection per client, frames in both directions)::

    header  := !2sBBIBBdI   (network byte order, 22 bytes)
               magic "TQ" | version | msg_type | qid | lane | status
               | deadline (f64 relative seconds, 0 = none) | payload_len
    payload := dtype_code u8 | ndim u8 | ndim * dim u32 | raw bytes (LE)
               (MSG_ERROR carries a UTF-8 message instead of a tensor)

Message types: ``REQUEST`` client->server (prompt tensor; lane +
deadline honoured), ``TOKENS`` server->client (incremental new-token
delta), ``DONE`` server->client (full token tensor + terminal status),
``ERROR`` (malformed/oversized request, or a request-level failure; an
ERROR with qid 0xFFFFFFFF is connection-scoped — protocol desync, the
peer closes after sending it), ``CANCEL`` client->server (abandon a
request: the server evicts it and answers ``DONE(status=cancelled)``
with whatever tokens it generated), ``CREDIT`` client->server (u32
payload: grant N more TOKENS frames for this qid — credit-based flow
control; at zero credit the server *pauses* that route's TOKENS in a
bounded per-request buffer instead of dropping them, and a route whose
buffer overflows is killed with ``status=overrun``).  ``qid`` is
chosen by the client and is scoped to its connection, so the server
routes responses by (connection, qid) while the engine schedules by its
own request id.

Version 2 added CANCEL/CREDIT and the credit semantics.  A frame whose
version does not match is answered with a connection-scoped ERROR and
the connection is closed — after a header disagreement the stream can
never be resynchronized, so failing loudly beats silently desyncing.

``TensorQueryServerSrc`` pushes one buffer per request: a ``(pad_to,)``
int32 row, left-padded with zeros (the engine treats leading zeros as
padding), with ``meta["query"]`` carrying the transport routing fields
consumed by ``ServeEngine.as_pipeline_filter(use_meta=True)`` and
``TensorQueryServerSink``.  The client side lives in
``repro.serving.net``.
"""
from __future__ import annotations

import collections
import socket
import struct
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..element import Element, Pad
from ..stream import Buffer
from .sources import SourceElement

MAGIC = b"TQ"
VERSION = 2                         # v2: CANCEL/CREDIT + credit flow control
HDR = struct.Struct("!2sBBIBBdI")   # magic, ver, type, qid, lane, status,
                                    # deadline, payload_len
MSG_REQUEST, MSG_TOKENS, MSG_DONE, MSG_ERROR = 1, 2, 3, 4
MSG_CANCEL, MSG_CREDIT = 5, 6
CONN_QID = 0xFFFFFFFF               # qid of connection-scoped ERROR frames
# absurd-length guard: a corrupted/hostile header must fail the parse,
# not commit the reader to a multi-GB recv
MAX_PAYLOAD = 64 * 1024 * 1024

LANE_CODES = {"interactive": 0, "batch": 1}
LANE_NAMES = {v: k for k, v in LANE_CODES.items()}
STATUS_CODES = {"ok": 0, "timeout": 1, "expired": 2, "cancelled": 3,
                "oom": 4, "error": 5, "overrun": 6}
STATUS_NAMES = {v: k for k, v in STATUS_CODES.items()}
_DTYPE_CODES = {"int32": 1, "float32": 2, "int64": 3, "uint8": 4}
_DTYPE_NAMES = {v: k for k, v in _DTYPE_CODES.items()}


class ProtocolError(ValueError):
    """Unrecoverable framing error (bad magic, version mismatch, absurd
    payload length): the byte stream cannot be resynchronized, so the
    peer must answer with a connection-scoped ERROR and close."""


def pack_tensor(arr: np.ndarray) -> bytes:
    """dtype code, ndim, dims (u32 each), then little-endian raw bytes."""
    arr = np.asarray(arr)
    name = str(arr.dtype)
    if name not in _DTYPE_CODES:
        raise ValueError(f"unsupported wire dtype {name!r}")
    head = struct.pack("!BB", _DTYPE_CODES[name], arr.ndim)
    dims = struct.pack(f"!{arr.ndim}I", *arr.shape)
    return head + dims + arr.astype(arr.dtype.newbyteorder("<")).tobytes()


def unpack_tensor(payload: bytes) -> np.ndarray:
    code, ndim = struct.unpack_from("!BB", payload, 0)
    if code not in _DTYPE_NAMES:
        raise ValueError(f"unknown wire dtype code {code}")
    shape = struct.unpack_from(f"!{ndim}I", payload, 2)
    dtype = np.dtype(_DTYPE_NAMES[code]).newbyteorder("<")
    raw = payload[2 + 4 * ndim:]
    n = int(np.prod(shape)) if ndim else 1
    if len(raw) != n * dtype.itemsize:
        raise ValueError(f"tensor payload size mismatch: {len(raw)} bytes "
                         f"for shape {shape} {dtype}")
    return np.frombuffer(raw, dtype=dtype).reshape(shape).astype(
        _DTYPE_NAMES[code])


def pack_frame(msg_type: int, qid: int, payload: bytes = b"", *,
               lane: int = 0, status: int = 0, deadline: float = 0.0) -> bytes:
    return HDR.pack(MAGIC, VERSION, msg_type, qid, lane, status,
                    deadline, len(payload)) + payload


def pack_credit(n: int) -> bytes:
    """CREDIT payload: a single u32 grant."""
    return struct.pack("!I", int(n))


def unpack_credit(payload: bytes) -> int:
    if len(payload) != 4:
        raise ValueError(f"CREDIT payload must be 4 bytes, got {len(payload)}")
    return struct.unpack("!I", payload)[0]


def recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly ``n`` bytes; None on orderly EOF at a frame edge."""
    chunks: List[bytes] = []
    got = 0
    while got < n:
        part = sock.recv(n - got)
        if not part:
            if got == 0:
                return None
            raise ConnectionError("peer closed mid-frame")
        chunks.append(part)
        got += len(part)
    return b"".join(chunks)


def read_frame(sock: socket.socket
               ) -> Optional[Tuple[int, int, int, int, float, bytes]]:
    """-> (msg_type, qid, lane, status, deadline, payload) or None on EOF."""
    hdr = recv_exact(sock, HDR.size)
    if hdr is None:
        return None
    magic, ver, msg_type, qid, lane, status, deadline, plen = HDR.unpack(hdr)
    if magic != MAGIC:
        raise ProtocolError(f"bad frame magic {magic!r}")
    if ver != VERSION:
        raise ProtocolError(
            f"unsupported tensor_query version {ver} (this peer speaks "
            f"{VERSION}); refusing to parse further — the stream cannot "
            "be resynchronized across a header disagreement")
    if plen > MAX_PAYLOAD:
        raise ProtocolError(
            f"frame payload length {plen} exceeds the {MAX_PAYLOAD}-byte "
            "cap — corrupted or hostile header")
    payload = recv_exact(sock, plen) if plen else b""
    if plen and payload is None:
        raise ConnectionError("peer closed mid-frame")
    return msg_type, qid, lane, status, deadline, payload


class QueryConnection:
    """One accepted client connection with a bounded, non-blocking
    outbound path.

    ``send_frame`` only *enqueues*: a dedicated writer thread drains the
    per-connection queue into the socket, so a slow or dead client can
    never stall the caller — in particular the engine's streaming
    callback, which fires from inside the decode/drain path and must
    return immediately for every other resident slot's sake.  The queue
    is bounded: best-effort TOKENS deltas are dropped on overflow
    (``n_dropped`` counts them; the DONE frame carries the authoritative
    full sequence), while terminal DONE/ERROR frames always enqueue
    (their number is bounded by requests in flight).  A failed socket
    write marks the connection dead and discards the backlog; frame
    order is preserved because the writer is the sole sender.

    **Credit-based flow control** (protocol v2): once a client sends a
    CREDIT frame for a qid, that route switches from best-effort to
    credited — each TOKENS frame spends one credit, and at zero credit
    frames *pause* in a bounded per-qid buffer instead of dropping.
    ``grant_credit`` refills and flushes in order.  A route whose pause
    buffer overflows (the client never refilled) reports ``"overrun"``
    to the caller, which kills the request with ``status=overrun``.
    The terminal DONE/ERROR frame flushes any still-paused TOKENS ahead
    of itself — bounded by ``pause_limit`` — so a credited route never
    *loses* tokens, it only defers them.
    """

    def __init__(self, sock: socket.socket, addr, max_outbound: int = 256,
                 pause_limit: int = 64, fault_plan=None):
        self.sock = sock
        self.addr = addr
        self.alive = True
        self.max_outbound = int(max_outbound)
        self.pause_limit = int(pause_limit)
        self.n_dropped = 0
        self.n_paused = 0               # TOKENS frames ever paused
        self.n_overruns = 0             # routes killed by pause overflow
        self._credit: Dict[int, int] = {}        # qid -> remaining credit
        self._paused: Dict[int, collections.deque] = {}
        self._faults = fault_plan
        self._q: collections.deque = collections.deque()
        self._q_lock = threading.Lock()
        self._q_event = threading.Event()
        self._sending = False           # writer mid-sendall (close() flush)
        self._writer = threading.Thread(
            target=self._write_loop, name=f"qconn:{addr}:writer", daemon=True)
        self._writer.start()

    def send_frame(self, msg_type: int, qid: int, payload: bytes = b"", *,
                   status: int = 0) -> bool:
        """Enqueue one frame for the writer thread; never blocks.
        Returns False if the connection is dead or a best-effort TOKENS
        frame was dropped on queue overflow.  Terminal DONE/ERROR
        frames flush the qid's paused TOKENS ahead of themselves and
        retire its credit state — the route is over either way."""
        if not self.alive:
            return False
        frame = pack_frame(msg_type, qid, payload, status=status)
        with self._q_lock:
            if msg_type in (MSG_DONE, MSG_ERROR):
                for paused in self._paused.pop(qid, ()):
                    self._q.append(paused)
                self._credit.pop(qid, None)
            elif len(self._q) >= self.max_outbound and msg_type == MSG_TOKENS:
                self.n_dropped += 1
                return False
            self._q.append(frame)
        self._q_event.set()
        return True

    def send_tokens(self, qid: int, payload: bytes):
        """Enqueue a TOKENS delta under the route's flow-control mode.

        Returns True (sent), False (dead connection, or dropped on
        overflow in legacy best-effort mode), ``"paused"`` (zero
        credit: buffered until the client refills), or ``"overrun"``
        (pause buffer overflow: the caller must kill the request)."""
        if not self.alive:
            return False
        with self._q_lock:
            credit = self._credit.get(qid)
            if credit is None:               # legacy best-effort route
                pass
            elif credit > 0:
                self._credit[qid] = credit - 1
            else:
                buf = self._paused.setdefault(qid, collections.deque())
                if len(buf) >= self.pause_limit:
                    self.n_overruns += 1
                    return "overrun"
                buf.append(pack_frame(MSG_TOKENS, qid, payload))
                self.n_paused += 1
                return "paused"
            frame = pack_frame(MSG_TOKENS, qid, payload)
            if len(self._q) >= self.max_outbound:
                self.n_dropped += 1
                return False
            self._q.append(frame)
        self._q_event.set()
        return True

    def grant_credit(self, qid: int, n: int) -> None:
        """Refill a route's TOKENS credit (switches it to credited mode
        on first grant) and flush its paused frames in order."""
        flushed = False
        with self._q_lock:
            credit = self._credit.get(qid, 0) + max(0, int(n))
            buf = self._paused.get(qid)
            while credit > 0 and buf:
                self._q.append(buf.popleft())
                credit -= 1
                flushed = True
            if buf is not None and not buf:
                self._paused.pop(qid, None)
            self._credit[qid] = credit
        if flushed:
            self._q_event.set()

    def n_paused_for(self, qid: int) -> int:
        with self._q_lock:
            return len(self._paused.get(qid, ()))

    @property
    def n_outbound(self) -> int:
        """Frames queued but not yet written to the socket."""
        with self._q_lock:
            return len(self._q)

    def _kill_socket(self) -> None:
        """Tear the transport down from the writer side.  ``shutdown``
        before ``close`` matters: the reader thread is blocked in
        ``recv`` holding a reference to the open file description, so a
        bare ``close`` would neither send FIN to the peer nor unblock
        the reader — the peer would hang instead of seeing EOF."""
        self.alive = False
        with self._q_lock:
            self._q.clear()
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass

    def _write_loop(self) -> None:
        while True:
            with self._q_lock:
                frame = self._q.popleft() if self._q else None
                if frame is None:
                    self._q_event.clear()
                else:
                    self._sending = True
            if frame is None:
                if not self.alive:
                    return
                self._q_event.wait(timeout=0.5)
                continue
            # fault seam: chaos plans inject send-side failures here (the
            # plan is duck-typed so the core layer needs no serving import)
            fault = self._faults.fire("server_send") if self._faults else None
            if fault is not None:
                if fault.action == "stall":
                    time.sleep(fault.stall_s)
                elif fault.action in ("close", "partial"):
                    if fault.action == "partial":
                        try:
                            self.sock.sendall(frame[:fault.cut_at])
                        except OSError:
                            pass
                    self._kill_socket()
                    return
            try:
                self.sock.sendall(frame)
            except OSError:
                self._kill_socket()
                return
            finally:
                with self._q_lock:
                    self._sending = False

    def close(self, flush_timeout: float = 1.0) -> None:
        # bounded flush: frames already queued (e.g. the protocol-error
        # ERROR the reader posted just before closing) must reach the
        # wire before the socket is torn down under the writer
        deadline = time.monotonic() + max(0.0, flush_timeout)
        while self.alive and time.monotonic() < deadline:
            with self._q_lock:
                idle = not self._q and not self._sending
            if idle:
                break
            time.sleep(0.005)
        self.alive = False
        self._q_event.set()             # wake the writer so it can exit
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


class TensorQueryServerSrc(SourceElement):
    """Accept tensor-query clients and push one buffer per request.

    Each REQUEST frame becomes a ``(pad_to,)`` int32 row (left-padded
    with zeros so a downstream ``tensor_batcher`` can stack rows of
    different prompt lengths) with routing metadata::

        meta["query"] = {"conn": QueryConnection, "qid": int,
                         "lane": "interactive"|"batch",
                         "deadline": float|None,   # relative seconds
                         "prompt_len": int, "t_arrival": float}

    Oversized or malformed requests are answered with an ERROR frame and
    never enter the pipeline.

    ``on_cancel(conn, qid)`` — if given — receives MSG_CANCEL frames
    (the server resolves the route and evicts the request); without it
    a CANCEL is answered directly with an empty ``DONE(cancelled)``.
    CREDIT frames are absorbed locally (``conn.grant_credit``).  During
    a drain (``stop_accepting()``) new REQUESTs are rejected with an
    ERROR while open connections keep streaming their in-flight work.
    """

    def __init__(self, name: str, host: str = "127.0.0.1", port: int = 0,
                 pad_to: int = 64, backlog: int = 16,
                 on_cancel: Optional[
                     Callable[[QueryConnection, int], None]] = None,
                 pause_limit: int = 64, fault_plan=None):
        super().__init__(name)
        self.host, self.port = host, int(port)
        self.pad_to = int(pad_to)
        self.backlog = int(backlog)
        self.on_cancel = on_cancel
        self.pause_limit = int(pause_limit)
        self.fault_plan = fault_plan
        self.draining = False
        self._listener: Optional[socket.socket] = None
        self._threads: List[threading.Thread] = []
        self.connections: List[QueryConnection] = []
        self.n_requests = 0
        self.n_rejected = 0
        self.n_cancels = 0
        self.n_conn_errors = 0          # connections dropped during setup/read
        self._eos_sent = False

    @property
    def address(self) -> Tuple[str, int]:
        return self.host, self.port

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        self._running = True
        self._eos_sent = False
        lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        lst.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        lst.bind((self.host, self.port))
        lst.listen(self.backlog)
        self.port = lst.getsockname()[1]
        self._listener = lst
        t = threading.Thread(target=self._accept_loop,
                             name=f"qsrc:{self.name}:accept", daemon=True)
        t.start()
        self._threads.append(t)

    def stop_accepting(self) -> None:
        """Enter drain mode: close the listener and reject any further
        REQUEST frames; open connections keep flowing.  ``shutdown``
        before ``close``: the accept thread blocked in ``accept()``
        holds a reference to the open file description, so a bare
        ``close`` would leave the kernel socket listening (and the
        thread happily accepting) until that syscall returned."""
        self.draining = True
        if self._listener is not None:
            try:
                self._listener.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._listener.close()
            except OSError:
                pass
            self._listener = None

    def stop(self) -> None:
        self._running = False
        if self._listener is not None:
            try:
                self._listener.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._listener.close()
            except OSError:
                pass
            self._listener = None
        for conn in list(self.connections):
            conn.close()
        for t in self._threads:
            t.join(timeout=2.0)
        self._threads = []
        # flush any partial batch downstream exactly once
        if not self._eos_sent:
            self._eos_sent = True
            self.srcpad.push(Buffer.eos_buffer())

    # -- network side -------------------------------------------------------
    def _accept_loop(self) -> None:
        while self._running and self._listener is not None:
            try:
                sock, addr = self._listener.accept()
            except OSError:
                return                     # listener closed by stop()/drain
            try:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                conn = QueryConnection(sock, addr,
                                       pause_limit=self.pause_limit,
                                       fault_plan=self.fault_plan)
                self.connections.append(conn)
                t = threading.Thread(
                    target=self._reader, args=(conn,),
                    name=f"qsrc:{self.name}:{addr}", daemon=True)
                t.start()
                self._threads.append(t)
            except Exception:              # one bad socket, not the loop
                self.n_conn_errors += 1
                try:
                    sock.close()
                except OSError:
                    pass

    def _reader(self, conn: QueryConnection) -> None:
        while self._running and conn.alive:
            try:
                frame = read_frame(conn.sock)
            except ProtocolError as exc:
                # the stream cannot be resynchronized: tell the peer why
                # (connection-scoped qid), then drop only this connection
                self.n_conn_errors += 1
                conn.send_frame(MSG_ERROR, CONN_QID, str(exc).encode(),
                                status=STATUS_CODES["error"])
                break
            except (OSError, ConnectionError, ValueError):
                self.n_conn_errors += 1
                break
            if frame is None:
                break
            msg_type, qid, lane, _status, deadline, payload = frame
            if msg_type == MSG_CANCEL:
                self.n_cancels += 1
                try:
                    if self.on_cancel is not None:
                        self.on_cancel(conn, qid)
                    else:
                        conn.send_frame(
                            MSG_DONE, qid,
                            pack_tensor(np.zeros((0,), np.int32)),
                            status=STATUS_CODES["cancelled"])
                except Exception as exc:   # cancel must never kill the conn
                    conn.send_frame(MSG_ERROR, qid,
                                    f"cancel failed: {exc}".encode(),
                                    status=STATUS_CODES["error"])
                continue
            if msg_type == MSG_CREDIT:
                try:
                    conn.grant_credit(qid, unpack_credit(payload))
                except ValueError as exc:
                    conn.send_frame(MSG_ERROR, qid, str(exc).encode(),
                                    status=STATUS_CODES["error"])
                continue
            if msg_type != MSG_REQUEST:
                conn.send_frame(MSG_ERROR, qid,
                                f"unexpected message type {msg_type}".encode(),
                                status=STATUS_CODES["error"])
                continue
            try:
                self._handle_request(conn, qid, lane, deadline, payload)
            except Exception as exc:       # request-level isolation: fail
                self.n_rejected += 1       # this qid, keep the connection
                conn.send_frame(MSG_ERROR, qid,
                                f"request failed: {exc}".encode(),
                                status=STATUS_CODES["error"])
                continue
        conn.close()

    def _handle_request(self, conn: QueryConnection, qid: int, lane: int,
                        deadline: float, payload: bytes) -> None:
        if self.draining:
            self.n_rejected += 1
            conn.send_frame(MSG_ERROR, qid, b"server draining",
                            status=STATUS_CODES["error"])
            return
        try:
            prompt = np.asarray(unpack_tensor(payload), np.int32).reshape(-1)
        except ValueError as exc:
            self.n_rejected += 1
            conn.send_frame(MSG_ERROR, qid, str(exc).encode(),
                            status=STATUS_CODES["error"])
            return
        if prompt.size == 0 or prompt.size > self.pad_to:
            self.n_rejected += 1
            conn.send_frame(
                MSG_ERROR, qid,
                f"prompt length {prompt.size} outside (0, {self.pad_to}]"
                .encode(), status=STATUS_CODES["error"])
            return
        row = np.zeros((self.pad_to,), np.int32)
        row[self.pad_to - prompt.size:] = prompt
        now = time.monotonic()
        meta = {"query": {
            "conn": conn, "qid": qid,
            "lane": LANE_NAMES.get(lane, "interactive"),
            "deadline": deadline if deadline > 0 else None,
            "prompt_len": int(prompt.size), "t_arrival": now,
        }}
        self.n_requests += 1
        self.srcpad.push(Buffer(row, pts=now, meta=meta))


class TensorQueryServerSink(Element):
    """Send each finished request back to its client as a DONE frame.

    Expects per-request buffers (downstream of ``tensor_unbatcher``)
    whose meta carries the ``query`` routing dict from
    ``TensorQueryServerSrc`` plus the ``status`` / ``n_tokens`` fields
    the engine filter wrote back.  Buffers without routing metadata are
    counted and dropped (e.g. locally injected test traffic).

    ``on_done(meta)`` — if given — fires after the terminal frame is
    handed to the connection, whether or not the send succeeded; the
    server uses it to drop its (request -> connection) route the moment
    a request reaches a terminal state."""

    def __init__(self, name: str,
                 on_done: Optional[Callable[[Dict[str, Any]], None]] = None):
        super().__init__(name)
        self.add_sink_pad()
        self.on_done = on_done
        self.n_sent = 0
        self.n_errors = 0
        self.n_unroutable = 0
        self.eos_seen = threading.Event()

    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos:
            self.eos_seen.set()
            return
        q = buf.meta.get("query") if isinstance(buf.meta, dict) else None
        conn = q.get("conn") if isinstance(q, dict) else None
        if conn is None:
            self.n_unroutable += 1
            return
        status_name = buf.meta.get("status", "ok")
        status = STATUS_CODES.get(status_name, STATUS_CODES["error"])
        # count before the send: a client that acts on the DONE frame
        # (and e.g. reads this counter) must never observe it lagging
        self.n_sent += 1
        if status_name == "error":
            # request-level failure: the client gets an ERROR frame with
            # the failure message instead of a token tensor
            self.n_errors += 1
            msg = str(buf.meta.get("error", "request failed")).encode()
            ok = conn.send_frame(MSG_ERROR, int(q["qid"]), msg, status=status)
        else:
            tokens = np.asarray(buf.chunks[0], np.int32).reshape(-1)
            n = buf.meta.get("n_tokens")
            if n is not None:
                tokens = tokens[:int(n)]
            ok = conn.send_frame(MSG_DONE, int(q["qid"]), pack_tensor(tokens),
                                 status=status)
        if not ok:
            self.n_sent -= 1          # connection died under the send
        if self.on_done is not None:
            self.on_done(buf.meta)    # terminal: the route is dead either way
