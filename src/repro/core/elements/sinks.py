"""Sink elements: AppSink (pull queue), TensorSink (callback), FakeSink."""
from __future__ import annotations

import queue as _queue
import threading
from typing import Callable, List, Optional

from ..element import Element, Pad
from ..stream import Buffer


class AppSink(Element):
    """Buffers are pulled by the application: ``sink.pull(timeout)``."""

    def __init__(self, name: str, max_size: int = 0, drop: bool = False):
        super().__init__(name)
        self.add_sink_pad()
        self._q: _queue.Queue = _queue.Queue(maxsize=max_size)
        self.drop = drop
        self.n_received = 0
        self.eos_seen = threading.Event()

    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos:
            self.eos_seen.set()
            self._q.put(buf)
            return
        self.n_received += 1
        if self.drop:
            try:
                self._q.put_nowait(buf)
            except _queue.Full:
                pass
        else:
            self._q.put(buf)

    def pull(self, timeout: Optional[float] = 5.0) -> Optional[Buffer]:
        try:
            return self._q.get(timeout=timeout)
        except _queue.Empty:
            return None


class TensorSink(Element):
    """Invoke a callback per buffer (NNStreamer tensor_sink new-data signal)."""

    def __init__(self, name: str, callback: Optional[Callable[[Buffer], None]] = None,
                 keep: bool = False):
        super().__init__(name)
        self.add_sink_pad()
        self.callback = callback
        self.keep = keep
        self.buffers: List[Buffer] = []
        self.n_received = 0
        self.eos_seen = threading.Event()

    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos:
            self.eos_seen.set()
            return
        self.n_received += 1
        if self.keep:
            self.buffers.append(buf)
        if self.callback is not None:
            self.callback(buf)


class FakeSink(Element):
    """Discard everything (counts frames)."""

    def __init__(self, name: str):
        super().__init__(name)
        self.add_sink_pad()
        self.n_received = 0
        self.eos_seen = threading.Event()

    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos:
            self.eos_seen.set()
            return
        self.n_received += 1
