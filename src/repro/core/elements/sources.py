"""Source elements.

``AppSrc`` — application-driven push source (paper: streams connected
from application threads).  ``VideoTestSrc`` — synthetic video frames at
a target fps.  ``SensorSrc``/``TensorSrcIIO`` — synthetic sensor streams
(the Linux IIO / Tizen Sensor Framework analogues): configurable rate and
channel count, deterministic waveform so tests are reproducible.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

import numpy as np

from ..element import Element, Pad
from ..stream import Buffer, MediaSpec, TensorSpec


class SourceElement(Element):
    """Base for thread-driven sources."""

    def __init__(self, name: str, num_buffers: int = -1, rate: Optional[float] = None):
        super().__init__(name)
        self.num_buffers = int(num_buffers)   # -1 = unlimited
        self.rate = rate                      # Hz; None = as fast as possible
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self.add_src_pad()

    def create(self, index: int) -> Buffer:
        raise NotImplementedError

    def _run(self) -> None:
        index = 0
        period = (1.0 / self.rate) if self.rate else 0.0
        next_t = time.monotonic()
        while self._running and (self.num_buffers < 0 or index < self.num_buffers):
            if period:
                now = time.monotonic()
                if now < next_t:
                    time.sleep(next_t - now)
                next_t += period
            try:
                buf = self.create(index)
                # stream-time pts (gst running time), not arrival wall-clock:
                # keeps sync policies deterministic for bursty sources
                buf.pts = index * period if period else float(index)
                self.srcpad.push(buf)
            except BaseException as exc:  # noqa: BLE001
                self.post_error(exc)
                return
            index += 1
        if self._running:
            self.srcpad.push(Buffer.eos_buffer())

    def start(self) -> None:
        self._running = True
        self._thread = threading.Thread(target=self._run, name=f"src:{self.name}",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._running = False
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None


class AppSrc(Element):
    """Push buffers from application code: ``appsrc.push(buf)``."""

    def __init__(self, name: str, spec=None):
        super().__init__(name)
        self.add_src_pad(spec=spec)

    def push(self, data, pts: Optional[float] = None, meta=None) -> None:
        buf = data if isinstance(data, Buffer) else Buffer(data, pts=pts, meta=meta)
        self.srcpad.push(buf)

    def end_of_stream(self) -> None:
        self.srcpad.push(Buffer.eos_buffer())


class VideoTestSrc(SourceElement):
    """Synthetic video frames (H, W, C) uint8 — moving gradient pattern."""

    def __init__(self, name: str, width: int = 224, height: int = 224,
                 channels: int = 3, num_buffers: int = -1,
                 rate: Optional[float] = None, seed: int = 0):
        super().__init__(name, num_buffers=num_buffers, rate=rate)
        self.width, self.height, self.channels = width, height, channels
        self.seed = seed
        self.srcpad.spec = MediaSpec("video/x-raw", format="RGB", width=width,
                                     height=height, channels=channels, rate=rate)

    def create(self, index: int) -> Buffer:
        h, w, c = self.height, self.width, self.channels
        row = (np.arange(w, dtype=np.uint16)[None, :] + index * 7 + self.seed)
        col = (np.arange(h, dtype=np.uint16)[:, None] * 3)
        frame = ((row + col)[:, :, None] + np.arange(c, dtype=np.uint16) * 85) % 256
        return Buffer(frame.astype(np.uint8), meta={"frame_index": index})


class SensorSrc(SourceElement):
    """Synthetic multi-channel sensor samples (channels,) float32."""

    def __init__(self, name: str, channels: int = 3, num_buffers: int = -1,
                 rate: Optional[float] = None, seed: int = 0):
        super().__init__(name, num_buffers=num_buffers, rate=rate)
        self.channels = channels
        self.seed = seed
        self.srcpad.spec = TensorSpec(dims=(channels,), dtype="float32", framerate=rate)

    def create(self, index: int) -> Buffer:
        t = index * 0.01 + self.seed
        phase = np.arange(self.channels, dtype=np.float32)
        sample = np.sin(2 * np.pi * (0.5 + 0.25 * phase) * t + phase).astype(np.float32)
        return Buffer(sample, meta={"sample_index": index})


class TensorSrcIIO(SensorSrc):
    """Alias element mirroring NNStreamer's Tensor-Src-IIO."""
