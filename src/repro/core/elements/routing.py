"""Stream-path control elements.

  * Tee            — duplicate a stream to N branches (functional parallelism)
  * TensorMux      — bundle N ``other/tensor`` streams -> one ``other/tensors``
  * TensorDemux    — unbundle ``other/tensors`` -> N ``other/tensor``
  * TensorMerge    — combine N tensors into ONE tensor (concat / stack)
  * TensorSplit    — slice one tensor into N tensors along an axis
  * InputSelector / OutputSelector / Valve — dynamic flow control

Mux/Demux are zero-copy: they only re-bundle the chunk tuple.  Merge and
Split follow the paper's dimension algebra: from two 3x4 streams, Merge
creates 6x4 (concat dim0), 3x8 (concat dim1) or 3x4x2 (stack); Mux
creates {3x4, 3x4}.  NB dims are gst innermost-first; numpy shapes are
reversed, which these elements handle internally.
"""
from __future__ import annotations

import threading
from typing import List, Optional

import numpy as np

from ..element import Element, Pad
from ..stream import Buffer
from ..sync import SyncCollector, SyncPolicy, stamp_latest


class Tee(Element):
    def __init__(self, name: str, num_src_pads: int = 0):
        super().__init__(name)
        self.add_sink_pad()
        for i in range(num_src_pads):
            self.add_src_pad(f"src_{i}")

    def request_src_pad(self) -> Pad:
        return self.add_src_pad(f"src_{len(self.srcpads)}")

    def link(self, downstream, srcpad=None, sinkpad=None):
        if srcpad is None:
            free = [p for p in self.srcpads.values() if p.peer is None]
            src = free[0] if free else self.request_src_pad()
            srcpad = src.name
        return super().link(downstream, srcpad=srcpad, sinkpad=sinkpad)

    def chain(self, pad: Pad, buf: Buffer) -> None:
        for p in self.srcpads.values():
            p.push(buf)


class _SyncedNToOne(Element):
    """Shared machinery for Mux and Merge (sync policies + EOS)."""

    def __init__(self, name: str, num_sinks: int, sync: str = "slowest"):
        super().__init__(name)
        policy, base = SyncPolicy.parse(sync)
        for i in range(num_sinks):
            self.add_sink_pad(f"sink_{i}")
        self.add_src_pad()
        self._indices = {f"sink_{i}": i for i in range(num_sinks)}
        self.collector = SyncCollector(num_sinks, policy=policy, base_index=base)
        self._eos_sent = False
        self._eos_lock = threading.Lock()

    def request_sink_pad(self) -> Pad:
        raise ValueError(f"{self.name}: fixed sink pads; set num_sinks at creation")

    def combine(self, bufs: List[Buffer]) -> Buffer:
        raise NotImplementedError

    def chain(self, pad: Pad, buf: Buffer) -> None:
        idx = self._indices[pad.name]
        if buf.eos:
            self.collector.offer(idx, buf)
            self._maybe_eos()
            return
        ready = self.collector.offer(idx, buf)
        if ready is not None:
            out = self.combine(ready)
            self.srcpad.push(out)
        # a collection may have drained the queue of an already-ended pad
        self._maybe_eos()

    def _maybe_eos(self) -> None:
        """Forward EOS as soon as no further output is possible — e.g.
        the base pad ended under ``base:<idx>`` sync, even if other
        pads are still live."""
        with self._eos_lock:
            if self._eos_sent:
                return
            if self.collector.all_eos() or self.collector.exhausted():
                self._eos_sent = True
                self.srcpad.push(Buffer.eos_buffer())


class TensorMux(_SyncedNToOne):
    """N x other/tensor -> other/tensors (zero-copy bundle)."""

    def combine(self, bufs: List[Buffer]) -> Buffer:
        chunks = tuple(c for b in bufs for c in b.chunks)
        meta: dict = {}
        for b in bufs:
            meta.update(b.meta)
        return Buffer(chunks, pts=stamp_latest(bufs), meta=meta)


class TensorDemux(Element):
    """other/tensors -> N x other/tensor (zero-copy unbundle).

    ``tensorpick`` optionally selects a subset, mirroring NNStreamer.
    """

    def __init__(self, name: str, num_src_pads: int, tensorpick: Optional[List[int]] = None):
        super().__init__(name)
        self.add_sink_pad()
        for i in range(num_src_pads):
            self.add_src_pad(f"src_{i}")
        self.tensorpick = tensorpick

    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos:
            self.handle_eos(pad, buf)
            return
        picks = self.tensorpick or range(len(buf.chunks))
        for out_idx, chunk_idx in enumerate(picks):
            p = self.srcpads.get(f"src_{out_idx}")
            if p is None:
                break
            p.push(Buffer((buf.chunks[chunk_idx],), pts=buf.pts, meta=buf.meta))


class TensorMerge(_SyncedNToOne):
    """N tensors -> ONE tensor.  mode: concat:<gst_dim> | stack."""

    def __init__(self, name: str, num_sinks: int, mode: str = "concat:0",
                 sync: str = "slowest"):
        super().__init__(name, num_sinks, sync=sync)
        if mode == "stack":
            self.mode, self.gst_dim = "stack", None
        elif mode.startswith("concat"):
            self.mode = "concat"
            self.gst_dim = int(mode.split(":", 1)[1]) if ":" in mode else 0
        else:
            raise ValueError(f"unknown merge mode {mode!r}")

    def combine(self, bufs: List[Buffer]) -> Buffer:
        arrays = [np.asarray(b.data) for b in bufs]
        if self.mode == "stack":
            out = np.stack(arrays, axis=-1)  # new innermost-last np == gst new dim
        else:
            rank = arrays[0].ndim
            np_axis = rank - 1 - self.gst_dim  # gst dims are innermost-first
            out = np.concatenate(arrays, axis=np_axis)
        return Buffer(out, pts=stamp_latest(bufs))


class TensorSplit(Element):
    """ONE tensor -> N tensors, slicing along gst dim with given sizes."""

    def __init__(self, name: str, tensorseg: List[int], gst_dim: int = 0):
        super().__init__(name)
        self.add_sink_pad()
        self.tensorseg = list(tensorseg)
        self.gst_dim = gst_dim
        for i in range(len(tensorseg)):
            self.add_src_pad(f"src_{i}")

    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos:
            self.handle_eos(pad, buf)
            return
        arr = np.asarray(buf.data)
        np_axis = arr.ndim - 1 - self.gst_dim
        offs = 0
        for i, seg in enumerate(self.tensorseg):
            sl = [slice(None)] * arr.ndim
            sl[np_axis] = slice(offs, offs + seg)
            self.srcpads[f"src_{i}"].push(
                Buffer(arr[tuple(sl)], pts=buf.pts, meta=buf.meta))
            offs += seg


class InputSelector(Element):
    """N sink pads, forward only the active one."""

    def __init__(self, name: str, num_sinks: int, active: int = 0):
        super().__init__(name)
        for i in range(num_sinks):
            self.add_sink_pad(f"sink_{i}")
        self.add_src_pad()
        self.active = active
        self._eos = [False] * num_sinks

    def chain(self, pad: Pad, buf: Buffer) -> None:
        idx = int(pad.name.split("_")[1])
        if buf.eos:
            self._eos[idx] = True
            if all(self._eos):
                self.srcpad.push(buf)
            return
        if idx == self.active:
            self.srcpad.push(buf)


class OutputSelector(Element):
    """One sink pad, forward to the active src pad only."""

    def __init__(self, name: str, num_srcs: int, active: int = 0):
        super().__init__(name)
        self.add_sink_pad()
        for i in range(num_srcs):
            self.add_src_pad(f"src_{i}")
        self.active = active

    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos:
            self.handle_eos(pad, buf)
            return
        self.srcpads[f"src_{self.active}"].push(buf)


class Valve(Element):
    """drop=True discards buffers (dynamic flow control)."""

    def __init__(self, name: str, drop: bool = False):
        super().__init__(name)
        self.add_sink_pad()
        self.add_src_pad()
        self.drop = drop

    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos or not self.drop:
            self.srcpad.push(buf)
