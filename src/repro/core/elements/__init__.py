from .queue import Queue
from .sources import AppSrc, VideoTestSrc, SensorSrc, TensorSrcIIO
from .sinks import AppSink, TensorSink, FakeSink
from .converter import TensorConverter, TensorDecoder
from .filter import TensorFilter
from .routing import (Tee, TensorMux, TensorDemux, TensorMerge, TensorSplit,
                      InputSelector, OutputSelector, Valve)
from .aggregator import TensorAggregator, TensorRate
from .batcher import TensorBatcher, TensorUnbatcher
from .transform import TensorTransform
from .flow import TensorIf, TensorRepoSink, TensorRepoSrc, TensorRepo
from .query import (QueryConnection, TensorQueryServerSink,
                    TensorQueryServerSrc)

__all__ = [
    "Queue", "AppSrc", "VideoTestSrc", "SensorSrc", "TensorSrcIIO",
    "AppSink", "TensorSink", "FakeSink",
    "TensorConverter", "TensorDecoder", "TensorFilter",
    "Tee", "TensorMux", "TensorDemux", "TensorMerge", "TensorSplit",
    "InputSelector", "OutputSelector", "Valve",
    "TensorAggregator", "TensorRate", "TensorTransform",
    "TensorBatcher", "TensorUnbatcher",
    "TensorIf", "TensorRepoSink", "TensorRepoSrc", "TensorRepo",
    "QueryConnection", "TensorQueryServerSrc", "TensorQueryServerSink",
]
