"""TensorTransform — elementwise/layout operators on tensor streams.

Supports NNStreamer's operator set as a *chain*:
  typecast:<dtype>, add:<v>, subtract:<v>, multiply:<v>, divide:<v>,
  clamp:<lo>:<hi>, normalize (mean/std standardization), transpose:<perm>

Chains parse from gst-style option strings:
  ``option="typecast:float32,divide:255.0,subtract:0.5"``

Backends:
  * "numpy"  — eager, one pass per op (the naive baseline in E4 terms)
  * "fused"  — single fused pass via the Pallas transform kernel
               (interpret mode on CPU); arith chain is folded into one
               scale/bias/clamp affine op before launch.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..element import Element, Pad
from ..stream import Buffer, canonical_dtype


class TransformOp:
    def __init__(self, kind: str, *args):
        self.kind = kind
        self.args = args

    def __repr__(self):
        return f"TransformOp({self.kind}, {self.args})"


def parse_chain(option: str) -> List[TransformOp]:
    ops: List[TransformOp] = []
    for item in option.split(","):
        item = item.strip()
        if not item:
            continue
        parts = item.split(":")
        kind = parts[0]
        if kind == "typecast":
            ops.append(TransformOp("typecast", canonical_dtype(parts[1])))
        elif kind in ("add", "subtract", "multiply", "divide"):
            ops.append(TransformOp(kind, float(parts[1])))
        elif kind == "clamp":
            ops.append(TransformOp("clamp", float(parts[1]), float(parts[2])))
        elif kind == "normalize":
            ops.append(TransformOp("normalize"))
        elif kind == "transpose":
            perm = tuple(int(p) for p in parts[1:])
            ops.append(TransformOp("transpose", perm))
        else:
            raise ValueError(f"unknown transform op {kind!r}")
    return ops


def fold_affine(ops: Sequence[TransformOp]) -> Optional[Tuple[float, float, float, float, Optional[str]]]:
    """Fold a pure arith/typecast chain into (scale, bias, lo, hi, dtype).

    Returns None if the chain contains normalize/transpose (not foldable).
    y = clamp(x * scale + bias, lo, hi), then cast.
    """
    scale, bias = 1.0, 0.0
    lo, hi = -np.inf, np.inf
    out_dtype: Optional[str] = None
    for op in ops:
        if op.kind == "typecast":
            out_dtype = op.args[0]
        elif op.kind == "add":
            bias += op.args[0]
        elif op.kind == "subtract":
            bias -= op.args[0]
        elif op.kind == "multiply":
            scale *= op.args[0]
            bias *= op.args[0]
        elif op.kind == "divide":
            scale /= op.args[0]
            bias /= op.args[0]
        elif op.kind == "clamp":
            # clamp then further affine is NOT foldable in general; only
            # allow clamp as the terminal arith op
            lo, hi = op.args
        else:
            return None
    return scale, bias, lo, hi, out_dtype


def apply_chain_numpy(arr: np.ndarray, ops: Sequence[TransformOp]) -> np.ndarray:
    out = arr
    for op in ops:
        if op.kind == "typecast":
            out = out.astype(op.args[0])
        elif op.kind == "add":
            out = out + op.args[0]
        elif op.kind == "subtract":
            out = out - op.args[0]
        elif op.kind == "multiply":
            out = out * op.args[0]
        elif op.kind == "divide":
            out = out / op.args[0]
        elif op.kind == "clamp":
            out = np.clip(out, op.args[0], op.args[1])
        elif op.kind == "normalize":
            mean = out.mean()
            std = out.std()
            out = (out - mean) / (std + 1e-8)
        elif op.kind == "transpose":
            out = np.transpose(out, op.args[0])
        else:
            raise ValueError(op.kind)
    return out


class TensorTransform(Element):
    def __init__(self, name: str, option: str, backend: str = "numpy"):
        super().__init__(name)
        self.add_sink_pad()
        self.add_src_pad()
        self.ops = parse_chain(option)
        self.backend = backend
        self._fused = None
        if backend == "fused":
            folded = fold_affine(self.ops)
            if folded is None:
                raise ValueError(
                    "fused backend requires a foldable arith/typecast chain")
            self._fused = folded

    def transform(self, pad: Pad, buf: Buffer) -> Optional[Buffer]:
        arr = np.asarray(buf.data)
        if self.backend == "fused":
            from ...kernels.transform import ops as tops
            scale, bias, lo, hi, dtype = self._fused
            out = np.asarray(tops.fused_transform(
                arr, scale=scale, bias=bias, lo=lo, hi=hi, out_dtype=dtype))
        else:
            out = apply_chain_numpy(arr, self.ops)
        return buf.with_chunks(out)
