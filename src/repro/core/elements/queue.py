"""Queue — the thread boundary that creates pipeline parallelism.

Matches GStreamer queue semantics that matter for the paper's results:
a queue decouples the upstream thread from downstream processing, so
stages before and after it execute concurrently (pipeline parallelism,
E1/E3).  Supports bounded capacity with either blocking or leaky
behaviour (``leaky=downstream`` drops the newest, ``leaky=upstream``
drops the oldest — used for QoS like the paper's live pipelines).
"""
from __future__ import annotations

import queue as _queue
import threading
from typing import Optional

from ..element import Element, Pad
from ..stream import Buffer


class Queue(Element):
    def __init__(self, name: str, max_size: int = 16, leaky: str = "no"):
        super().__init__(name)
        if leaky not in ("no", "upstream", "downstream"):
            raise ValueError(f"leaky must be no|upstream|downstream, got {leaky!r}")
        self.max_size = int(max_size)
        self.leaky = leaky
        self.add_sink_pad()
        self.add_src_pad()
        self._q: _queue.Queue = _queue.Queue(maxsize=self.max_size)
        self._worker: Optional[threading.Thread] = None
        self._running = False
        self.n_dropped = 0

    # -- upstream side ------------------------------------------------------
    def chain(self, pad: Pad, buf: Buffer) -> None:
        if not self._running:
            return
        if buf.eos:
            self._q.put(buf)  # EOS always enqueues (blocks if full)
            return
        if self.leaky == "downstream":
            try:
                self._q.put_nowait(buf)
            except _queue.Full:
                self.n_dropped += 1  # drop newest
        elif self.leaky == "upstream":
            while True:
                try:
                    self._q.put_nowait(buf)
                    return
                except _queue.Full:
                    try:
                        self._q.get_nowait()  # drop oldest
                        self.n_dropped += 1
                    except _queue.Empty:
                        pass
        else:
            self._q.put(buf)  # block upstream (backpressure)

    # -- downstream side ------------------------------------------------------
    def _run(self) -> None:
        while self._running:
            try:
                buf = self._q.get(timeout=0.1)
            except _queue.Empty:
                continue
            try:
                self.srcpad.push(buf)
            except BaseException as exc:  # noqa: BLE001 - bus-reported
                self.post_error(exc)
                return
            if buf.eos:
                return

    def start(self) -> None:
        self._running = True
        self._worker = threading.Thread(target=self._run, name=f"queue:{self.name}",
                                        daemon=True)
        self._worker.start()

    def stop(self) -> None:
        self._running = False
        if self._worker is not None:
            self._worker.join(timeout=2.0)
            self._worker = None
        # drain
        while True:
            try:
                self._q.get_nowait()
            except _queue.Empty:
                break
