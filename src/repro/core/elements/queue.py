"""Queue — the thread boundary that creates pipeline parallelism.

Matches GStreamer queue semantics that matter for the paper's results:
a queue decouples the upstream thread from downstream processing, so
stages before and after it execute concurrently (pipeline parallelism,
E1/E3).  Supports bounded capacity with either blocking or leaky
behaviour (``leaky=downstream`` drops the newest, ``leaky=upstream``
drops the oldest — used for QoS like the paper's live pipelines).

``workers`` > 1 runs multiple downstream worker threads pulling from
the same queue, so a *blocking* downstream stage (e.g. a tensor_filter
mounted on ``ServeEngine.as_pipeline_filter``, which parks until its
whole micro-batch finishes) can process several buffers concurrently.
Ordering across workers is not preserved — downstream must route by
metadata, as the tensor-query elements do.  EOS is forwarded exactly
once, after every in-flight buffer has fully drained downstream.
"""
from __future__ import annotations

import queue as _queue
import threading
from typing import Optional

from ..element import Element, Pad
from ..stream import Buffer


class Queue(Element):
    def __init__(self, name: str, max_size: int = 16, leaky: str = "no",
                 workers: int = 1):
        super().__init__(name)
        if leaky not in ("no", "upstream", "downstream"):
            raise ValueError(f"leaky must be no|upstream|downstream, got {leaky!r}")
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.max_size = int(max_size)
        self.leaky = leaky
        self.num_workers = int(workers)
        self.add_sink_pad()
        self.add_src_pad()
        self._q: _queue.Queue = _queue.Queue(maxsize=self.max_size)
        self._workers: list = []
        self._running = False
        self.n_dropped = 0
        # buffers enqueued but not yet fully pushed downstream; EOS waits
        # until this hits zero so it can never overtake an in-flight buffer
        self._outstanding = 0
        self._drain_cv = threading.Condition()

    # -- upstream side ------------------------------------------------------
    def chain(self, pad: Pad, buf: Buffer) -> None:
        if not self._running:
            return
        if buf.eos:
            self._q.put(buf)  # EOS always enqueues (blocks if full)
            return
        if self.leaky == "downstream":
            try:
                self._track(buf)
                self._q.put_nowait(buf)
            except _queue.Full:
                self._untrack()
                self.n_dropped += 1  # drop newest
        elif self.leaky == "upstream":
            self._track(buf)
            while True:
                try:
                    self._q.put_nowait(buf)
                    return
                except _queue.Full:
                    try:
                        self._q.get_nowait()  # drop oldest
                        self._untrack()
                        self.n_dropped += 1
                    except _queue.Empty:
                        pass
        else:
            self._track(buf)
            self._q.put(buf)  # block upstream (backpressure)

    def _track(self, buf: Buffer) -> None:
        with self._drain_cv:
            self._outstanding += 1

    def _untrack(self) -> None:
        with self._drain_cv:
            self._outstanding -= 1
            self._drain_cv.notify_all()

    # -- downstream side ------------------------------------------------------
    def _run(self) -> None:
        while self._running:
            try:
                buf = self._q.get(timeout=0.1)
            except _queue.Empty:
                continue
            if buf.eos:
                # exactly-once EOS: wait for every in-flight buffer (other
                # workers may still be blocked downstream), then forward
                with self._drain_cv:
                    while self._outstanding > 0 and self._running:
                        self._drain_cv.wait(timeout=0.1)
                try:
                    self.srcpad.push(buf)
                except BaseException as exc:  # noqa: BLE001 - bus-reported
                    self.post_error(exc)
                return
            try:
                self.srcpad.push(buf)
            except BaseException as exc:  # noqa: BLE001 - bus-reported
                self._untrack()
                self.post_error(exc)
                return
            self._untrack()

    def start(self) -> None:
        self._running = True
        self._workers = [
            threading.Thread(target=self._run,
                             name=f"queue:{self.name}:{i}", daemon=True)
            for i in range(self.num_workers)]
        for w in self._workers:
            w.start()

    def stop(self) -> None:
        self._running = False
        for w in self._workers:
            w.join(timeout=2.0)
        self._workers = []
        # drain
        while True:
            try:
                self._q.get_nowait()
            except _queue.Empty:
                break
