"""TensorConverter (media → tensor) and TensorDecoder (tensor → media/other).

Converter sub-plugins accept video / audio / text / flatbuf-like payloads
and emit ``other/tensor`` streams.  Decoder sub-plugins turn tensors back
into consumable results (bounding boxes, labels, overlay frames,
serialized dicts — the Flatbuf/Protobuf analogue is a plain dict payload).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

from ..element import Element, Pad
from ..stream import Buffer, TensorSpec


class TensorConverter(Element):
    """Convert media buffers to tensor buffers.

    modes:
      * "video"  — HWC uint8 frame -> tensor (optionally float32 scaled)
      * "audio"  — PCM samples -> tensor
      * "text"   — str -> uint8 codepoint tensor (fixed size, padded)
      * "passthrough" — already-tensor data, restamp only
      * custom: pass ``fn``
    """

    def __init__(self, name: str, mode: str = "video",
                 to_float: bool = False, text_size: int = 256,
                 fn: Optional[Callable[[np.ndarray], np.ndarray]] = None):
        super().__init__(name)
        self.add_sink_pad()
        self.add_src_pad()
        self.mode = mode
        self.to_float = to_float
        self.text_size = text_size
        self.fn = fn

    def transform(self, pad: Pad, buf: Buffer) -> Optional[Buffer]:
        if self.fn is not None:
            return buf.with_chunks(self.fn(buf.data))
        if self.mode == "text":
            text = buf.data if isinstance(buf.data, str) else str(buf.data)
            codes = np.frombuffer(text.encode("utf-8")[: self.text_size], dtype=np.uint8)
            out = np.zeros((self.text_size,), dtype=np.uint8)
            out[: codes.size] = codes
            return buf.with_chunks(out)
        arr = np.asarray(buf.data)
        if self.mode in ("video", "audio"):
            if self.to_float:
                arr = arr.astype(np.float32)
                if self.mode == "video":
                    arr = arr / 255.0
            return buf.with_chunks(arr)
        if self.mode == "passthrough":
            return buf.with_chunks(arr)
        raise ValueError(f"unknown converter mode {self.mode!r}")


class TensorDecoder(Element):
    """Decode tensor streams into results.

    sub-plugins ("mode"):
      * "argmax_label"   — classification tensor -> {"label": int, "score": float}
      * "bounding_boxes" — (N,5) [x,y,w,h,score] -> list of box dicts
      * "overlay"        — boxes + size -> transparent RGBA frame with boxes
      * "flatbuf"/"protobuf" — dict payload {"tensors": [...], "pts": ...}
      * custom: pass ``fn``
    """

    def __init__(self, name: str, mode: str = "argmax_label",
                 width: int = 0, height: int = 0,
                 fn: Optional[Callable[[Buffer], object]] = None):
        super().__init__(name)
        self.add_sink_pad()
        self.add_src_pad()
        self.mode = mode
        self.width, self.height = width, height
        self.fn = fn

    def transform(self, pad: Pad, buf: Buffer) -> Optional[Buffer]:
        if self.fn is not None:
            return buf.with_chunks(np.asarray(self.fn(buf), dtype=object).reshape(()))
        if self.mode == "argmax_label":
            scores = np.asarray(buf.data).reshape(-1)
            idx = int(np.argmax(scores))
            out = np.array((idx, float(scores[idx])), dtype=np.float32)
            new = buf.with_chunks(out)
            new.meta["label"] = idx
            return new
        if self.mode == "bounding_boxes":
            arr = np.asarray(buf.data).reshape(-1, 5)
            new = buf.with_chunks(arr)
            new.meta["boxes"] = [
                {"x": float(r[0]), "y": float(r[1]), "w": float(r[2]),
                 "h": float(r[3]), "score": float(r[4])} for r in arr]
            return new
        if self.mode == "overlay":
            arr = np.asarray(buf.data).reshape(-1, 5)
            frame = np.zeros((self.height, self.width, 4), dtype=np.uint8)
            for x, y, w, h, score in arr:
                x0, y0 = int(max(x, 0)), int(max(y, 0))
                x1 = int(min(x + w, self.width - 1))
                y1 = int(min(y + h, self.height - 1))
                if x1 <= x0 or y1 <= y0:
                    continue
                frame[y0:y1, x0, :] = (0, 255, 0, 255)
                frame[y0:y1, x1, :] = (0, 255, 0, 255)
                frame[y0, x0:x1, :] = (0, 255, 0, 255)
                frame[y1, x0:x1, :] = (0, 255, 0, 255)
            return buf.with_chunks(frame)
        if self.mode in ("flatbuf", "protobuf"):
            payload = {"tensors": [np.asarray(c) for c in buf.chunks], "pts": buf.pts}
            new = Buffer(buf.chunks, pts=buf.pts, meta=dict(buf.meta))
            new.meta["payload"] = payload
            return new
        raise ValueError(f"unknown decoder mode {self.mode!r}")
