"""TensorFilter — the neural network as an atomic pipeline filter.

The NNFW sub-plugin structure of the paper maps to *backends*:

  * ``python``      — arbitrary callable (the custom-C/Python sub-plugin)
  * ``jax``         — jax.jit compiled callable placed on a device
  * ``jax-sharded`` — pjit'd callable on a Mesh with in/out shardings
                      (the NPU / accelerator-delegation analogue)

A filter is resolved either from a direct ``fn`` or from the model
registry (``model="glm4-9b"``), which mirrors loading a .tflite/.snpe
artifact by path.  Filters keep per-invocation latency statistics so
benchmarks can report per-stage numbers like the paper's Table II.

Micro-batching: buffers produced by ``TensorBatcher`` carry
``meta["batch"]`` and a leading batch axis.  The filter pads such
batches up to the next power-of-2 *bucket* so a jitted backend only
ever sees ``log2(max_batch)+1`` distinct leading shapes — one XLA
compilation per bucket rather than one per observed batch size.
Outputs are sliced back to the true batch size and the batch metadata
is forwarded untouched for the downstream ``TensorUnbatcher``.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..element import Element, Pad
from ..stream import Buffer
from .batcher import BATCH_META_KEY


def bucket_for(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, clamped to max_batch."""
    if n >= max_batch:
        return max_batch
    b = 1
    while b < n:
        b <<= 1
    return b


class TensorFilter(Element):
    def __init__(self, name: str, fn: Optional[Callable] = None,
                 model: Optional[str] = None, framework: str = "python",
                 device=None, mesh=None, in_shardings=None, out_shardings=None,
                 outputs_meta_key: Optional[str] = None, max_batch: int = 8,
                 pass_meta: bool = False):
        super().__init__(name)
        if pass_meta and framework != "python":
            raise ValueError(
                f"{name}: pass_meta requires the python backend — jitted "
                f"backends cannot trace per-frame metadata dicts")
        self.pass_meta = bool(pass_meta)
        self.add_sink_pad()
        self.add_src_pad()
        self.framework = framework
        self.model_name = model
        self._raw_fn = fn
        self._device = device
        self._mesh = mesh
        self._in_shardings = in_shardings
        self._out_shardings = out_shardings
        self._compiled: Optional[Callable] = None
        self.outputs_meta_key = outputs_meta_key
        self.max_batch = int(max_batch)
        # latency stats (paper Table II rows 3-5)
        self.n_invocations = 0
        self.total_latency_s = 0.0
        # bucket cache stats: bucket size -> [n_batches, n_frames, total_s]
        self.bucket_stats: Dict[int, List[float]] = {}

    # -- backend resolution -------------------------------------------------
    def _resolve(self) -> Callable:
        if self._compiled is not None:
            return self._compiled
        fn = self._raw_fn
        if fn is None:
            if self.model_name is None:
                raise ValueError(f"{self.name}: TensorFilter needs fn= or model=")
            from ...registry import get_model
            fn = get_model(self.model_name)
        if self.framework == "python":
            self._compiled = fn
        elif self.framework == "jax":
            import jax
            jitted = jax.jit(fn)
            if self._device is not None:
                dev = self._device

                def run(*args):
                    args = [jax.device_put(a, dev) for a in args]
                    return jitted(*args)
                self._compiled = run
            else:
                self._compiled = jitted
        elif self.framework == "jax-sharded":
            import jax
            self._compiled = jax.jit(fn, in_shardings=self._in_shardings,
                                     out_shardings=self._out_shardings)
        else:
            raise ValueError(f"unknown TensorFilter framework {self.framework!r}")
        return self._compiled

    # -- invocation -----------------------------------------------------------
    def invoke(self, chunks: Sequence[Any],
               metas: Optional[List[Optional[dict]]] = None) -> Tuple[Any, ...]:
        fn = self._resolve()
        t0 = time.perf_counter()
        if self.framework.startswith("jax"):
            import jax
            ctx = self._mesh if self._mesh is not None else _nullcontext()
            with ctx:
                out = fn(*chunks)
            out = jax.block_until_ready(out)
        elif metas is not None:
            out = fn(*chunks, metas=metas)
        else:
            out = fn(*chunks)
        self.total_latency_s += time.perf_counter() - t0
        self.n_invocations += 1
        if isinstance(out, (tuple, list)):
            return tuple(out)
        return (out,)

    def invoke_batched(self, chunks: Sequence[Any], n: int,
                       metas: Optional[List[Optional[dict]]] = None,
                       ) -> Tuple[Any, ...]:
        """Invoke on a leading-batch-axis stack of ``n`` frames.

        Pads the batch axis up to the power-of-2 bucket so a jitted
        backend compiles at most once per bucket, then slices outputs
        back to the true size.  When ``pass_meta`` supplies per-frame
        ``metas``, pad rows carry ``None``.
        """
        bucket = bucket_for(n, self.max_batch)
        if bucket > n:
            chunks = [np.concatenate(
                [c, np.zeros((bucket - n,) + tuple(np.asarray(c).shape[1:]),
                             np.asarray(c).dtype)], axis=0)
                for c in chunks]
            if metas is not None:
                metas = list(metas) + [None] * (bucket - n)
        t0 = time.perf_counter()
        out = self.invoke(chunks, metas=metas)
        stat = self.bucket_stats.setdefault(bucket, [0, 0, 0.0])
        stat[0] += 1
        stat[1] += n
        stat[2] += time.perf_counter() - t0
        if bucket > n:
            out = tuple(np.asarray(o)[:n] for o in out)
        return out

    @property
    def n_bucket_compilations(self) -> int:
        """Distinct padded leading shapes seen == jit compilations
        attributable to batch-size variation (one per bucket)."""
        return len(self.bucket_stats)

    def transform(self, pad: Pad, buf: Buffer) -> Optional[Buffer]:
        info = buf.meta.get(BATCH_META_KEY)
        if info is not None:
            metas = info["meta"] if self.pass_meta else None
            out_chunks = self.invoke_batched(buf.chunks, int(info["size"]),
                                             metas=metas)
        else:
            out_chunks = self.invoke(
                buf.chunks, metas=[buf.meta] if self.pass_meta else None)
        new = buf.with_chunks(out_chunks)
        if self.outputs_meta_key:
            new.meta[self.outputs_meta_key] = out_chunks
        return new

    @property
    def mean_latency_s(self) -> float:
        return self.total_latency_s / max(self.n_invocations, 1)


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False
