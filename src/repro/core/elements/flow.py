"""Value-based flow control and recurrence.

``TensorIf`` routes buffers by a predicate over tensor *values* without
application-thread intervention (paper §III).  Compound conditions over
reductions of the tensor are supported.

``TensorRepoSink`` / ``TensorRepoSrc`` share a named repository slot,
constructing a recurring data path *without* a stream cycle (GStreamer
prohibits graph cycles; the paper's E4 discussion explains why).  The
repo is a 1-deep mailbox per name: sink overwrites, src reads
most-recent (or a seed value before the first write).
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Optional

import numpy as np

from ..element import Element, Pad
from ..stream import Buffer


class TensorIf(Element):
    """Route to src_true / src_false by a predicate on the tensor.

    Built-in compare ops on a reduction of the tensor:
      reduction: "mean" | "max" | "min" | "sum" | "elem:<i>"
      compare:   "gt" | "ge" | "lt" | "le" | "eq" | "ne"
    or pass ``predicate=callable(Buffer)->bool``.
    behavior for the false branch: "route" (to src_false) or "drop".
    """

    def __init__(self, name: str, reduction: str = "mean", compare: str = "gt",
                 value: float = 0.0, behavior: str = "route",
                 predicate: Optional[Callable[[Buffer], bool]] = None):
        super().__init__(name)
        self.add_sink_pad()
        self.add_src_pad("src_true")
        if behavior == "route":
            self.add_src_pad("src_false")
        self.reduction = reduction
        self.compare = compare
        self.value = value
        self.behavior = behavior
        self.predicate = predicate

    def _reduce(self, arr: np.ndarray) -> float:
        r = self.reduction
        if r == "mean":
            return float(arr.mean())
        if r == "max":
            return float(arr.max())
        if r == "min":
            return float(arr.min())
        if r == "sum":
            return float(arr.sum())
        if r.startswith("elem:"):
            return float(arr.reshape(-1)[int(r.split(":")[1])])
        raise ValueError(f"unknown reduction {r!r}")

    def _test(self, buf: Buffer) -> bool:
        if self.predicate is not None:
            return bool(self.predicate(buf))
        x = self._reduce(np.asarray(buf.data))
        v = self.value
        return {"gt": x > v, "ge": x >= v, "lt": x < v,
                "le": x <= v, "eq": x == v, "ne": x != v}[self.compare]

    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos:
            self.handle_eos(pad, buf)
            return
        if self._test(buf):
            self.srcpads["src_true"].push(buf)
        elif self.behavior == "route":
            self.srcpads["src_false"].push(buf)
        # behavior == "drop": discard


class TensorRepo:
    """Process-wide named repository (mailbox per slot)."""

    _slots: Dict[str, "._Slot"] = {}
    _lock = threading.Lock()

    class _Slot:
        def __init__(self):
            self.lock = threading.Lock()
            self.value: Optional[Buffer] = None

    @classmethod
    def slot(cls, name: str) -> "_Slot":
        with cls._lock:
            if name not in cls._slots:
                cls._slots[name] = cls._Slot()
            return cls._slots[name]

    @classmethod
    def reset(cls) -> None:
        with cls._lock:
            cls._slots.clear()


class TensorRepoSink(Element):
    """Write buffers into a named repo slot (terminates a branch)."""

    def __init__(self, name: str, slot: str):
        super().__init__(name)
        self.add_sink_pad()
        self._slot = TensorRepo.slot(slot)
        self.eos_seen = threading.Event()

    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos:
            self.eos_seen.set()
            return
        with self._slot.lock:
            self._slot.value = buf


class TensorRepoSrc(Element):
    """On each input ("tick") emit {input, latest repo value}.

    NNStreamer's tensor_reposrc is a pure source; for deterministic tests
    we implement the common recurrent pattern: it has a sink pad (the
    driving stream) and bundles the repo value with each driving frame,
    seeding with zeros of ``seed_shape`` before the first write.
    """

    def __init__(self, name: str, slot: str, seed_shape=None, seed_dtype="float32"):
        super().__init__(name)
        self.add_sink_pad()
        self.add_src_pad()
        self._slot = TensorRepo.slot(slot)
        self.seed_shape = tuple(seed_shape) if seed_shape else None
        self.seed_dtype = seed_dtype

    def transform(self, pad: Pad, buf: Buffer) -> Optional[Buffer]:
        with self._slot.lock:
            latest = self._slot.value
        if latest is None:
            if self.seed_shape is None:
                raise ValueError(f"{self.name}: repo empty and no seed_shape")
            state = np.zeros(self.seed_shape, dtype=self.seed_dtype)
        else:
            state = latest.chunks[0]
        return Buffer(tuple(buf.chunks) + (state,), pts=buf.pts, meta=buf.meta)
