"""TensorAggregator (temporal frame merging) and TensorRate (QoS).

Aggregator merges ``frames_in`` consecutive frames into one output
(e.g. frames 2i and 2i+1 -> one frame, halving the rate), optionally with
``frames_flush`` stride for overlapping windows — the LSTM/seq2seq feeding
pattern from the paper.  Output timestamp = latest input (paper §III).

TensorRate throttles/duplicates to a target framerate and exposes simple
QoS counters (in/out/dropped/duplicated), mirroring NNStreamer's
tensor_rate element.
"""
from __future__ import annotations

import collections
from typing import Deque, List, Optional

import numpy as np

from ..element import Element, Pad
from ..stream import Buffer


class TensorAggregator(Element):
    def __init__(self, name: str, frames_in: int = 2,
                 frames_flush: Optional[int] = None, concat_axis: int = 0,
                 stack: bool = False):
        super().__init__(name)
        self.add_sink_pad()
        self.add_src_pad()
        if frames_in < 1:
            raise ValueError("frames_in must be >= 1")
        self.frames_in = frames_in
        # stride; clamped to the window size (overlap-or-exact semantics)
        self.frames_flush = min(frames_flush or frames_in, frames_in)
        if self.frames_flush < 1:
            raise ValueError("frames_flush must be >= 1")
        self.concat_axis = concat_axis
        self.stack = stack
        self._window: Deque[Buffer] = collections.deque()

    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos:
            self._window.clear()
            self.handle_eos(pad, buf)
            return
        self._window.append(buf)
        if len(self._window) < self.frames_in:
            return
        frames = list(self._window)[: self.frames_in]
        arrays = [np.asarray(b.data) for b in frames]
        if self.stack:
            out = np.stack(arrays, axis=0)
        else:
            out = np.concatenate(arrays, axis=self.concat_axis)
        pts = max(b.pts for b in frames)
        self.srcpad.push(Buffer(out, pts=pts, meta=frames[-1].meta))
        for _ in range(min(self.frames_flush, len(self._window))):
            self._window.popleft()


class TensorRate(Element):
    """Rate control: drop frames above target rate; framerate override.

    With ``throttle=True`` drops buffers arriving faster than
    ``framerate`` (live QoS).  Counters mirror tensor_rate properties.
    """

    def __init__(self, name: str, framerate: float, throttle: bool = True):
        super().__init__(name)
        self.add_sink_pad()
        self.add_src_pad()
        self.framerate = float(framerate)
        self.throttle = throttle
        self._period = 1.0 / self.framerate
        self._last_out_pts: Optional[float] = None
        self.n_in = 0
        self.n_out = 0
        self.n_dropped = 0

    def chain(self, pad: Pad, buf: Buffer) -> None:
        if buf.eos:
            self.handle_eos(pad, buf)
            return
        self.n_in += 1
        if self.throttle and self._last_out_pts is not None:
            if buf.pts - self._last_out_pts < self._period * (1 - 1e-6):
                self.n_dropped += 1
                return
        self._last_out_pts = buf.pts
        self.n_out += 1
        self.srcpad.push(buf)
