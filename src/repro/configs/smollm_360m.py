"""smollm-360m — llama-arch small dense [hf:HuggingFaceTB/SmolLM-135M]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab_size=49152,
    norm="rmsnorm", mlp_act="swiglu", rope="rope",
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="hf:HuggingFaceTB/SmolLM-135M",
)
