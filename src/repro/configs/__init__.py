"""Config registry: ``get_config("glm4-9b")`` / ``--arch glm4-9b``."""
from __future__ import annotations

import importlib
from typing import Dict

from ..models.config import ModelConfig, smoke_variant
from .shapes import SHAPES, InputShape

_MODULES = {
    "whisper-tiny": "whisper_tiny",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "nemotron-4-340b": "nemotron_4_340b",
    "glm4-9b": "glm4_9b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "dbrx-132b": "dbrx_132b",
    "xlstm-350m": "xlstm_350m",
    "qwen2.5-32b": "qwen2_5_32b",
    "smollm-360m": "smollm_360m",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    cfg = mod.CONFIG
    return smoke_variant(cfg) if smoke else cfg


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke=smoke) for a in ARCH_IDS}


__all__ = ["get_config", "all_configs", "ARCH_IDS", "SHAPES", "InputShape"]
