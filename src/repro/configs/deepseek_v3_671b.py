"""deepseek-v3-671b — MoE with MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437]."""
from ..models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab_size=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  router="sigmoid_bias", routed_scale=2.5,
                  capacity_factor=1.25, first_dense_layers=3),
    prefix_d_ff=18432, mtp_depth=1,
    norm="rmsnorm", mlp_act="swiglu", rope="rope", rope_theta=10000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="arXiv:2412.19437",
)
