"""whisper-tiny — encoder-decoder audio LM [arXiv:2212.04356].

Conv/mel frontend is a STUB: input_specs feeds (B, 1500, 384) frame
embeddings.  max_seq is widened beyond the card's 448 so the assigned
train_4k shape lowers (structural adaptation, see DESIGN.md).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    n_enc_layers=4, enc_seq=1500,
    norm="layernorm", mlp_act="gelu", qkv_bias=True,
    rope="learned", tie_embeddings=True,
    max_seq=4096,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="arXiv:2212.04356",
)
