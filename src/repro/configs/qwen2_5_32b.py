"""qwen2.5-32b — dense GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B family]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab_size=152064,
    norm="rmsnorm", mlp_act="swiglu", qkv_bias=True,
    rope="rope", rope_theta=1_000_000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="hf:Qwen/Qwen2.5-0.5B",
)
