"""glm4-9b — dense GQA kv=2, RoPE (half rotary), QKV bias
[hf:THUDM/glm-4-9b]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=151552,
    norm="rmsnorm", mlp_act="swiglu", qkv_bias=True,
    rope="rope", rope_pct=0.5, rope_theta=10000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="hf:THUDM/glm-4-9b",
)
