"""dbrx-132b — fine-grained MoE: 16 experts top-4 [hf:databricks/dbrx-base]."""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    moe=MoEConfig(n_experts=16, top_k=4, d_expert=10752,
                  router="softmax", capacity_factor=1.25),
    norm="layernorm", mlp_act="swiglu", rope="rope", rope_theta=500_000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="hf:databricks/dbrx-base",
)
