"""jamba-v0.1-52b — hybrid Mamba+attention 1:7, MoE 16e top-2 every 2nd
layer [arXiv:2403.19887].  No positional encoding (rope=none)."""
from ..models.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    attn_layer_period=8, attn_layer_offset=4,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336,
                  layer_period=2, layer_offset=1, capacity_factor=1.25),
    norm="rmsnorm", mlp_act="swiglu", rope="none",
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="arXiv:2403.19887",
)
