"""xlstm-350m — sLSTM + mLSTM blocks, 7:1 ratio [arXiv:2405.04517].
d_ff=0: blocks carry their own up/down projections."""
from ..models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    ssm=SSMConfig(slstm_every=8),   # 7 mLSTM : 1 sLSTM
    norm="rmsnorm", rope="none", mlp_act="gelu",
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="arXiv:2405.04517",
)
