"""qwen2-vl-72b — VLM backbone with M-RoPE, dynamic resolution
[arXiv:2409.12191].  ViT frontend is a STUB: input_specs feeds
(B, vision_seq, d_model) projected patch embeddings."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    norm="rmsnorm", mlp_act="swiglu", qkv_bias=True,
    rope="mrope", mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
    vision_seq=1024,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="arXiv:2409.12191",
)
