"""nemotron-4-340b — dense GQA, squared-ReLU, partial rotary 50%
[arXiv:2402.16819]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab_size=256000,
    norm="layernorm", mlp_act="relu2", rope="rope", rope_pct=0.5,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="arXiv:2402.16819",
)
