from .pipeline import TokenStream, synthetic_batches, lm_batch_specs

__all__ = ["TokenStream", "synthetic_batches", "lm_batch_specs"]
