"""Data pipeline: deterministic synthetic token streams + batching.

Built on the repro.core stream framework where that matters (the ARS /
sensor experiments) and on a plain generator for LM training.  The
synthetic LM distribution is a mixture of skewed unigrams and copy
patterns so the loss actually decreases during the example train runs.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterator, Optional

import numpy as np

import jax.numpy as jnp
from jax import ShapeDtypeStruct


class TokenStream:
    """Deterministic pseudo-corpus: batch iterator of {tokens, labels}."""

    def __init__(self, vocab_size: int, seq_len: int, batch: int, seed: int = 0,
                 copy_period: int = 17):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch = batch
        self.rng = np.random.default_rng(seed)
        self.copy_period = copy_period

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        B, S, V = self.batch, self.seq_len, self.vocab_size
        # zipf-ish unigram base
        base = self.rng.zipf(1.3, size=(B, S + 1)) % V
        # inject copy structure: token[t] = token[t - copy_period]
        cp = self.copy_period
        for row in base:
            start = int(self.rng.integers(0, cp))
            src = row[start: S + 1 - cp: cp]
            row[start + cp: S + 1: cp][: len(src)] = src[: len(row[start + cp: S + 1: cp])]
        seq = base.astype(np.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def synthetic_batches(vocab_size: int, seq_len: int, batch: int, n: int,
                      seed: int = 0):
    it = TokenStream(vocab_size, seq_len, batch, seed)
    for _ in range(n):
        yield next(it)


def lm_batch_specs(batch: int, seq_len: int):
    """ShapeDtypeStructs for a training batch (dry-run input_specs)."""
    return {"tokens": ShapeDtypeStruct((batch, seq_len), jnp.int32),
            "labels": ShapeDtypeStruct((batch, seq_len), jnp.int32)}
