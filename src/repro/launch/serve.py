"""Serving launcher: batched generation through the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \
        --requests 8 --batch 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models import build_model
from ..serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = cfg.replace(param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_size=args.batch,
                         capacity=args.prompt_len + args.max_new + 8,
                         max_new_tokens=args.max_new)

    rng = np.random.default_rng(0)
    requests = [rng.integers(0, cfg.vocab_size,
                             rng.integers(4, args.prompt_len)).astype(np.int32)
                for _ in range(args.requests)]
    t0 = time.perf_counter()
    results = engine.serve(requests)
    wall = time.perf_counter() - t0
    total_tokens = sum(len(r.tokens) for r in results)
    print(f"served {len(results)} requests / {total_tokens} tokens "
          f"in {wall:.2f}s ({total_tokens / wall:.1f} tok/s)")
    for r in results[:3]:
        print(f"  req {r.request_id}: prompt[{len(r.prompt)}] -> "
              f"{r.tokens[:8]}... latency={r.latency_s:.3f}s")


if __name__ == "__main__":
    main()
