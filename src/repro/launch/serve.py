"""Serving launcher: continuous batching through the stream pipeline.

Requests are pushed into an appsrc, micro-batched by ``tensor_batcher``
(rate-adaptive: full batch or ``max_wait_ms``, whichever first), run
through the continuous-batching ServeEngine mounted as a
``tensor_filter``, and split back into per-request results by
``tensor_unbatcher``.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \
        --requests 8 --batch 4 --max-new 16
    PYTHONPATH=src python -m repro.launch.serve --smoke --direct  # no pipeline
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models import build_model
from ..models.config import ModelConfig, SSMConfig
from ..serving import ServeEngine

# demo-scale config per serving family (mirrors the conformance matrix
# in tests/conftest.py): --family serves any of them through the same
# paged engine — attention layers page, recurrent layers use state slabs
_FAM_BASE = ModelConfig(
    arch_id="fam-demo", family="dense", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    norm="rmsnorm", mlp_act="swiglu", rope="rope",
    param_dtype="float32", compute_dtype="float32")
_FAM_SSM = SSMConfig(d_state=16, d_conv=4, expand=2)
FAMILY_CONFIGS = {
    "transformer": _FAM_BASE,
    "mamba": _FAM_BASE.replace(arch_id="fam-mamba", family="hybrid",
                               ssm=_FAM_SSM, attn_layer_period=1,
                               attn_layer_offset=1),
    "xlstm": _FAM_BASE.replace(arch_id="fam-xlstm", family="ssm", d_ff=0,
                               n_kv_heads=4, rope="none",
                               ssm=SSMConfig(d_state=16, d_conv=4, expand=2,
                                             slstm_every=2)),
    "hybrid": _FAM_BASE.replace(arch_id="fam-hybrid", family="hybrid",
                                ssm=_FAM_SSM, attn_layer_period=2,
                                attn_layer_offset=0),
}


def _print_spec_stats(engine):
    ls = engine.loop_stats()
    if "n_spec_rounds" not in ls:
        return
    rounds = max(1, ls["n_spec_rounds"])
    print(f"speculative: K={ls['spec_k']}, {ls['n_spec_rounds']} rounds -> "
          f"{ls['n_spec_tokens']} tokens "
          f"({ls['n_spec_tokens'] / rounds:.2f}/round), accept rate "
          f"{ls['spec_accept_rate']:.2f}, hist {ls['spec_accept_hist']}")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-360m")
    ap.add_argument("--family", choices=["arch"] + sorted(FAMILY_CONFIGS),
                    default="arch",
                    help="serve a demo model of this family (transformer/"
                         "mamba/xlstm/hybrid) instead of --arch; recurrent "
                         "families run paged via per-slot state slabs")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=50.0)
    ap.add_argument("--direct", action="store_true",
                    help="call engine.serve() directly instead of the pipeline")
    ap.add_argument("--paged", choices=["auto", "on", "off"], default="auto",
                    help="block-paged KV cache (auto: on when the model "
                         "supports it)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged mode: tokens per KV block")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="paged mode: pool size (default: batch*capacity "
                         "worth of blocks)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="paged mode: prompt tokens cached per join step")
    ap.add_argument("--share-prefix", choices=["auto", "on", "off"],
                    default="auto",
                    help="paged mode: map requests' common prompt prefixes "
                         "onto already-resident KV blocks (copy-on-write; "
                         "auto: on whenever paged)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy decode; > 0 samples from "
                         "softmax(logits / temperature)")
    ap.add_argument("--top-k", type=int, default=None,
                    help="restrict sampling to the k highest logits")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling PRNG seed (per-request, per-step keys "
                         "are derived from it — identical across modes)")
    ap.add_argument("--shared-prompt", type=int, default=0,
                    help="give every request this many identical leading "
                         "prompt tokens (exercises prefix sharing)")
    ap.add_argument("--num-state-slots", type=int, default=None,
                    help="recurrent families: state slabs in the pool "
                         "(default: one per batch slot; fewer gates "
                         "admission like a small block pool)")
    ap.add_argument("--listen", type=int, default=None, metavar="PORT",
                    help="serve over TCP via the tensor_query elements "
                         "(0 = ephemeral port).  With --smoke, drives the "
                         "synthetic requests through a loopback client and "
                         "exits; otherwise serves until interrupted")
    ap.add_argument("--lanes", default="interactive",
                    help="comma list of priority lanes the smoke client "
                         "cycles through (e.g. 'interactive,batch'; batch "
                         "lane requests are preemptible)")
    ap.add_argument("--max-wait-ms-net", type=float, default=5.0,
                    help="--listen: micro-batch window of the server-side "
                         "tensor_batcher")
    ap.add_argument("--drain-timeout-s", type=float, default=30.0,
                    help="--listen (standing server): on SIGTERM/SIGINT, "
                         "stop admitting and give in-flight requests this "
                         "long to finish before cancelling them; every "
                         "client gets a terminal frame and the process "
                         "exits 0")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="serve tensor-parallel over the first N devices "
                         "(a (1, N) data×model mesh; paged mode only). "
                         "Weights shard by the training PartitionSpec "
                         "rules, the paged KV pool shards head_dim, and "
                         "decode output is token-identical to N=1. "
                         "On CPU, simulate devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--retain-cap", type=int, default=None,
                    help="paged mode: cap on retained (prefix-reusable) "
                         "free blocks; the oldest are retired beyond it "
                         "(default: unbounded)")
    ap.add_argument("--retain-ttl-s", type=float, default=None,
                    help="paged mode: retire retained blocks older than "
                         "this many seconds (default: no TTL)")
    ap.add_argument("--kv-dtype", choices=["f32", "bf16", "int8"],
                    default=None,
                    help="KV cache storage precision (default: engine "
                         "default, f32).  'int8' block-quantizes the paged "
                         "pool with per-row scales — ~3-4x the resident "
                         "requests at equal pool bytes, greedy-token drift "
                         "bounded by the drift-tolerance suite (paged mode "
                         "only; incompatible with --mesh and --spec-k)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft tokens proposed and "
                         "verified per burst round (0 = off; paged "
                         "transformer-family targets only — recurrent "
                         "state cannot roll back rejected tokens)")
    ap.add_argument("--draft-config", default=None, metavar="ARCH",
                    help="--spec-k: the draft model — an --arch id sharing "
                         "the target's vocabulary, or 'tiny' for an "
                         "auto-shrunken copy of the target config (the "
                         "default when --spec-k > 0)")
    ap.add_argument("--burst", type=int, default=8,
                    help="decode burst length K: fused device steps per "
                         "host round-trip when no admissions/prefills are "
                         "pending (1 = drain every token; the engine "
                         "degrades to 1 itself whenever the queue is "
                         "non-empty, so join latency is unchanged)")
    return ap


_RECURRENT_FAMILIES = ("mamba", "xlstm", "hybrid")


def validate_args(args) -> None:
    """Fail fast on flag combinations the engine would reject anyway —
    but deep inside construction, after weights are already built.  Each
    check is a one-line error naming both offending flags, raised before
    any model work starts."""
    if args.requests < 1:
        raise SystemExit("--requests must be >= 1")
    if args.shared_prompt >= args.prompt_len - 1:
        # the unique suffix needs at least one token of length spread
        raise SystemExit("--shared-prompt must be < --prompt-len - 1")
    if args.spec_k > 0:
        if args.mesh is not None:
            raise SystemExit(
                "--spec-k and --mesh are incompatible: speculative "
                "decoding under a device mesh is not implemented")
        if args.share_prefix == "on":
            raise SystemExit(
                "--spec-k and --share-prefix on are incompatible: the "
                "draft pool rides the target's page tables but COW forks "
                "only cover the target pool (leave --share-prefix auto)")
        if args.family in _RECURRENT_FAMILIES:
            raise SystemExit(
                f"--spec-k and --family {args.family} are incompatible: "
                "recurrent state cannot roll back rejected draft tokens")
        if args.paged == "off":
            raise SystemExit(
                "--spec-k and --paged off are incompatible: speculative "
                "rollback is arithmetic on the paged per-slot lengths")
    if args.kv_dtype == "int8":
        if args.paged == "off":
            raise SystemExit(
                "--kv-dtype int8 and --paged off are incompatible: "
                "quantized KV lives in the paged block pool")
        if args.spec_k > 0:
            raise SystemExit(
                "--kv-dtype int8 and --spec-k are incompatible: the "
                "draft/verify path is not quantization-aware")
        if args.mesh is not None:
            raise SystemExit(
                "--kv-dtype int8 and --mesh are incompatible: the scale "
                "pools have no sharding specs yet")


def main():
    args = build_parser().parse_args()
    validate_args(args)

    if args.family != "arch":
        cfg = FAMILY_CONFIGS[args.family]
    else:
        cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = cfg.replace(param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    draft_model = draft_params = None
    if args.spec_k > 0:
        name = args.draft_config or "tiny"
        if name == "tiny":
            # shrunken copy of the target: half the layers and width,
            # same head_dim and (crucially) the same vocabulary
            dcfg = cfg.replace(
                arch_id=f"{cfg.arch_id}-draft",
                n_layers=max(1, cfg.n_layers // 2),
                d_model=max(2 * cfg.n_heads, cfg.d_model // 2),
                n_heads=max(1, cfg.n_heads // 2),
                n_kv_heads=max(1, min(cfg.n_kv_heads, cfg.n_heads // 2)),
                d_ff=max(4, cfg.d_ff // 2) if cfg.d_ff else cfg.d_ff)
        else:
            dcfg = get_config(name, smoke=args.smoke)
        if args.smoke:
            dcfg = dcfg.replace(param_dtype="float32",
                                compute_dtype="float32")
        draft_model = build_model(dcfg)
        draft_params = draft_model.init(jax.random.PRNGKey(1))
        print(f"speculative decoding: K={args.spec_k}, draft "
              f"{dcfg.arch_id} ({dcfg.n_layers}L d{dcfg.d_model})")
    tri = {"auto": None, "on": True, "off": False}
    mesh = None
    if args.mesh is not None:
        from .mesh import make_serving_mesh
        mesh = make_serving_mesh(model=args.mesh)
        print(f"serving over mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}"
              f" ({jax.device_count()} device(s) visible)")
    engine = ServeEngine(model, params, batch_size=args.batch,
                         capacity=args.prompt_len + args.max_new + 8,
                         max_new_tokens=args.max_new,
                         paged=tri[args.paged],
                         block_size=args.block_size,
                         num_blocks=args.num_blocks,
                         prefill_chunk=args.prefill_chunk,
                         share_prefix=tri[args.share_prefix],
                         num_state_slots=args.num_state_slots,
                         burst=args.burst,
                         temperature=args.temperature,
                         top_k=args.top_k, seed=args.seed,
                         mesh=mesh, retain_cap=args.retain_cap,
                         retain_ttl_s=args.retain_ttl_s,
                         draft_model=draft_model, draft_params=draft_params,
                         spec_k=args.spec_k, kv_dtype=args.kv_dtype)

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, args.shared_prompt).astype(np.int32)
    lengths = [int(rng.integers(max(4, args.shared_prompt + 1),
                                args.prompt_len))
               for _ in range(args.requests)]
    requests = [np.concatenate(
                    [shared, rng.integers(0, cfg.vocab_size,
                                          n - len(shared)).astype(np.int32)])
                for n in lengths]

    if args.listen is not None:
        from ..serving import TensorQueryClient, TensorQueryServer
        lanes = [l.strip() for l in args.lanes.split(",") if l.strip()]
        server = TensorQueryServer(engine, port=args.listen,
                                   max_wait_ms=args.max_wait_ms_net,
                                   pad_to=args.prompt_len).start()
        print(f"tensor_query server listening on 127.0.0.1:{server.port} "
              f"(lanes: {', '.join(lanes)})")
        try:
            if not args.smoke:
                # standing server: SIGTERM/SIGINT triggers a graceful
                # drain — stop admitting, finish (or cancel) in-flight
                # work so every client holds a terminal frame, exit 0
                import signal
                stop_evt = threading.Event()

                def _on_signal(signum, frame):
                    del frame
                    print(f"signal {signum}: draining "
                          f"(timeout {args.drain_timeout_s:.0f}s)",
                          flush=True)
                    stop_evt.set()
                signal.signal(signal.SIGTERM, _on_signal)
                signal.signal(signal.SIGINT, _on_signal)
                while not stop_evt.wait(timeout=0.2):
                    pass
                clean = server.drain(timeout=args.drain_timeout_s)
                print("drain complete" if clean
                      else "drain timed out: remaining requests cancelled",
                      flush=True)
                return
            t0 = time.perf_counter()
            client = TensorQueryClient("127.0.0.1", server.port)
            qids = [client.submit(r, lane=lanes[i % len(lanes)])
                    for i, r in enumerate(requests)]
            rs = [client.result(q, timeout=300) for q in qids]
            wall = time.perf_counter() - t0
            total = sum(len(r.tokens) for r in rs if r.tokens is not None)
            print(f"served {len(rs)} requests / {total} tokens over TCP "
                  f"in {wall:.2f}s ({total / wall:.1f} tok/s)")
            for r in rs[:3]:
                print(f"  qid {r.qid}: status={r.status} "
                      f"ttft={r.ttft_s:.3f}s tokens={list(r.tokens[:8])}...")
            print(f"scheduler: prefills={engine.n_prefills} "
                  f"joins={engine.n_joins} evictions={engine.n_evictions} "
                  f"preemptions={engine.n_preemptions} "
                  f"restores={engine.n_restores} expired={engine.n_expired}")
            _print_spec_stats(engine)
            client.close()
        except KeyboardInterrupt:
            pass
        finally:
            server.stop()
        return

    t0 = time.perf_counter()
    if args.direct:
        results = engine.serve(requests)
        total_tokens = sum(len(r.tokens) for r in results)
        n_results = len(results)
    else:
        from ..core import parse_pipeline
        pipe = parse_pipeline(
            "appsrc name=req ! tensor_batcher max_batch=%d max_wait_ms=%s ! "
            "queue max_size=8 ! tensor_filter framework=python model=llm "
            "max_batch=%d ! tensor_unbatcher ! tensor_sink name=out keep=true"
            % (args.batch, args.max_wait_ms, args.batch),
            models={"llm": engine.as_pipeline_filter()})
        pipe.start()
        # batcher stacks frames, so pad prompts to a common length up front
        # (left-pad: the engine already treats leading zeros as padding)
        maxlen = max(lengths)
        for i, r in enumerate(requests):
            pipe["req"].push(np.pad(r, (maxlen - len(r), 0)),
                             meta={"request": i, "prompt_len": len(r)})
        pipe["req"].end_of_stream()
        pipe["out"].eos_seen.wait(timeout=300)
        pipe.stop()
        results = pipe["out"].buffers
        total_tokens = sum(np.asarray(b.data).size for b in results)
        n_results = len(results)
    wall = time.perf_counter() - t0

    print(f"served {n_results} requests / {total_tokens} tokens "
          f"in {wall:.2f}s ({total_tokens / wall:.1f} tok/s)")
    print(f"scheduler: prefills={engine.n_prefills} joins={engine.n_joins} "
          f"evictions={engine.n_evictions}"
          + (f" prefill_chunks={engine.n_prefill_chunks}" if engine.paged
             else ""))
    ls = engine.loop_stats()
    decoded = max(1, ls["n_device_steps"])
    print(f"decode loop: burst K={ls['burst']}, {ls['n_bursts']} bursts / "
          f"{ls['n_device_steps']} device steps, "
          f"{ls['n_host_syncs']} host syncs "
          f"({ls['n_host_syncs'] / decoded:.2f}/step), "
          f"{ls['n_state_uploads']} state uploads, "
          f"{ls['n_burst_early_exits']} early exits")
    _print_spec_stats(engine)
    if engine.paged:
        a = engine.allocator
        s = engine.pool_stats()
        print(f"paged cache: {a.num_blocks} blocks x {a.block_size} tokens, "
              f"{s['n_free']} free / {s['n_shared']} shared / "
              f"{s['n_private']} private after drain")
        print(f"kv storage: {s['kv_dtype']}, {s['bytes_per_block']} "
              f"bytes/block, {s['pool_bytes'] / 1e6:.2f} MB pool")
        if engine.state_store is not None:
            print(f"state store: {s['num_state_slots']} slabs, "
                  f"{s['n_state_free']} free / {s['n_state_live']} live "
                  "after drain (recurrent layers)")
        if engine.share_prefix:
            print(f"prefix sharing: {engine.n_prefix_hits} hits, "
                  f"{engine.n_shared_tokens} prompt tokens served from "
                  f"resident blocks, {engine.n_cow_forks} COW forks")
    if args.direct:
        for r in results[:3]:
            print(f"  req {r.request_id}: prompt[{len(r.prompt)}] -> "
                  f"{r.tokens[:8]}... latency={r.latency_s:.3f}s")
    else:
        for b in results[:3]:
            print(f"  req {b.meta.get('request')}: "
                  f"prompt_len={b.meta.get('prompt_len')} -> "
                  f"{np.asarray(b.data)[:8]}...")


if __name__ == "__main__":
    main()
