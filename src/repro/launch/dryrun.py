import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

MUST be run as a module entry point (the XLA flag above executes before
any jax import):

    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Prints compiled.memory_analysis() / cost_analysis() and writes a JSON
record (FLOPs, bytes, per-collective bytes, per-device memory) to
experiments/dryrun/ for the roofline analysis.
"""
import argparse
import json
import re
import time
import traceback

import jax

from ..configs import ARCH_IDS
from ..configs.shapes import SHAPES
from .mesh import make_production_mesh
from .specs import SKIPS, make_step_for_shape

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8}
_SHAPE_RE = re.compile(r"\b(pred|u8|s8|u16|s16|u32|s32|u64|s64|bf16|f16|f32|f64)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collectives(hlo_text: str):
    """Per-collective operand bytes from post-SPMD HLO.

    Operand types are not printed inline, so bytes derive from the result
    type + replica-group size: all-gather operand = result/G; all-reduce
    and all-to-all operand = result; reduce-scatter operand = result*G.
    """
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(.*?)\s(" + "|".join(COLLECTIVES) + r")(?:-start)?\(",
                      stripped)
        if not m or re.search(r"(all-\w+|collective-permute)-done\(", stripped):
            continue
        kind = m.group(2)
        result_bytes = sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(m.group(1)))
        if result_bytes == 0:
            continue
        g = _group_size(stripped)
        if kind == "all-gather":
            op_bytes = result_bytes // max(g, 1)
        elif kind == "reduce-scatter":
            op_bytes = result_bytes * g
        else:  # all-reduce, all-to-all, collective-permute
            op_bytes = result_bytes
        out[kind]["count"] += 1
        out[kind]["bytes"] += op_bytes
    return out


def _compile_and_measure(arch, shape_name, mesh, cfg=None, unroll=False,
                         model_opts=None):
    step, ins, ins_sh, out_sh, model, rcfg = make_step_for_shape(
        arch, shape_name, mesh, cfg=cfg, unroll=unroll, model_opts=model_opts)
    with mesh:
        lowered = jax.jit(step, in_shardings=ins_sh,
                          out_shardings=out_sh).lower(*ins)
        compiled = lowered.compile()
    rec = {}
    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
    cost = compiled.cost_analysis()
    if cost:
        rec["flops"] = float(cost.get("flops", 0.0))
        rec["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
        rec["transcendentals"] = float(cost.get("transcendentals", 0.0))
    try:
        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo)
        rec["hlo_lines"] = hlo.count("\n")
    except Exception as exc:  # noqa: BLE001
        rec["collectives_error"] = repr(exc)
    return rec, rcfg


def _extrapolate(c1, c2, n_periods):
    """cost(n) = cost(1 period) + (n-1) * per-period delta.

    XLA's HloCostAnalysis visits while bodies ONCE, so a scanned layer
    stack is undercounted by its trip count; compiling 1- and 2-period
    variants recovers the true totals (flops / bytes / collectives).
    """
    out = {}
    for k in ("flops", "bytes_accessed", "transcendentals"):
        if k in c1 and k in c2:
            out[k] = c1[k] + (n_periods - 1) * (c2[k] - c1[k])
    if "collectives" in c1 and "collectives" in c2:
        coll = {}
        for kind in COLLECTIVES:
            a, b = c1["collectives"][kind], c2["collectives"][kind]
            coll[kind] = {
                "count": a["count"] + (n_periods - 1) * (b["count"] - a["count"]),
                "bytes": a["bytes"] + (n_periods - 1) * (b["bytes"] - a["bytes"]),
            }
        out["collectives"] = coll
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            verbose: bool = True, extrapolate: bool = True) -> dict:
    from ..launch.specs import n_periods_of, reduced_period_cfg, resolve_config
    mesh = make_production_mesh(multi_pod=multi_pod)
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    rec = {"arch": arch, "shape": shape_name,
           "mesh": list(mesh.devices.shape), "multi_pod": multi_pod,
           "n_devices": mesh.devices.size, "status": "ok"}
    t0 = time.time()
    try:
        if (arch, shape_name) in SKIPS:
            rec["status"] = "skip"
            rec["reason"] = SKIPS[(arch, shape_name)]
            return _finish(rec, out_dir, tag, t0, verbose)
        full, cfg = _compile_and_measure(arch, shape_name, mesh)
        rec.update(full)
        rec["raw_flops"] = full.get("flops")
        rec["extrapolated"] = False
        if extrapolate:
            n = n_periods_of(cfg)
            rec["n_periods"] = n
            if n > 2:
                # unrolled reduced variants: every layer/chunk in the HLO,
                # so per-period deltas are true costs
                c1, _ = _compile_and_measure(arch, shape_name, mesh,
                                             cfg=reduced_period_cfg(cfg, 1),
                                             unroll=True)
                c2, _ = _compile_and_measure(arch, shape_name, mesh,
                                             cfg=reduced_period_cfg(cfg, 2),
                                             unroll=True)
                rec.update(_extrapolate(c1, c2, n))
                rec["extrapolated"] = True
    except Exception as exc:  # noqa: BLE001
        rec["status"] = "fail"
        rec["error"] = repr(exc)
        rec["traceback"] = traceback.format_exc()[-4000:]
    return _finish(rec, out_dir, tag, t0, verbose)


def _finish(rec, out_dir, tag, t0, verbose):
    rec["wall_s"] = round(time.time() - t0, 2)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        if rec["status"] == "ok":
            coll = rec.get("collectives", {})
            cbytes = sum(v["bytes"] for v in coll.values())
            print(f"[OK]   {tag}: flops={rec.get('flops', 0):.3e} "
                  f"bytes={rec.get('bytes_accessed', 0):.3e} "
                  f"coll={cbytes:.3e}B "
                  f"args={rec.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"temp={rec.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"wall={rec['wall_s']}s", flush=True)
        elif rec["status"] == "skip":
            print(f"[SKIP] {tag}: {rec['reason']}", flush=True)
        else:
            print(f"[FAIL] {tag}: {rec['error']}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-extrapolate", action="store_true",
                    help="skip the 1/2-period cost extrapolation compiles")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip combos whose JSON already has status ok/skip")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    combos = []
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = (False, True) if args.both_meshes else (args.multi_pod,)
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    n_ok = n_skip = n_fail = 0
    for a, s, mp in combos:
        if args.skip_existing:
            tag = f"{a}__{s}__{'pod2' if mp else 'pod1'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                with open(path) as f:
                    old = json.load(f)
                good = old.get("status") in ("ok", "skip")
                if good and (args.no_extrapolate or old.get("extrapolated")
                             or old.get("status") == "skip"
                             or old.get("n_periods", 99) <= 2):
                    n_ok += old["status"] == "ok"
                    n_skip += old["status"] == "skip"
                    print(f"[CACHED] {tag}", flush=True)
                    continue
        rec = run_one(a, s, mp, args.out, extrapolate=not args.no_extrapolate)
        n_ok += rec["status"] == "ok"
        n_skip += rec["status"] == "skip"
        n_fail += rec["status"] == "fail"
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skip, {n_fail} fail "
          f"of {len(combos)}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
