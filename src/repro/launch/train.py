"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --steps 100 --batch 8 --seq 128

Runs the real Trainer on the host devices.  ``--mesh host`` wraps the
step in pjit over whatever devices exist (data-parallel); the production
mesh path is exercised by dryrun.py.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config
from ..data import TokenStream
from ..models import build_model
from ..models.frontends import fake_audio_frames, fake_vision_patches
from ..training import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = cfg.replace(param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg)
    trainer = Trainer(model, peak_lr=args.lr, warmup=max(args.steps // 10, 1),
                      total_steps=args.steps)

    extra = None
    if cfg.family == "audio":
        extra = fake_audio_frames(cfg, args.batch)
    elif cfg.vision_seq:
        extra = fake_vision_patches(cfg, args.batch)

    stream = TokenStream(cfg.vocab_size, args.seq, args.batch, seed=0)

    def batches():
        for b in stream:
            if extra is not None:
                b = dict(b, extra_embeds=extra)
            yield b

    hist = trainer.fit(batches(), steps=args.steps, log_every=args.log_every)
    print(f"final loss: {hist[-1]['loss']:.4f} "
          f"(start {hist[0]['loss']:.4f})")
    if args.ckpt_dir:
        from ..checkpoint import save_checkpoint
        path = save_checkpoint(args.ckpt_dir, args.steps, trainer.state.params)
        print(f"checkpoint: {path}")


if __name__ == "__main__":
    main()
