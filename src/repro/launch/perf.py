import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")

"""Perf hillclimb driver (EXPERIMENTS.md §Perf).

Measures the three roofline terms for one (arch x shape) under a set of
optimization knobs, via the same extrapolated-compile methodology as the
dry-run:

    PYTHONPATH=src python -m repro.launch.perf --arch deepseek-v3-671b \
        --shape train_4k --tag chunk2048 --attn-chunk 2048
"""
import argparse
import dataclasses
import json
import time

import jax

from ..configs import ARCH_IDS
from ..configs.shapes import SHAPES
from .dryrun import COLLECTIVES, _compile_and_measure, _extrapolate
from .mesh import make_production_mesh
from .specs import n_periods_of, reduced_period_cfg, resolve_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def measure(arch: str, shape_name: str, *, model_opts=None, cfg_edit=None,
            multi_pod: bool = False, full_compile: bool = False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = resolve_config(arch, shape_name)
    if cfg_edit:
        cfg = cfg_edit(cfg)
    n = n_periods_of(cfg)
    rec = {}
    if full_compile:  # memory analysis needs the full scanned program
        full, _ = _compile_and_measure(arch, shape_name, mesh, cfg=cfg,
                                       model_opts=model_opts)
        rec.update({k: full[k] for k in ("argument_size_in_bytes",
                                         "temp_size_in_bytes") if k in full})
    c1, _ = _compile_and_measure(arch, shape_name, mesh,
                                 cfg=reduced_period_cfg(cfg, 1), unroll=True,
                                 model_opts=model_opts)
    c2, _ = _compile_and_measure(arch, shape_name, mesh,
                                 cfg=reduced_period_cfg(cfg, 2), unroll=True,
                                 model_opts=model_opts)
    rec.update(_extrapolate(c1, c2, n))
    coll = sum(v["bytes"] for v in rec.get("collectives", {}).values())
    rec["t_compute_s"] = rec.get("flops", 0) / PEAK_FLOPS
    rec["t_memory_s"] = rec.get("bytes_accessed", 0) / HBM_BW
    rec["t_collective_s"] = coll / LINK_BW
    rec["dominant"] = max(("t_compute_s", "t_memory_s", "t_collective_s"),
                          key=lambda k: rec[k])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=tuple(SHAPES), required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--attn-chunk", type=int, default=0)
    ap.add_argument("--acc-bf16", action="store_true")
    ap.add_argument("--probs-bf16", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--flat-dp", action="store_true",
                    help="use the model axis as extra data parallelism")
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--sliding-window", type=int, default=-1)
    ap.add_argument("--full-compile", action="store_true",
                    help="also run the scanned compile for memory analysis")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    model_opts = {}
    if args.attn_chunk:
        model_opts["attn_chunk"] = args.attn_chunk
    if args.acc_bf16:
        model_opts["acc_bf16"] = True
    if args.probs_bf16:
        model_opts["probs_bf16"] = True
    if args.seq_parallel:
        model_opts["seq_parallel"] = True
    if args.mla_absorb:
        model_opts["mla_absorb"] = True
    if args.flat_dp:
        model_opts["flat_dp"] = True

    def cfg_edit(cfg):
        if args.capacity_factor and cfg.moe:
            cfg = cfg.replace(moe=dataclasses.replace(
                cfg.moe, capacity_factor=args.capacity_factor))
        if args.sliding_window >= 0:
            cfg = cfg.replace(sliding_window=args.sliding_window)
        return cfg

    t0 = time.time()
    rec = measure(args.arch, args.shape, model_opts=model_opts,
                  cfg_edit=cfg_edit, full_compile=args.full_compile)
    rec.update({"arch": args.arch, "shape": args.shape, "tag": args.tag,
                "model_opts": model_opts, "wall_s": round(time.time() - t0, 1)})
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.arch}__{args.shape}__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[{args.tag}] compute={rec['t_compute_s']:.3f}s "
          f"memory={rec['t_memory_s']:.3f}s "
          f"collective={rec['t_collective_s']:.3f}s "
          f"dominant={rec['dominant']} -> {path}")


if __name__ == "__main__":
    main()
