"""input_specs(): ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation — plus the sharding
trees for params / optimizer state / caches / batches.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config
from ..configs.shapes import SHAPES, InputShape
from ..models import build_model
from ..models.config import ModelConfig
from ..models.frontends import audio_frames_shape, vision_patches_shape
from ..models.sharding import cache_specs, paged_cache_specs, param_specs
from ..optim import adamw_init
from ..training.trainer import TrainState, make_train_step
from .mesh import dp_axes


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))

# dense/VLM archs run long_500k only with this sliding window (DESIGN.md)
LONG_CONTEXT_WINDOW = 8192

# whisper-tiny is a full-attention enc-dec: long_500k is skipped
SKIPS = {("whisper-tiny", "long_500k"): "full-attention enc-dec; 500k decode out of envelope"}


def resolve_config(arch: str, shape_name: str, smoke: bool = False) -> ModelConfig:
    cfg = get_config(arch, smoke=smoke)
    if shape_name == "long_500k" and cfg.family in ("dense", "vlm"):
        cfg = cfg.replace(sliding_window=LONG_CONTEXT_WINDOW)
    if cfg.family == "audio" and SHAPES[shape_name].kind in ("train", "prefill"):
        # decoder learned-pos table must cover the full seq (DESIGN.md)
        cfg = cfg.replace(max_seq=max(cfg.max_seq, SHAPES[shape_name].seq_len))
    return cfg


def batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Training-batch ShapeDtypeStructs (tokens/labels [+frontend embeds])."""
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": ShapeDtypeStruct((B, S), jnp.int32),
             "labels": ShapeDtypeStruct((B, S), jnp.int32)}
    emb = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    if cfg.family == "audio":
        specs["extra_embeds"] = ShapeDtypeStruct(audio_frames_shape(cfg, B), emb)
    elif cfg.vision_seq:
        specs["extra_embeds"] = ShapeDtypeStruct(vision_patches_shape(cfg, B), emb)
    return specs


def batch_shardings(mesh, specs, dp=None) -> Dict[str, Any]:
    dp = dp or dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]

    def sh(leaf):
        spec = (dpa,) + (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(sh, specs)


def state_structs_and_shardings(model, mesh, opt_dtype=jnp.bfloat16, dp=None):
    """eval_shape the TrainState and build its sharding tree."""
    dp = dp or dp_axes(mesh)
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_specs(params_s, dp=dp, axis_sizes=_axis_sizes(mesh))
    state_s = jax.eval_shape(
        lambda p: TrainState(p, adamw_init(p, state_dtype=opt_dtype)), params_s)
    # optimizer m/v mirror param specs; step replicated
    state_specs = TrainState(
        params=pspecs,
        opt=type(state_s.opt)(step=P(), m=pspecs, v=pspecs))
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs)
    return state_s, shardings


def cache_structs_and_shardings(model, mesh, batch: int, capacity: int,
                                cache_dtype=jnp.bfloat16, dp=None):
    dp = dp or dp_axes(mesh)
    cache_s = jax.eval_shape(
        lambda: model.init_cache(batch, capacity, dtype=cache_dtype))
    cspecs = cache_specs(cache_s, dp=dp, shard_seq_when_batch1=(batch == 1),
                         axis_sizes=_axis_sizes(mesh))
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs)
    return cache_s, shardings


def paged_cache_structs_and_shardings(model, mesh, num_blocks: int,
                                      block_size: int,
                                      num_state_slots: int = 0,
                                      cache_dtype=jnp.bfloat16):
    """eval_shape the serving engine's paged pool and build its sharding
    tree (block/slot axes replicated, feature dims on "model" — see
    ``paged_cache_specs``)."""
    cache_s = jax.eval_shape(
        lambda: model.init_paged_cache(num_blocks, block_size,
                                       dtype=cache_dtype,
                                       num_state_slots=num_state_slots))
    cspecs = paged_cache_specs(cache_s, axis_sizes=_axis_sizes(mesh))
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs)
    return cache_s, shardings


def reduced_period_cfg(cfg: ModelConfig, p: int) -> ModelConfig:
    """Same config with the scanned stack cut to ``p`` periods (used to
    extrapolate cost_analysis past XLA's count-while-body-once)."""
    if cfg.family in ("dense", "vlm"):
        return cfg.replace(n_layers=p)
    if cfg.family == "moe":
        return cfg.replace(n_layers=cfg.moe.first_dense_layers + p)
    if cfg.family == "hybrid":
        return cfg.replace(n_layers=cfg.attn_layer_period * p)
    if cfg.family == "ssm":
        every = cfg.ssm.slstm_every or 4
        return cfg.replace(n_layers=every * p)
    if cfg.family == "audio":
        return cfg.replace(n_layers=p, n_enc_layers=p)
    raise ValueError(cfg.family)


def n_periods_of(cfg: ModelConfig) -> int:
    from ..models.transformer import layer_pattern
    if cfg.family == "audio":
        return cfg.n_layers  # enc and dec stacks both scan n_layers
    _, _, n = layer_pattern(cfg)
    return n


def make_step_for_shape(arch: str, shape_name: str, mesh, *, smoke: bool = False,
                        cfg: Optional[ModelConfig] = None, unroll: bool = False,
                        model_opts: Optional[dict] = None):
    """Builds (step_fn, in_specs, in_shardings, out_shardings) for lowering.

    step kinds: train -> train_step(state, batch); prefill ->
    prefill(params, tokens[, embeds]); decode -> decode_step(params,
    cache, token, pos).
    """
    if (arch, shape_name) in SKIPS:
        raise ValueError(f"skip: {SKIPS[(arch, shape_name)]}")
    shape = SHAPES[shape_name]
    if cfg is None:
        cfg = resolve_config(arch, shape_name, smoke=smoke)
    model_opts = dict(model_opts or {})
    # flat_dp: treat the whole mesh as data parallelism (small archs whose
    # head counts don't divide the TP axis — params replicate over "model")
    flat_dp = model_opts.pop("flat_dp", False)
    model = build_model(cfg, remat=(shape.kind == "train"), unroll=unroll,
                        **model_opts)
    dp = tuple(mesh.axis_names) if flat_dp else dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]

    if shape.kind == "train":
        specs = batch_specs(cfg, shape)
        state_s, state_sh = state_structs_and_shardings(model, mesh, dp=dp)
        batch_sh = batch_shardings(mesh, specs, dp=dp)
        step = make_train_step(model)
        metrics_sh = {"loss": NamedSharding(mesh, P()),
                      "lr": NamedSharding(mesh, P()),
                      "grad_norm": NamedSharding(mesh, P())}
        return (step, (state_s, specs), (state_sh, batch_sh),
                (state_sh, metrics_sh), model, cfg)

    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_specs(params_s, dp=dp, axis_sizes=_axis_sizes(mesh))
    params_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), params_s and pspecs)

    B, S = shape.global_batch, shape.seq_len
    emb_dt = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32

    if shape.kind == "prefill":
        tokens = ShapeDtypeStruct((B, S), jnp.int32)
        tokens_sh = NamedSharding(mesh, P(dpa, None))
        extra = None
        extra_sh = None
        if cfg.family == "audio":
            extra = ShapeDtypeStruct(audio_frames_shape(cfg, B), emb_dt)
            extra_sh = NamedSharding(mesh, P(dpa, None, None))
        elif cfg.vision_seq:
            extra = ShapeDtypeStruct(vision_patches_shape(cfg, B), emb_dt)
            extra_sh = NamedSharding(mesh, P(dpa, None, None))
        cache_s, cache_sh = cache_structs_and_shardings(model, mesh, B, S, dp=dp)

        def prefill_step(params, tokens, extra_embeds=None):
            return model.prefill(params, tokens, capacity=S,
                                 extra_embeds=extra_embeds,
                                 cache_dtype=jnp.bfloat16)

        vocab_ok = cfg.vocab_size % _axis_sizes(mesh).get("model", 1) == 0
        logits_sh = NamedSharding(mesh, P(dpa, "model" if vocab_ok else None))
        ins = (params_s, tokens) if extra is None else (params_s, tokens, extra)
        ins_sh = (params_sh, tokens_sh) if extra is None else \
            (params_sh, tokens_sh, extra_sh)
        return (prefill_step, ins, ins_sh, (logits_sh, cache_sh), model, cfg)

    # decode
    capacity = S
    cache_s, cache_sh = cache_structs_and_shardings(model, mesh, B, capacity,
                                                    dp=dp)
    token = ShapeDtypeStruct((B, 1), jnp.int32)
    token_sh = NamedSharding(mesh, P(dpa if B > 1 else None, None))
    pos = ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())
    vocab_ok = cfg.vocab_size % _axis_sizes(mesh).get("model", 1) == 0
    logits_sh = NamedSharding(mesh, P(dpa if B > 1 else None,
                                      "model" if vocab_ok else None))

    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return (decode_step, (params_s, cache_s, token, pos),
            (params_sh, cache_sh, token_sh, pos_sh),
            (logits_sh, cache_sh), model, cfg)
