"""Production meshes.  Defined as FUNCTIONS so importing this module
never touches jax device state (the dry-run sets the host-device-count
flag before any jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16x16 = 256 chips/pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_serving_mesh(model: int = 1):
    """Pure tensor-parallel ``(1, model)`` mesh for the paged serving
    engine, over the first ``model`` devices.

    Serving keeps the data axis at size 1 on purpose: the engine's slot
    batch is tiny and host-scheduled, so sharding it would only force
    uneven batch splits through the model's internal batch constraints,
    while the weight/KV tensor axes are where the memory and FLOPs
    actually live.  ``model`` must not exceed the device count."""
    import numpy as np
    devs = jax.devices()
    if model < 1 or model > len(devs):
        raise ValueError(
            f"make_serving_mesh(model={model}): have {len(devs)} device(s)")
    return jax.sharding.Mesh(
        np.asarray(devs[:model]).reshape(1, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The FSDP/batch axes of a mesh (everything except "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")
