"""Pallas kernel: fused MoE top-k gating.

One VMEM pass per token block: iteratively extract the k maxima
(k <= 8 everywhere in the assigned pool) instead of sorting E scores.
E is small (16-256) so a block of scores (block_t, E) sits in VMEM and
the k passes are VPU-only — no HBM re-reads per pass, which is the point
of fusing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _gating_kernel(s_ref, vals_ref, idx_ref, *, k):
    s = s_ref[...].astype(jnp.float32)            # (bt, E)
    bt, E = s.shape
    eidx = jax.lax.broadcasted_iota(jnp.int32, (bt, E), 1)
    for j in range(k):                            # k static, small
        m = jnp.max(s, axis=1)                    # (bt,)
        # first argmax position
        is_max = (s == m[:, None])
        first = jnp.min(jnp.where(is_max, eidx, E), axis=1)
        vals_ref[:, j] = m
        idx_ref[:, j] = first
        s = jnp.where(eidx == first[:, None], NEG, s)


@functools.partial(jax.jit, static_argnames=("k", "block_t", "interpret"))
def gating_topk(scores, k: int, *, block_t: int = 512, interpret: bool = True):
    """scores: (T, E), T multiple of block_t -> (vals (T,k), idx (T,k))."""
    T, E = scores.shape
    bt = min(block_t, T)
    grid = (T // bt,)
    return pl.pallas_call(
        functools.partial(_gating_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((bt, E), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((bt, k), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((T, k), jnp.float32),
                   jax.ShapeDtypeStruct((T, k), jnp.int32)),
        interpret=interpret,
    )(scores)
