"""Public op: shape-agnostic fused top-k gating."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .. import default_interpret
from .kernel import gating_topk


def topk(scores, k: int, *, interpret: Optional[bool] = None):
    """scores: (..., E) -> (vals (...,k), idx (...,k))."""
    shape = scores.shape
    E = shape[-1]
    flat = scores.reshape(-1, E)
    T = flat.shape[0]
    bt = 512
    pad = (-T) % bt if T > bt else 0
    if T < bt:
        bt = max(8, 1 << (T - 1).bit_length()) if T > 8 else 8
        pad = (-T) % bt
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)), constant_values=-1e30)
    vals, idx = gating_topk(flat, k, block_t=bt,
                            interpret=default_interpret(interpret))
    vals, idx = vals[:T], idx[:T]
    return (vals.reshape(shape[:-1] + (k,)).astype(scores.dtype),
            idx.reshape(shape[:-1] + (k,)))
