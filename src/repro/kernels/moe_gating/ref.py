"""Oracle: top-k over the expert axis (values + indices, sorted desc)."""
from __future__ import annotations

import jax


def topk_ref(scores, k: int):
    """scores: (T, E) -> (vals (T,k), idx (T,k))."""
    return jax.lax.top_k(scores, k)
