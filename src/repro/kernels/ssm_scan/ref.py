"""Oracle: Mamba selective scan, sequential jnp reference."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(dt, Bc, Cc, xs, A, D, h0=None):
    """dt, xs: (B,S,di); Bc, Cc: (B,S,N); A: (di,N); D: (di,).

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = C_t h_t + D x_t.
    Returns (y (B,S,di) in xs.dtype, h_last (B,di,N) f32).
    """
    B, S, di = xs.shape
    N = Bc.shape[-1]
    dt32 = dt.astype(jnp.float32)
    decay = jnp.exp(dt32[..., None] * A[None, None])
    drive = (dt32 * xs.astype(jnp.float32))[..., None] * \
        Bc.astype(jnp.float32)[..., None, :]
    h = jnp.zeros((B, di, N), jnp.float32) if h0 is None else h0
    ys = []
    for t in range(S):
        h = decay[:, t] * h + drive[:, t]
        ys.append(jnp.einsum("bdn,bn->bd", h, Cc[:, t].astype(jnp.float32)))
    y = jnp.stack(ys, axis=1) + D[None, None] * xs.astype(jnp.float32)
    return y.astype(xs.dtype), h
