"""Public op: padding + dtype handling for the selective-scan kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .. import default_interpret
from .kernel import selective_scan_kernel


def selective_scan(dt, Bc, Cc, xs, A, D, h0=None, *, block_d: int = 128,
                   chunk_t: int = 256, interpret: Optional[bool] = None):
    """Same contract as models.mamba.selective_scan (h0 must be None —
    prefill starts cold; decode uses the single-step jnp path)."""
    assert h0 is None, "kernel path supports cold start only"
    B, S, di = xs.shape
    bd = min(block_d, di)
    ct = min(chunk_t, S)
    pad_d = (-di) % bd
    pad_t = (-S) % ct
    if pad_d:
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad_d)))
        xs = jnp.pad(xs, ((0, 0), (0, 0), (0, pad_d)))
        A = jnp.pad(A, ((0, pad_d), (0, 0)))
        D = jnp.pad(D, (0, pad_d))
    if pad_t:
        dt = jnp.pad(dt, ((0, 0), (0, pad_t), (0, 0)))
        xs = jnp.pad(xs, ((0, 0), (0, pad_t), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad_t), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad_t), (0, 0)))
    y, h_last = selective_scan_kernel(dt, xs, Bc, Cc, A, D, block_d=bd,
                                      chunk_t=ct,
                                      interpret=default_interpret(interpret))
    y = y[:, :S, :di]
    h_last = h_last[:, :di]
    return y, h_last
