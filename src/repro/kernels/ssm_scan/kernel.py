"""Pallas TPU selective scan (Mamba S6).

TPU adaptation of the CUDA selective-scan: grid (B, n_d, n_t) with the
time dim innermost-sequential; the recurrent state h (block_d, N) lives
in VMEM scratch across time chunks, dt/x/B/C stream in per-chunk.  The
within-chunk loop is a `fori_loop` over rows — sublane-indexed VMEM
reads, VPU elementwise updates, one (block_d, N) state per core.  This
replaces warp-level shuffles with VMEM-resident state, trading GPU
shared-memory tricks for TPU's large vector memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_out_ref,
                h_ref, *, chunk_t, n_t):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)                     # (bd, N)
    Dp = d_ref[...].astype(jnp.float32)                    # (1, bd)

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)         # (bd,)
        x_t = x_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)           # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)
        decay = jnp.exp(dt_t[:, None] * A)                 # (bd, N)
        drive = (dt_t * x_t)[:, None] * b_t[None, :]
        h = decay * h + drive
        y = jnp.sum(h * c_t[None, :], axis=1) + Dp[0] * x_t
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk_t, step, h_ref[...])
    h_ref[...] = h

    @pl.when(it == n_t - 1)
    def _emit_state():
        h_out_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d", "chunk_t", "interpret"))
def selective_scan_kernel(dt, xs, Bc, Cc, A, D, *, block_d: int = 128,
                          chunk_t: int = 256, interpret: bool = True):
    """dt, xs: (B,S,di); Bc, Cc: (B,S,N); A: (di,N); D: (di,).

    S % chunk_t == 0 and di % block_d == 0 (ops.py pads).
    Returns (y (B,S,di), h_last (B,di,N) f32).
    """
    B, S, di = xs.shape
    N = Bc.shape[-1]
    bd = min(block_d, di)
    ct = min(chunk_t, S)
    n_d, n_t = di // bd, S // ct
    grid = (B, n_d, n_t)
    D2 = D.reshape(1, di)
    y, h_last = pl.pallas_call(
        functools.partial(_ssm_kernel, chunk_t=ct, n_t=n_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ct, bd), lambda b, id_, it: (b, it, id_)),  # dt
            pl.BlockSpec((1, ct, bd), lambda b, id_, it: (b, it, id_)),  # x
            pl.BlockSpec((1, ct, N), lambda b, id_, it: (b, it, 0)),     # B
            pl.BlockSpec((1, ct, N), lambda b, id_, it: (b, it, 0)),     # C
            pl.BlockSpec((bd, N), lambda b, id_, it: (id_, 0)),          # A
            pl.BlockSpec((1, bd), lambda b, id_, it: (0, id_)),          # D
        ],
        out_specs=(
            pl.BlockSpec((1, ct, bd), lambda b, id_, it: (b, it, id_)),  # y
            pl.BlockSpec((1, bd, N), lambda b, id_, it: (b, id_, 0)),    # h_last
        ),
        out_shape=(jax.ShapeDtypeStruct((B, S, di), xs.dtype),
                   jax.ShapeDtypeStruct((B, di, N), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(dt, xs, Bc, Cc, A, D2)
    return y, h_last
