"""Pallas kernel: fused typecast+scale+bias+clamp (Tensor-Transform).

The NNStreamer tensor_transform chain (e.g. "typecast:float32,
divide:255,subtract:0.5") is one elementwise affine op after folding;
on TPU we fuse it into a single HBM->VMEM->HBM pass with (8,128)-aligned
tiles instead of one pass per chain op (paper E4's pre-processing
overhead, adapted to the TPU memory hierarchy).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8


def _transform_kernel(x_ref, o_ref, *, scale, bias, lo, hi):
    x = x_ref[...].astype(jnp.float32)
    y = x * scale + bias
    y = jnp.clip(y, lo, hi)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bias", "lo", "hi",
                                             "out_dtype", "block_rows",
                                             "interpret"))
def fused_transform_2d(x, *, scale: float, bias: float, lo: float, hi: float,
                       out_dtype=None, block_rows: int = 256,
                       interpret: bool = True):
    """x: (R, C) with C a multiple of 128; R a multiple of 8."""
    R, C = x.shape
    out_dtype = out_dtype or x.dtype
    br = min(block_rows, R)
    grid = (R // br,)
    return pl.pallas_call(
        functools.partial(_transform_kernel, scale=scale, bias=bias,
                          lo=lo, hi=hi),
        grid=grid,
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), out_dtype),
        interpret=interpret,
    )(x)
