"""Pure-jnp oracle for the fused Tensor-Transform affine chain."""
from __future__ import annotations

import jax.numpy as jnp


def fused_transform_ref(x, scale: float, bias: float, lo: float, hi: float,
                        out_dtype=None):
    """y = cast(clamp(x*scale + bias, lo, hi))  — one logical pass."""
    y = x.astype(jnp.float32) * scale + bias
    y = jnp.clip(y, lo, hi)
    return y.astype(out_dtype or x.dtype)
