"""Public op: shape-agnostic fused transform (pads/tiles to kernel layout)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import default_interpret
from .kernel import LANE, SUBLANE, fused_transform_2d
from .ref import fused_transform_ref
import functools


@functools.partial(jax.jit, static_argnames=("scale", "bias", "lo", "hi",
                                             "out_dtype"))
def fused_transform_xla(x, *, scale=1.0, bias=0.0, lo=-np.inf, hi=np.inf,
                        out_dtype=None):
    """Single-pass fused affine+clamp+cast compiled by XLA — the CPU
    wall-clock proxy for the Pallas kernel (which targets TPU and is
    validated in interpret mode)."""
    y = x.astype(jnp.float32) * scale + bias
    y = jnp.clip(y, lo, hi)
    return y.astype(out_dtype or x.dtype)


def fused_transform(x, *, scale: float = 1.0, bias: float = 0.0,
                    lo: float = -np.inf, hi: float = np.inf,
                    out_dtype=None, interpret: Optional[bool] = None):
    """Arbitrary-shape fused affine+clamp+cast via the Pallas kernel."""
    x = jnp.asarray(x)
    out_dtype = jnp.dtype(out_dtype) if out_dtype else x.dtype
    n = x.size
    if n == 0:
        return x.astype(out_dtype)
    cols = LANE
    rows = -(-n // cols)
    block_rows = 256
    # pad rows to a multiple of the grid block (grid must tile exactly)
    quantum = block_rows if rows > block_rows else SUBLANE
    rows_pad = -(-rows // quantum) * quantum
    flat = jnp.ravel(x)
    flat = jnp.pad(flat, (0, rows_pad * cols - n))
    y = fused_transform_2d(flat.reshape(rows_pad, cols), scale=scale,
                           bias=bias, lo=float(lo), hi=float(hi),
                           out_dtype=out_dtype, block_rows=block_rows,
                           interpret=default_interpret(interpret))
    return jnp.ravel(y)[:n].reshape(x.shape)
