"""Oracle: causal (optionally sliding-window) GQA attention, pure jnp."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, sliding_window: int = 0):
    """q: (B,H,S,hd); k,v: (B,KV,T,hd) -> (B,H,S,hd)."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, S, hd)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, k).astype(jnp.float32) / np.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if sliding_window:
        mask &= kpos > qpos - sliding_window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, v)
    return o.reshape(B, H, S, hd)
