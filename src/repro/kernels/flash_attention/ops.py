"""Public op: layout adaptation (B,S,H,hd) <-> kernel layout, padding."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .. import default_interpret
from .kernel import flash_attention


def flash_attention_bshd(q, k, v, *, causal: bool = True,
                         sliding_window: int = 0, block_q: int = 128,
                         block_k: int = 128,
                         interpret: Optional[bool] = None):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd) — model-native layout."""
    S = q.shape[1]
    T = k.shape[1]
    bq = min(block_q, S)
    bk = min(block_k, T)
    pad_q = (-S) % bq
    pad_k = (-T) % bk
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded keys sit at positions >= T; the causal mask (kpos<=qpos
        # with qpos<S<=kpos) would keep them for the padded q rows only,
        # which are discarded — but for safety give them NEG via window
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    o = flash_attention(qt, kt, vt, causal=causal,
                        sliding_window=sliding_window,
                        block_q=bq, block_k=bk,
                        interpret=default_interpret(interpret))
    return jnp.moveaxis(o[:, :, :S], 1, 2)
