"""Pallas TPU flash attention (prefill): blockwise online softmax.

Grid (B, H, nq, nk), innermost kv dim sequential on TPU; running
(m, l, acc) live in VMEM scratch across kv steps.  Q/K/V tiles are
(block_q x hd) / (block_k x hd) — hd is 64..192 in the assigned pool, so
tiles are MXU-aligned on the lane dim and the two matmuls per step hit
the MXU.  GQA maps query head -> kv head in the BlockSpec index_map (no
materialized K/V repeat).  Causal + sliding-window masks are applied from
global block offsets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, block_q, block_k, n_k, causal, window):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[:, 0]                                  # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
    m_ref[:, 0] = m_new
    v = v_ref[0, 0].astype(jnp.float32)                   # (bk, hd)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[:, 0], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "sliding_window",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, sliding_window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B,H,S,hd); k,v: (B,KV,T,hd).  S % block_q == T % block_k == 0."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    bq, bk = min(block_q, S), min(block_k, T)
    nq, nk = S // bq, T // bk
    scale = 1.0 / np.sqrt(hd)
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_q=bq, block_k=bk,
                          n_k=nk, causal=causal, window=sliding_window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
