# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
from __future__ import annotations

from typing import Optional


def default_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve a Pallas ``interpret=`` argument.

    Every ``kernels/*/ops.py`` entry point takes ``interpret=None`` and
    runs it through here: ``None`` autodetects the backend (CPU hosts
    get interpret mode — compiled Pallas silently miscompiles or
    crashes there), an explicit bool is passed through untouched.
    Resolving at call time (not import time) respects late backend
    selection (``jax.config``/``JAX_PLATFORMS`` set after import).
    """
    if interpret is not None:
        return bool(interpret)
    import jax
    return jax.default_backend() == "cpu"
