"""Pallas TPU decode attention: flash-decoding style split-K.

One new token attends to a long KV cache (the decode_32k / long_500k hot
path).  Grid (B, H, n_kblocks): KV blocks stream HBM->VMEM while running
(m, l, acc) stay in VMEM scratch; the valid-length mask comes from a
scalar operand.  q is tiny ((1, hd) per head) so arithmetic intensity is
memory-bound by design — the kernel's job is to keep the KV stream at
HBM bandwidth, which on TPU means (block_k x hd) tiles with hd on lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale, block_k, n_k):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bk)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < len_ref[0], s, NEG)

    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
    m_ref[0, 0] = m_new
    v = v_ref[0, 0].astype(jnp.float32)                    # (bk, hd)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (1, hd)
    acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[0, 0], 1e-30)).astype(o_ref.dtype)


def _paged_decode_kernel(len_ref, pt_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale, block_size,
                         n_pages):
    """Same online-softmax body as ``_decode_kernel``, but the KV block
    streamed at grid step (b, h, ip) is *indirected*: the BlockSpec
    index map reads ``pt_ref[b, ip]`` (scalar-prefetched page table) to
    pick the physical block, so the kernel walks each sequence's pages
    in logical order while the pool stays scattered in HBM.  Per-row
    lengths replace the shared scalar length."""
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bs, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bs)
    kpos = ip * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < len_ref[b], s, NEG)

    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
    m_ref[0, 0] = m_new
    v = v_ref[0, 0].astype(jnp.float32)                    # (bs, hd)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (1, hd)
    acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(ip == n_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[0, 0], 1e-30)).astype(o_ref.dtype)


def _paged_decode_quant_kernel(len_ref, pt_ref, q_ref, k_ref, v_ref,
                               ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref,
                               *, scale, block_size, n_pages):
    """``_paged_decode_kernel`` with int8 K/V pools dequantized in the
    inner loop: the streamed (bs, hd) int8 tile is widened to f32 and
    multiplied by its per-row scale vector (bs,) right before the score
    dot — HBM traffic is the int8 pool plus a bs-float sliver of scales
    per page, ~1/4 of the f32 stream for the same cache content."""
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]  # (bs, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bs)
    kpos = ip * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < len_ref[b], s, NEG)

    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
    m_ref[0, 0] = m_new
    v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]  # (bs, hd)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (1, hd)
    acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(ip == n_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[0, 0], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_quant(q, k_pages, v_pages, k_scale, v_scale,
                                 page_table, lengths, *,
                                 interpret: bool = True):
    """Int8 variant of ``paged_decode_attention``.

    q: (B,H,hd) float; pools: (num_blocks,KV,bs,hd) int8;
    k_scale/v_scale: (num_blocks,KV,bs) float32 per-row scales;
    page_table: (B,P) int32; lengths: (B,) int32 -> (B,H,hd).

    Same split-K page walk; the scale pools stream through their own
    scalar-prefetch-indirected BlockSpecs so each (bs, hd) int8 tile
    arrives with its (bs,) scale vector and is dequantized in VMEM.
    """
    B, H, hd = q.shape
    KV, bs = k_pages.shape[1], k_pages.shape[2]
    P = page_table.shape[1]
    G = H // KV
    scale = 1.0 / np.sqrt(hd)
    lengths = jnp.asarray(lengths, jnp.int32).reshape(B)
    page_table = jnp.asarray(page_table, jnp.int32)
    out = pl.pallas_call(
        functools.partial(_paged_decode_quant_kernel, scale=scale,
                          block_size=bs, n_pages=P),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, H, P),
            in_specs=[
                pl.BlockSpec((1, 1, 1, hd),
                             lambda b, h, ip, ln, pt: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bs, hd),
                             lambda b, h, ip, ln, pt: (pt[b, ip], h // G, 0, 0)),
                pl.BlockSpec((1, 1, bs, hd),
                             lambda b, h, ip, ln, pt: (pt[b, ip], h // G, 0, 0)),
                pl.BlockSpec((1, 1, bs),
                             lambda b, h, ip, ln, pt: (pt[b, ip], h // G, 0)),
                pl.BlockSpec((1, 1, bs),
                             lambda b, h, ip, ln, pt: (pt[b, ip], h // G, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, 1, hd),
                                   lambda b, h, ip, ln, pt: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        interpret=interpret,
    )(lengths, page_table, q[:, :, None, :], k_pages, v_pages,
      k_scale, v_scale)
    return out[:, :, 0, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           interpret: bool = True):
    """q: (B,H,hd); pools: (num_blocks,KV,bs,hd); page_table: (B,P)
    int32; lengths: (B,) int32 -> (B,H,hd).

    Flash-decoding split-K over *pages*: grid (B, H, P), one KV block
    per page.  Unallocated page-table entries may point anywhere valid —
    their positions exceed ``lengths`` so the mask zeroes them.
    """
    B, H, hd = q.shape
    KV, bs = k_pages.shape[1], k_pages.shape[2]
    P = page_table.shape[1]
    G = H // KV
    scale = 1.0 / np.sqrt(hd)
    lengths = jnp.asarray(lengths, jnp.int32).reshape(B)
    page_table = jnp.asarray(page_table, jnp.int32)
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=scale, block_size=bs,
                          n_pages=P),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, H, P),
            in_specs=[
                pl.BlockSpec((1, 1, 1, hd),
                             lambda b, h, ip, ln, pt: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bs, hd),
                             lambda b, h, ip, ln, pt: (pt[b, ip], h // G, 0, 0)),
                pl.BlockSpec((1, 1, bs, hd),
                             lambda b, h, ip, ln, pt: (pt[b, ip], h // G, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, 1, hd),
                                   lambda b, h, ip, ln, pt: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        interpret=interpret,
    )(lengths, page_table, q[:, :, None, :], k_pages, v_pages)
    return out[:, :, 0, :]


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, length, *, block_k: int = 512,
                     interpret: bool = True):
    """q: (B,H,hd); caches: (B,KV,C,hd); length: () int32 -> (B,H,hd)."""
    B, H, hd = q.shape
    KV, C = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    bk = min(block_k, C)
    n_k = C // bk
    scale = 1.0 / np.sqrt(hd)
    grid = (B, H, n_k)
    length = jnp.asarray(length, jnp.int32).reshape(1)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=bk, n_k=n_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, 1, hd), lambda b, h, ik, ln: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bk, hd), lambda b, h, ik, ln: (b, h // G, ik, 0)),
                pl.BlockSpec((1, 1, bk, hd), lambda b, h, ik, ln: (b, h // G, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, ik, ln: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        interpret=interpret,
    )(length, q[:, :, None, :], k_cache, v_cache)
    return out[:, :, 0, :]
