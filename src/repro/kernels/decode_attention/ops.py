"""Public op: decode attention in model-native layout with padding."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import decode_attention


def decode_attention_bhd(q, k_cache, v_cache, length, *, block_k: int = 512,
                         interpret: bool = True):
    """q: (B,1,H,hd); caches: (B,C,KV,hd) -> (B,1,H,hd)."""
    B, _, H, hd = q.shape
    C = k_cache.shape[1]
    bk = min(block_k, C)
    pad = (-C) % bk
    kt = jnp.moveaxis(k_cache, 2, 1)
    vt = jnp.moveaxis(v_cache, 2, 1)
    if pad:  # padded slots are masked by the length check (length <= C)
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    o = decode_attention(q[:, 0], kt, vt, length, block_k=bk,
                         interpret=interpret)
    return o[:, None]
