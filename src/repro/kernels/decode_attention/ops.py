"""Public op: decode attention in model-native layout with padding."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .. import default_interpret
from .kernel import (decode_attention, paged_decode_attention,
                     paged_decode_attention_quant)


def decode_attention_bhd(q, k_cache, v_cache, length, *, block_k: int = 512,
                         interpret: Optional[bool] = None):
    """q: (B,1,H,hd); caches: (B,C,KV,hd) -> (B,1,H,hd)."""
    B, _, H, hd = q.shape
    C = k_cache.shape[1]
    bk = min(block_k, C)
    pad = (-C) % bk
    kt = jnp.moveaxis(k_cache, 2, 1)
    vt = jnp.moveaxis(v_cache, 2, 1)
    if pad:  # padded slots are masked by the length check (length <= C)
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    o = decode_attention(q[:, 0], kt, vt, length, block_k=bk,
                         interpret=default_interpret(interpret))
    return o[:, None]


def paged_decode_attention_bhd(q, k_pages, v_pages, page_table, lengths, *,
                               interpret: Optional[bool] = None):
    """Paged decode attention in the serving engine's layout.

    q: (B,1,H,hd); k_pages/v_pages: (num_blocks, block_size, KV, hd) —
    the ``ServeEngine`` paged-cache leaf layout; page_table: (B,P);
    lengths: (B,).  Returns (B,1,H,hd).
    """
    kt = jnp.moveaxis(k_pages, 2, 1)   # -> (nb, KV, bs, hd)
    vt = jnp.moveaxis(v_pages, 2, 1)
    o = paged_decode_attention(q[:, 0], kt, vt, page_table, lengths,
                               interpret=default_interpret(interpret))
    return o[:, None]


def paged_decode_attention_quant_bhd(q, k_pages, v_pages, k_scale, v_scale,
                                     page_table, lengths, *,
                                     interpret: Optional[bool] = None):
    """Int8 paged decode attention in the serving engine's layout.

    q: (B,1,H,hd) float; k_pages/v_pages: (num_blocks, block_size, KV,
    hd) int8 — the ``kv_dtype="int8"`` paged-cache leaf layout;
    k_scale/v_scale: (num_blocks, block_size, KV) float32 per-row
    scales; page_table: (B,P); lengths: (B,).  Returns (B,1,H,hd).
    """
    kt = jnp.moveaxis(k_pages, 2, 1)    # -> (nb, KV, bs, hd)
    vt = jnp.moveaxis(v_pages, 2, 1)
    kst = jnp.moveaxis(k_scale, 2, 1)   # -> (nb, KV, bs)
    vst = jnp.moveaxis(v_scale, 2, 1)
    o = paged_decode_attention_quant(q[:, 0], kt, vt, kst, vst,
                                     page_table, lengths,
                                     interpret=default_interpret(interpret))
    return o[:, None]
