"""Oracle: single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k_cache, v_cache, length):
    """q: (B,H,hd); caches: (B,KV,C,hd); length: scalar valid prefix,
    or (B,) per-sequence valid prefixes.

    Returns (B,H,hd).
    """
    B, H, hd = q.shape
    KV, C = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k_cache).astype(jnp.float32)
    s = s / np.sqrt(hd)
    length = jnp.asarray(length)
    if length.ndim == 1:  # (B,) true per-sequence lengths (paged decode)
        valid = jnp.arange(C)[None, None, None, :] < length[:, None, None, None]
    else:
        valid = jnp.arange(C)[None, None, None, :] < length
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgt,bktd->bkgd", p, v_cache)
    return o.reshape(B, H, hd)


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, lengths):
    """Oracle: decode attention through a block page table.

    q: (B,H,hd); k_pages/v_pages: (num_blocks, KV, bs, hd) shared pools;
    page_table: (B,P) int32 — physical block of each logical page;
    lengths: (B,) valid tokens per sequence.  Gathers each sequence's
    logical view (B, KV, P*bs, hd) then reduces exactly like the dense
    oracle, so dense and paged layouts are interchangeable under
    identical content.  Returns (B,H,hd).
    """
    B, P = page_table.shape
    KV, bs, hd = k_pages.shape[1:]
    kg = jnp.moveaxis(k_pages[page_table], 2, 1)   # (B,KV,P,bs,hd)
    vg = jnp.moveaxis(v_pages[page_table], 2, 1)
    kg = kg.reshape(B, KV, P * bs, hd)
    vg = vg.reshape(B, KV, P * bs, hd)
    return decode_attention_ref(q, kg, vg, lengths)


def paged_decode_attention_quant_ref(q, k_pages, v_pages, k_scale, v_scale,
                                     page_table, lengths):
    """Oracle for the int8 paged kernel: dequantize the whole pool
    (``int8 * scale[..., None]`` with per-(block, head, row) f32 scales
    of shape (num_blocks, KV, bs)) and delegate to the f32 paged oracle
    — the kernel's in-loop dequant must match this exactly."""
    kf = k_pages.astype(jnp.float32) * k_scale[..., None]
    vf = v_pages.astype(jnp.float32) * v_scale[..., None]
    return paged_decode_attention_ref(q, kf, vf, page_table, lengths)
