"""Oracle: single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k_cache, v_cache, length):
    """q: (B,H,hd); caches: (B,KV,C,hd); length: scalar valid prefix.

    Returns (B,H,hd).
    """
    B, H, hd = q.shape
    KV, C = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k_cache).astype(jnp.float32)
    s = s / np.sqrt(hd)
    valid = jnp.arange(C)[None, None, None, :] < length
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgt,bktd->bkgd", p, v_cache)
    return o.reshape(B, H, hd)
