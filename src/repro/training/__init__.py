from .trainer import Trainer, TrainState, make_train_step

__all__ = ["Trainer", "TrainState", "make_train_step"]
