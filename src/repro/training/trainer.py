"""Training loop: loss -> grads -> AdamW, with optional pjit sharding.

``make_train_step`` builds the jit-able pure function used both by the
Trainer (real CPU runs) and by the multi-pod dry-run (lower/compile
only).  NNTrainer analogue: on-device training as a first-class citizen
of the same framework (paper §Broader Impact).
"""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..optim import AdamWState, adamw_init, adamw_update, cosine_schedule


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_train_step(model, *, peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, weight_decay: float = 0.1):
    """(state, batch) -> (state, metrics).  Pure; jit/pjit outside."""

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        # step+1: the first optimizer step takes a non-zero warmup LR
        lr = cosine_schedule(state.opt.step + 1, peak_lr=peak_lr,
                             warmup=warmup, total=total_steps)
        params, opt = adamw_update(state.params, grads, state.opt, lr,
                                   weight_decay=weight_decay)
        metrics = {"loss": loss, "lr": lr,
                   "grad_norm": jnp.sqrt(sum(
                       jnp.sum(jnp.square(g.astype(jnp.float32)))
                       for g in jax.tree.leaves(grads)))}
        return TrainState(params, opt), metrics

    return train_step


class Trainer:
    """Single-process trainer for the runnable examples."""

    def __init__(self, model, *, seed: int = 0, opt_state_dtype=None, **opt_kw):
        self.model = model
        self.params = model.init(jax.random.PRNGKey(seed))
        self.opt = adamw_init(self.params, state_dtype=opt_state_dtype)
        self.state = TrainState(self.params, self.opt)
        self._step_fn = jax.jit(make_train_step(model, **opt_kw))
        self.history = []

    def fit(self, batches, steps: int, log_every: int = 10,
            log_fn: Optional[Callable[[str], None]] = print):
        it = iter(batches)
        for i in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            t0 = time.perf_counter()
            self.state, metrics = self._step_fn(self.state, batch)
            metrics = {k: float(v) for k, v in
                       jax.tree.map(lambda x: x, metrics).items()}
            metrics["step_time_s"] = time.perf_counter() - t0
            self.history.append(metrics)
            if log_fn and (i % log_every == 0 or i == steps - 1):
                log_fn(f"step {i:5d} loss={metrics['loss']:.4f} "
                       f"lr={metrics['lr']:.2e} "
                       f"gnorm={metrics['grad_norm']:.3f} "
                       f"dt={metrics['step_time_s']*1e3:.1f}ms")
        return self.history
