"""Model registry — maps model names to invokable callables.

The analogue of pointing a Tensor-Filter at a ``.tflite`` path: models
register under a name and TensorFilter / SingleShot resolve them.
Built-ins: "identity" plus lazy loaders for the 10 assigned architecture
configs (reduced "smoke" variants, so a textual pipeline can reference
``model=smollm-360m:smoke`` without multi-GiB allocation).
"""
from __future__ import annotations

import threading
from typing import Callable, Dict

_MODELS: Dict[str, Callable] = {}
_LOCK = threading.Lock()


def register_model(name: str, fn: Callable) -> None:
    with _LOCK:
        _MODELS[name] = fn


def get_model(name: str) -> Callable:
    with _LOCK:
        if name in _MODELS:
            return _MODELS[name]
    fn = _try_lazy_load(name)
    if fn is None:
        raise ValueError(f"unknown model {name!r}; registered: {sorted(_MODELS)}")
    register_model(name, fn)
    return fn


def _try_lazy_load(name: str) -> Callable | None:
    """Resolve "<arch>:smoke" to a jitted forward fn of the reduced config."""
    if not name.endswith(":smoke"):
        return None
    arch = name[: -len(":smoke")]
    from .configs import get_config
    try:
        cfg = get_config(arch, smoke=True)
    except KeyError:
        return None
    import jax
    from .models import build_model

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def forward(tokens, *extra):
        return model.apply(params, tokens, *extra)

    return jax.jit(forward)


def _register_builtins() -> None:
    register_model("identity", lambda *xs: xs if len(xs) > 1 else xs[0])


_register_builtins()
