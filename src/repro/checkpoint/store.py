"""Checkpointing: pytree <-> sharded .npz files.

Layout: <dir>/step_<n>/part_<i>.npz plus a manifest of the tree
structure.  Leaves are gathered to host; save is chunked so a single
file stays under ``max_bytes_per_part`` (mirrors real multi-host
checkpoint sharding at laptop scale).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, np.ndarray]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    named = [(f"leaf_{i}", np.asarray(x)) for i, x in enumerate(leaves)]
    return named, treedef


def save_checkpoint(directory: str, step: int, tree,
                    max_bytes_per_part: int = 512 * 1024 * 1024) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    named, treedef = _flatten(tree)
    parts: List[List[Tuple[str, np.ndarray]]] = [[]]
    size = 0
    for name, arr in named:
        if size + arr.nbytes > max_bytes_per_part and parts[-1]:
            parts.append([])
            size = 0
        parts[-1].append((name, arr))
        size += arr.nbytes
    index = {}
    for i, group in enumerate(parts):
        np.savez(os.path.join(path, f"part_{i}.npz"), **dict(group))
        for name, _ in group:
            index[name] = i
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"n_leaves": len(named), "index": index,
                   "treedef": str(treedef)}, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like):
    """Restore into the structure of ``like`` (validates leaf count/shape)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    files = {}
    arrays = {}
    for name, part in manifest["index"].items():
        if part not in files:
            files[part] = np.load(os.path.join(path, f"part_{part}.npz"))
        arrays[name] = files[part][name]
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(leaves) != manifest["n_leaves"]:
        raise ValueError(f"checkpoint has {manifest['n_leaves']} leaves, "
                         f"target tree has {len(leaves)}")
    out = []
    for i, ref in enumerate(leaves):
        arr = arrays[f"leaf_{i}"]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf_{i} shape {arr.shape} != {ref.shape}")
        out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
