"""SingleShot — the paper's pipeline-less "Single API" (Tizen C/.NET, Android).

Run one model with a unified interface, no pipeline required::

    single = SingleShot(model="identity")
    out = single.invoke(np.ones((4,)))

Mirrors TensorFilter backend resolution, including jax / jax-sharded.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

from .core.elements.filter import TensorFilter


class SingleShot:
    def __init__(self, model: Optional[str] = None, fn=None,
                 framework: str = "python", device=None, mesh=None,
                 in_shardings=None, out_shardings=None):
        self._filter = TensorFilter(
            "single", fn=fn, model=model, framework=framework, device=device,
            mesh=mesh, in_shardings=in_shardings, out_shardings=out_shardings)

    def invoke(self, *inputs: Any) -> Any:
        out = self._filter.invoke(inputs)
        return out[0] if len(out) == 1 else out

    @property
    def mean_latency_s(self) -> float:
        return self._filter.mean_latency_s

    @property
    def n_invocations(self) -> int:
        return self._filter.n_invocations
