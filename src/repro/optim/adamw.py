"""AdamW in pure JAX.  Optimizer-state dtype is configurable so giant
models can keep m/v in bf16 (halves optimizer HBM; see EXPERIMENTS.md
§Dry-run memory discussion)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params, state_dtype=None) -> AdamWState:
    def zeros_like(p):
        dt = state_dtype or p.dtype
        return jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros_like, params),
                      v=jax.tree.map(zeros_like, params))


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, grad_clip: float = 1.0):
    """Returns (new_params, new_state).  Global-norm clip + decoupled WD."""
    step = state.step + 1
    if grad_clip:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
        update = update + weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
