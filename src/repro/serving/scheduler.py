"""Priority-aware request scheduler for the serving engine.

``ServeEngine``'s original queue was a single FIFO deque with
head-of-line admission: a queued request whose worst-case block
reservation did not fit blocked every smaller request behind it, and
all requests were equal — a latency-sensitive probe waited behind a
bulk batch job.  This module replaces it with a small two-lane
scheduler:

  * **lanes** — ``interactive`` and ``batch``.  Candidates are offered
    to the engine interactive-first, FIFO within a lane, so a short
    interactive request admits ahead of any amount of queued batch
    work.
  * **size-aware admission** — the scheduler yields *all* queued
    requests in priority order; the engine admits any candidate whose
    block + state-slab reservation fits and simply skips past the ones
    that do not, so a too-large request can never starve a smaller one
    behind it (the head-of-line fix).
  * **deadlines** — a request may carry an absolute TTFT deadline
    (monotonic seconds).  ``expire`` pops queued requests whose
    deadline has passed before they started; the engine fails them
    with status ``"expired"`` instead of burning pool space on output
    nobody is waiting for.
  * **preemption support** — a preempted request re-enters *the front*
    of its lane (``push(front=True)``) carrying its generated tokens,
    page digests, and the host-side spill of its KV pages / state slab
    so the engine can re-admit it bit-identically.

The scheduler is plain host-side bookkeeping: no thread owns it, the
engine guards it with its submission lock.
"""
from __future__ import annotations

import dataclasses
import collections
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

LANES = ("interactive", "batch")

__all__ = ["LANES", "SchedRequest", "Scheduler"]


@dataclasses.dataclass(eq=False)     # identity semantics: queue membership
class SchedRequest:
    """One queued generation request (or a preempted one re-queued).

    ``deadline`` is absolute ``time.monotonic()`` seconds (None = no
    deadline) and bounds *time to first token*: a request that has not
    been admitted by its deadline is expired, one that has started is
    allowed to finish.  The restore fields are empty for fresh
    requests; a preempted request carries everything needed to rebuild
    its slot exactly: the tokens generated so far, the number of cache
    positions it had filled, its per-page chain digests, and the spill
    payload (host copy of its KV pages + recurrent state slab).
    """
    rid: int
    prompt: np.ndarray
    lane: str = "interactive"
    deadline: Optional[float] = None
    tag: Any = None
    t_submit: float = 0.0
    # -- preemption restore state --
    tokens: List[int] = dataclasses.field(default_factory=list)
    length: int = 0                  # cache positions filled at spill time
    digests: List[bytes] = dataclasses.field(default_factory=list)
    spill: Any = None                # host pytree of page/slab data
    # speculative-decode restore state: {"rounds", "deficit", "prev"}
    # (None when the engine is not speculative or the request is fresh)
    spec: Any = None
    # -- memoized prefix match (valid while allocator.epoch unchanged) --
    match: Optional[Tuple[List[int], List[bytes], int]] = None
    match_epoch: int = -1

    @property
    def preempted(self) -> bool:
        return self.spill is not None or self.length > 0


class Scheduler:
    """Two-lane priority queue over ``SchedRequest``s."""

    def __init__(self, lanes: Tuple[str, ...] = LANES):
        if not lanes:
            raise ValueError("need at least one lane")
        self.lanes = tuple(lanes)
        self._queues: Dict[str, collections.deque] = {
            lane: collections.deque() for lane in self.lanes}

    # -- occupancy ----------------------------------------------------------
    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    @property
    def pending(self) -> bool:
        return any(self._queues.values())

    def n_queued(self, lane: Optional[str] = None) -> int:
        if lane is not None:
            return len(self._queues[lane])
        return len(self)

    def stats(self) -> Dict[str, int]:
        return {f"queued_{lane}": len(q) for lane, q in self._queues.items()}

    # -- queue ops ----------------------------------------------------------
    def push(self, req: SchedRequest, *, front: bool = False) -> None:
        """Enqueue ``req`` on its lane; ``front=True`` re-queues a
        preempted request ahead of its lane's FIFO order."""
        if req.lane not in self._queues:
            raise ValueError(
                f"unknown lane {req.lane!r}; have {self.lanes}")
        q = self._queues[req.lane]
        q.appendleft(req) if front else q.append(req)

    def candidates(self) -> Iterator[SchedRequest]:
        """All queued requests in admission-priority order: lanes in
        declared order (interactive first), FIFO within a lane.  The
        engine admits what fits and leaves the rest queued — iteration
        is over a snapshot, so ``remove`` during the scan is safe."""
        for lane in self.lanes:
            yield from list(self._queues[lane])

    def remove(self, req: SchedRequest) -> bool:
        """Dequeue ``req`` (admitted or cancelled); False if absent."""
        try:
            self._queues[req.lane].remove(req)
            return True
        except ValueError:
            return False

    def pop_rid(self, rid: int) -> Optional[SchedRequest]:
        """Dequeue the request with id ``rid`` (None if not queued)."""
        for q in self._queues.values():
            for req in q:
                if req.rid == rid:
                    q.remove(req)
                    return req
        return None

    def expire(self, now: float) -> List[SchedRequest]:
        """Pop every queued request whose deadline has passed.  Only
        *unstarted* requests expire — a preempted request already holds
        generated tokens its client has streamed, so it is exempt."""
        out: List[SchedRequest] = []
        for q in self._queues.values():
            kept, dead = [], []
            for req in q:
                is_dead = (req.deadline is not None and now > req.deadline
                           and not req.preempted)
                (dead if is_dead else kept).append(req)
            if dead:
                out.extend(dead)
                q.clear()
                q.extend(kept)
        return out
