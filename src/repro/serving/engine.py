"""Continuous-batching serving engine built on the stream framework.

Requests enter a thread-safe queue (``submit``) and are scheduled into a
fixed array of ``batch_size`` *slots*.  Unlike the fixed-group batcher
this replaces, the decode loop never waits for a full group:

  * finished sequences (hit ``eos_id`` or ``max_new_tokens``) are
    *evicted*, freeing their slot immediately;
  * queued requests *join mid-decode*: the newcomer's prompt is
    left-padded to the batch's current position, prefilled, and its
    slice of the KV cache is spliced into the live cache, so decoding
    of in-flight sequences is never interrupted.

All slots share one scalar decode position (sequences are left-aligned
by padding, like the fixed-group engine before it), so a prompt longer
than the current position waits until the position catches up — or
until the batch drains, at which point the engine re-anchors with a
fresh prefill.

The cache splice is model-agnostic: the batch axis of every cache leaf
is discovered once via ``jax.eval_shape`` (comparing cache shapes for
batch B vs B+1), so any model exposing ``prefill``/``decode_step``
works — transformer, MLA, hybrid — without per-model axis annotations.

The engine is also usable as a pipeline TensorFilter
(``as_pipeline_filter``): batched prompt tensors stream in, generated
token tensors stream out, in request order.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class GenerationResult:
    request_id: int
    prompt: np.ndarray
    tokens: np.ndarray
    latency_s: float


@dataclasses.dataclass
class _Request:
    rid: int
    prompt: np.ndarray
    t_submit: float


class _Slot:
    __slots__ = ("rid", "prompt", "tokens", "t_submit", "done")

    def __init__(self, req: _Request, first_token: int, eos_id: Optional[int],
                 max_new: int):
        self.rid = req.rid
        self.prompt = req.prompt
        self.tokens: List[int] = [int(first_token)]
        self.t_submit = req.t_submit
        self.done = (eos_id is not None and int(first_token) == eos_id) \
            or max_new <= 1


class ServeEngine:
    def __init__(self, model, params, *, batch_size: int = 4,
                 capacity: int = 256, max_new_tokens: int = 16,
                 cache_dtype=jnp.float32, greedy: bool = True,
                 eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.capacity = capacity
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self._prefill = jax.jit(make_prefill_step(model, capacity, cache_dtype),
                                static_argnames=())
        self._decode = jax.jit(make_decode_step(model, greedy=greedy))
        # request queue + in-flight slot map
        self._pending: collections.deque = collections.deque()
        self._slots: List[Optional[_Slot]] = [None] * batch_size
        self._cache = None
        self._token = None            # (B, 1) int32 — last token per slot
        self._pos = 0                 # shared aligned decode position
        self._batch_axes = None       # cache pytree of batch-axis indices
        self._lock = threading.Lock()
        self._next_rid = 0
        # scheduler counters
        self.n_batches = 0            # prefill launches (back-compat alias)
        self.n_requests = 0
        self.n_prefills = 0
        self.n_joins = 0              # requests admitted mid-decode
        self.n_evictions = 0          # slots freed by eos/max_new

    # -- synchronous fixed batch API (kept for benchmarks/back-compat) ------
    def generate_batch(self, prompts: np.ndarray,
                       extra_embeds=None) -> np.ndarray:
        """prompts: (B, S) int32 -> generated (B, max_new_tokens)."""
        B, S = prompts.shape
        assert B == self.batch_size, (B, self.batch_size)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      extra_embeds)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out = [np.asarray(token)]
        pos = S
        for _ in range(self.max_new_tokens - 1):
            token, _, cache = self._decode(self.params, cache, token,
                                           jnp.int32(pos))
            out.append(np.asarray(token))
            pos += 1
        self.n_batches += 1
        self.n_requests += B
        self.last_batch_latency_s = time.perf_counter() - t0
        return np.concatenate(out, axis=1)

    # -- continuous batching ------------------------------------------------
    def submit(self, prompt: np.ndarray) -> int:
        """Enqueue a request; returns its request id (thread-safe)."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.shape[0] == 0:
            raise ValueError(f"prompt must be non-empty 1-D, got {prompt.shape}")
        if prompt.shape[0] > self.capacity:
            raise ValueError(
                f"prompt length {prompt.shape[0]} exceeds KV-cache capacity "
                f"{self.capacity}; raise capacity= or truncate the prompt")
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self._pending.append(_Request(rid, prompt, time.monotonic()))
            self.n_requests += 1
        return rid

    @property
    def n_active(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    @property
    def has_work(self) -> bool:
        with self._lock:
            return bool(self._pending) or self.n_active > 0

    def step(self) -> List[GenerationResult]:
        """Admit what fits, run one decode step, evict what finished.

        Returns results for requests that completed during this step.
        """
        self._admit()
        finished = self._evict()
        if self.n_active == 0:
            return finished
        if self._pos >= self.capacity:
            # cache exhausted: truncate everything still in flight
            for slot in self._slots:
                if slot is not None:
                    slot.done = True
            return finished + self._evict()
        token, _, cache = self._decode(self.params, self._cache, self._token,
                                       jnp.int32(self._pos))
        self._token, self._cache = token, cache
        self._pos += 1
        tok = np.asarray(token[:, 0])
        for i, slot in enumerate(self._slots):
            if slot is None or slot.done:
                continue
            slot.tokens.append(int(tok[i]))
            if ((self.eos_id is not None and slot.tokens[-1] == self.eos_id)
                    or len(slot.tokens) >= self.max_new_tokens):
                slot.done = True
        return finished + self._evict()

    def serve(self, requests: List[np.ndarray],
              timeout_s: float = 120.0) -> List[GenerationResult]:
        """Serve via continuous batching; results in request order."""
        rids = [self.submit(r) for r in requests]
        deadline = time.monotonic() + timeout_s
        done: Dict[int, GenerationResult] = {}
        while self.has_work:
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"serve: {len(done)}/{self.n_requests} finished before "
                    f"timeout ({self.n_active} in flight)")
            for res in self.step():
                done[res.request_id] = res
        return [done[rid] for rid in rids if rid in done]

    def as_pipeline_filter(self):
        """Adapter: (n, S) prompt batch -> (n, max_new_tokens) generations.

        Row order in == row order out, so TensorUnbatcher downstream can
        restore per-request pts/meta.  Rows shorter than max_new (early
        eos) are right-padded with eos_id (or 0).
        """
        pad = self.eos_id if self.eos_id is not None else 0

        def fn(prompts):
            prompts = np.asarray(prompts, np.int32)
            results = self.serve([row for row in prompts])
            out = np.full((len(results), self.max_new_tokens), pad, np.int32)
            for i, r in enumerate(results):
                out[i, : len(r.tokens)] = r.tokens
            return out
        return fn

    # -- scheduler internals ------------------------------------------------
    def _admit(self) -> None:
        free = [i for i, s in enumerate(self._slots) if s is None]
        if not free:
            return
        with self._lock:
            if not self._pending:
                return
            if self.n_active == 0:
                # batch drained: re-anchor with a fresh prefill wave
                self._cache = None
                take = [self._pending.popleft()
                        for _ in range(min(len(free), len(self._pending)))]
                joins = list(zip(free, take))
                fresh = True
            elif self._pos >= self.capacity:
                # cache exhausted: in-flight slots are about to be
                # truncated; hold newcomers for the fresh re-anchor
                return
            else:
                # mid-decode join: only prompts that fit the current position
                joins, keep = [], collections.deque()
                for req in self._pending:
                    if len(joins) < len(free) and req.prompt.shape[0] <= self._pos:
                        joins.append((free[len(joins)], req))
                    else:
                        keep.append(req)
                self._pending = keep
                fresh = False
        if not joins:
            return
        B = self.batch_size
        if fresh:
            maxlen = max(req.prompt.shape[0] for _, req in joins)
            self._pos = maxlen
        batch = np.zeros((B, self._pos), np.int32)
        for slot_i, req in joins:
            batch[slot_i, self._pos - req.prompt.shape[0]:] = req.prompt
        logits, cache = self._prefill(self.params, jnp.asarray(batch), None)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        self.n_prefills += 1
        self.n_batches += 1
        if fresh:
            self._cache, self._token = cache, first
        else:
            slot_ids = [slot_i for slot_i, _ in joins]
            self._cache = self._splice_cache(self._cache, cache, slot_ids)
            self._token = self._token.at[jnp.asarray(slot_ids), 0].set(
                first[jnp.asarray(slot_ids), 0])
            self.n_joins += len(joins)
        first_np = np.asarray(first[:, 0])
        for slot_i, req in joins:
            self._slots[slot_i] = _Slot(req, first_np[slot_i], self.eos_id,
                                        self.max_new_tokens)

    def _evict(self) -> List[GenerationResult]:
        out: List[GenerationResult] = []
        now = time.monotonic()
        for i, slot in enumerate(self._slots):
            if slot is None or not slot.done:
                continue
            out.append(GenerationResult(
                request_id=slot.rid, prompt=slot.prompt,
                tokens=np.asarray(slot.tokens, np.int32),
                latency_s=now - slot.t_submit))
            self._slots[i] = None
            self.n_evictions += 1
        return out

    # -- cache splicing -----------------------------------------------------
    def _discover_batch_axes(self, seq_len: int):
        """Which axis of each cache leaf is the batch axis?  Compare
        cache shapes for batch B vs B+1 (eval_shape: no compilation)."""
        def shapes(batch):
            tokens = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
            return jax.eval_shape(self._prefill, self.params, tokens, None)[1]

        def axis(a, b):
            for i, (p, q) in enumerate(zip(a.shape, b.shape)):
                if p != q:
                    return i
            return -1  # leaf independent of batch
        return jax.tree.map(axis, shapes(self.batch_size),
                            shapes(self.batch_size + 1))

    def _splice_cache(self, live, fresh, slot_ids: List[int]):
        if self._batch_axes is None:
            self._batch_axes = self._discover_batch_axes(max(self._pos, 1))
        sel = jnp.asarray(slot_ids, jnp.int32)

        def merge(old, new, ax):
            if ax < 0:
                return old
            idx = [slice(None)] * old.ndim
            idx[ax] = sel
            return old.at[tuple(idx)].set(new[tuple(idx)])
        return jax.tree.map(merge, live, fresh, self._batch_axes)
