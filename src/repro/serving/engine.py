"""Batched serving engine — the paper's "serve a model with batched
requests" scenario, built on the stream framework.

Requests arrive on a queue; the engine groups them into fixed-size
batches (padding with idle slots), runs prefill once per batch, then a
decode loop.  The engine is itself usable as a pipeline TensorFilter
(requests stream in, generations stream out).
"""
from __future__ import annotations

import dataclasses
import queue as _queue
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class GenerationResult:
    request_id: int
    prompt: np.ndarray
    tokens: np.ndarray
    latency_s: float


class ServeEngine:
    def __init__(self, model, params, *, batch_size: int = 4,
                 capacity: int = 256, max_new_tokens: int = 16,
                 cache_dtype=jnp.float32, greedy: bool = True,
                 eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.capacity = capacity
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self._prefill = jax.jit(make_prefill_step(model, capacity, cache_dtype),
                                static_argnames=())
        self._decode = jax.jit(make_decode_step(model, greedy=greedy))
        self.n_batches = 0
        self.n_requests = 0

    # -- synchronous batch API ---------------------------------------------------
    def generate_batch(self, prompts: np.ndarray,
                       extra_embeds=None) -> np.ndarray:
        """prompts: (B, S) int32 -> generated (B, max_new_tokens)."""
        B, S = prompts.shape
        assert B == self.batch_size, (B, self.batch_size)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      extra_embeds)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out = [np.asarray(token)]
        pos = S
        for _ in range(self.max_new_tokens - 1):
            token, _, cache = self._decode(self.params, cache, token,
                                           jnp.int32(pos))
            out.append(np.asarray(token))
            pos += 1
        self.n_batches += 1
        self.n_requests += B
        self.last_batch_latency_s = time.perf_counter() - t0
        return np.concatenate(out, axis=1)

    # -- queued request API --------------------------------------------------------
    def serve(self, requests: List[np.ndarray],
              timeout_s: float = 120.0) -> List[GenerationResult]:
        """Pad/group variable requests into batches and run them all."""
        results: List[GenerationResult] = []
        maxlen = max(r.shape[0] for r in requests)
        for i in range(0, len(requests), self.batch_size):
            group = requests[i: i + self.batch_size]
            while len(group) < self.batch_size:
                group.append(np.zeros((maxlen,), np.int32))  # idle slot
            batch = np.stack([np.pad(r, (maxlen - r.shape[0], 0)) for r in group])
            t0 = time.perf_counter()
            gen = self.generate_batch(batch.astype(np.int32))
            dt = time.perf_counter() - t0
            for j, r in enumerate(requests[i: i + self.batch_size]):
                results.append(GenerationResult(
                    request_id=i + j, prompt=r, tokens=gen[j], latency_s=dt))
        return results
