"""Continuous-batching serving engine built on the stream framework.

Requests enter a thread-safe queue (``submit``) and are scheduled into a
fixed array of ``batch_size`` *slots*.  Unlike the fixed-group batcher
this replaces, the decode loop never waits for a full group:

  * finished sequences (hit ``eos_id`` or ``max_new_tokens``) are
    *evicted*, freeing their slot immediately;
  * queued requests *join mid-decode*: the newcomer's prompt is
    left-padded to the batch's current position, prefilled, and its
    slice of the KV cache is spliced into the live cache, so decoding
    of in-flight sequences is never interrupted.

Two cache regimes share this scheduler:

**Dense (legacy / any model)** — all slots share one scalar decode
position (sequences are left-aligned by padding), so a prompt longer
than the current position waits until the position catches up — or
until the batch drains, at which point the engine re-anchors with a
fresh prefill.  The join splice is model-agnostic: the batch axis of
every cache leaf is discovered once via ``jax.eval_shape`` (comparing
cache shapes for batch B vs B+1), so any model exposing
``prefill``/``decode_step`` works — transformer, MLA, hybrid — without
per-model axis annotations.

**Paged (models with ``init_paged_cache``/``paged_step``)** — the KV
cache is one shared pool of fixed-size blocks (``kv_cache.py``); each
slot owns a page table and a true position counter, and attention masks
by per-slot length instead of shared left padding.  Joins no longer pay
a full-position prefill: a newcomer's prompt is consumed in bounded
``prefill_chunk``-token steps *in the same batched calls* that keep
decoding the in-flight slots, so join cost is independent of how long
the batch has been running.  Blocks are reserved worst-case at
admission (prompt + max_new), extended lazily block-by-block as decode
crosses boundaries, and released in full on eviction; a request whose
reservation does not fit stays queued — never a mid-decode allocation
failure.

**Recurrent / hybrid families (mamba, xLSTM, jamba-style stacks)** run
through the same paged scheduler: their attention layers page as above
while each recurrent layer keeps per-sequence state in fixed-size
slabs handed out by a ``StateStore`` (``kv_cache.py``).  Admission is
all-or-nothing across *both* pools — a request needs its worst-case
block reservation AND one free state slab, else it stays queued — and
eviction frees both.  A recycled slab still holds the evictee's state;
the model's paged step zeroes any row whose sequence starts this call
(``lengths == 0``), so state can never leak across requests.  These
families decode *correctly* only here: the dense engine's left-pad
join approximation would run pad tokens through the recurrence and
corrupt the state summary.

**Prefix sharing + copy-on-write (paged only)** — the block pool is
content-addressed: whenever a slot completes a page, the engine
registers the block under the chain digest of the token prefix it
caches.  At admission, a joiner's prompt is matched page-by-page
against resident blocks; matched pages are *mapped* into the new
slot's page table with a refcount bump instead of being re-prefilled
(a final partial page can map onto another sequence's completed tail
block — rows past the joiner's length are masked).  Shared blocks are
immutable: before ``paged_scatter`` would write into a block whose
refcount exceeds one, the engine forks it — acquires a private block,
copies the page's KV, and swaps the page-table entry — so in-flight
slots can never observe each other's writes.  The last matched prompt
token is always re-run through the model (``matched <= len(prompt)-1``)
so the joiner's first sampled token has logits to come from.

**Sampling** — both modes draw next tokens through one shared sampler.
``temperature`` selects the mode: 0 (the default) is exact greedy
argmax, > 0 samples from ``softmax(logits / temperature)`` under
``top_k`` (an explicit ``greedy=True`` forces argmax regardless).
Slot ``b``'s key for its ``t``-th generated token is
``fold_in(fold_in(PRNGKey(seed), request_id), t)`` — a pure function of
the request and step, independent of serving mode, batch composition,
or join timing — so paged and dense serving emit identical token
streams for the same seed.

**Device-resident decode loop** — the hot path never round-trips per
token.  All per-step slot state (page tables, lengths, last tokens,
per-slot ``(rid, step)`` sampling counters, done flags) lives in
persistent device arrays (``DeviceSlotState``) that are mutated in-jit
by one fused **megastep** — model step + sampler + token/length/eos
update, donated buffers — and only rebuilt from the host after a
*structural* event (admission, eviction, block extension, COW fork).
When no admissions, prefill chunks, or forks are pending, the engine
runs **decode bursts**: up to ``burst`` megasteps per host round-trip
in one ``lax.while_loop`` with an all-done early-out, draining sampled
tokens from a device-side ring buffer once per burst — host syncs per
decoded token drop from ~4 to ``1/K``.  Whenever the request queue is
non-empty the engine degrades to ``K = 1`` so join latency is
unchanged; the burst bound is a *traced* scalar, so every K runs the
same compiled loop body and burst output is bit-identical to
single-stepping by construction.
"""
from __future__ import annotations

import contextlib
import dataclasses
import inspect
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kv_cache import (ROOT_DIGEST, BlockAllocator, CacheFullError,
                       DeviceSlotState, StateStore, chain_digest)
from .scheduler import SchedRequest, Scheduler
from .steps import (make_decode_step, make_dense_burst, make_paged_burst,
                    make_paged_mixed_step, make_paged_spec_burst,
                    make_paged_spec_mixed_step, make_prefill_step,
                    make_sampler_core)


@dataclasses.dataclass
class GenerationResult:
    request_id: int
    prompt: np.ndarray
    tokens: np.ndarray
    latency_s: float
    # "ok" | "timeout" | "expired" | "cancelled" | "overrun" | "error" —
    # non-ok results carry whatever tokens were generated before the
    # request was failed
    status: str = "ok"
    ttft_s: Optional[float] = None    # submit -> first generated token
    error: Optional[str] = None       # failure message (status "error")


class _Slot:
    __slots__ = ("rid", "prompt", "tokens", "t_submit", "done", "lane",
                 "deadline", "tag", "status", "t_first", "adm_seq")

    def __init__(self, req: SchedRequest, first_token: int,
                 eos_id: Optional[int], max_new: int):
        self.rid = req.rid
        self.prompt = req.prompt
        self.tokens: List[int] = [int(first_token)]
        self.t_submit = req.t_submit
        self.done = (eos_id is not None and int(first_token) == eos_id) \
            or max_new <= 1
        self.lane = req.lane
        self.deadline = req.deadline
        self.tag = req.tag
        self.status = "ok"
        self.t_first: Optional[float] = None
        self.adm_seq = 0


class _PagedSlot:
    """Per-slot decode state in paged mode: true position counter lives
    in the engine's ``_lengths`` array; this tracks ownership."""
    __slots__ = ("rid", "prompt", "tokens", "t_submit", "done", "blocks",
                 "reserve_left", "prefill_off", "digests", "lane",
                 "deadline", "tag", "status", "t_first", "adm_seq",
                 "spec_rounds", "spec_deficit", "spec_prev")

    def __init__(self, req: SchedRequest, blocks: List[int],
                 reserve_left: int, prefill_off: int = 0,
                 digests: Optional[List[bytes]] = None):
        self.rid = req.rid
        self.prompt = req.prompt
        self.tokens: List[int] = []
        self.t_submit = req.t_submit
        self.done = False
        self.blocks = blocks          # physical block ids, page order
        self.reserve_left = reserve_left  # blocks still claimable lazily
        self.prefill_off = prefill_off    # prompt tokens already cached
        self.digests = digests if digests is not None else []  # per full page
        self.lane = req.lane
        self.deadline = req.deadline
        self.tag = req.tag
        self.status = "ok"
        self.t_first: Optional[float] = None
        self.adm_seq = 0
        # host mirrors of the speculative slot-state keys (spec engines
        # only): rounds run (PRNG stream position), draft-cache deficit
        # (0/1 positions the draft KV trails the target), and the token
        # at cache position lengths-1 (the deficit catch-up input)
        self.spec_rounds = 0
        self.spec_deficit = 0
        self.spec_prev = 0


class ServeEngine:
    def __init__(self, model, params, *, batch_size: int = 4,
                 capacity: int = 256, max_new_tokens: int = 16,
                 cache_dtype=jnp.float32, greedy: Optional[bool] = None,
                 temperature: float = 0.0, top_k: Optional[int] = None,
                 seed: int = 0, eos_id: Optional[int] = None,
                 paged: Optional[bool] = None, block_size: int = 16,
                 num_blocks: Optional[int] = None, prefill_chunk: int = 32,
                 share_prefix: Optional[bool] = None,
                 num_state_slots: Optional[int] = None,
                 burst: int = 1, trace_logits: bool = False,
                 mesh=None, retain_cap: Optional[int] = None,
                 retain_ttl_s: Optional[float] = None,
                 draft_model=None, draft_params=None, spec_k: int = 0,
                 kv_dtype: Optional[str] = None,
                 fault_plan=None, max_restarts: int = 3):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.capacity = capacity
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self.cache_dtype = cache_dtype
        # kv_dtype: storage precision of the serving KV pool.  "f32" /
        # "bf16" simply pin cache_dtype; "int8" switches the paged pool
        # to block-quantized int8 storage with per-row f32 scale leaves
        # (models/attention.gqa_paged_step_quant) — a capacity lever,
        # not a numerics-preserving one, so quantized mode is covered by
        # the drift-tolerance suite instead of bitwise conformance.
        if kv_dtype not in (None, "f32", "bf16", "int8"):
            raise ValueError(
                f"kv_dtype must be 'f32', 'bf16' or 'int8', got {kv_dtype!r}")
        if kv_dtype == "f32":
            self.cache_dtype = cache_dtype = jnp.float32
        elif kv_dtype == "bf16":
            self.cache_dtype = cache_dtype = jnp.bfloat16
        self.kv_dtype = kv_dtype
        self._quant = kv_dtype == "int8"
        if temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {temperature}")
        if top_k is not None and top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        # temperature drives the mode: 0 (the default) is exactly the
        # greedy path, > 0 samples; an explicit greedy=True still wins
        self._greedy = (temperature == 0) if greedy is None \
            else bool(greedy) or temperature == 0
        self.temperature = temperature
        self.top_k = top_k
        self.seed = seed
        # paged mode: auto-on when the model implements the protocol
        has_paged = (hasattr(model, "init_paged_cache")
                     and hasattr(model, "paged_step")
                     and (not hasattr(model, "supports_paged")
                          or model.supports_paged()))
        if paged and not has_paged:
            raise ValueError(
                f"paged=True but {type(model).__name__} does not implement "
                "init_paged_cache/paged_step (or supports_paged() is False)")
        self.paged = has_paged if paged is None else bool(paged)
        # tensor-parallel serving over a device mesh: weights are placed
        # by the repo's PartitionSpec rules (heads/FFN/vocab on "model",
        # FSDP over the remaining axes), the paged pool gets
        # head-sharded leaves (see paged_cache_specs), and all host-
        # mirrored slot state is replicated.  The jitted megasteps run
        # unchanged — committed input shardings propagate through them,
        # and every serving entry point enters `with mesh:` so the
        # model's internal with_sharding_constraints activate.
        self.mesh = mesh
        self._replicated = None
        if mesh is not None:
            if not self.paged:
                raise ValueError(
                    "mesh= requires paged mode: tensor-parallel serving "
                    "shards the paged block pool (the dense per-slot cache "
                    "has no sharded layout)")
            from jax.sharding import NamedSharding, PartitionSpec
            from ..models.sharding import param_specs
            axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            dp = tuple(a for a in mesh.axis_names if a != "model") \
                or ("data",)
            pspecs = param_specs(params, dp=dp, axis_sizes=axis_sizes)
            self.params = jax.device_put(
                params,
                jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs))
            self._replicated = NamedSharding(mesh, PartitionSpec())
        self._prefill = jax.jit(make_prefill_step(model, capacity, cache_dtype),
                                static_argnames=())
        self._decode = jax.jit(make_decode_step(model, greedy=True))
        # both modes draw tokens through one sampler core, so a given
        # (seed, request, step) yields the same token either way; the
        # core is inlined into the fused megasteps, and also jitted
        # standalone for the dense admission path
        sampler = make_sampler_core(seed, greedy=self._greedy,
                                    temperature=temperature or 1.0,
                                    top_k=top_k)
        self._sample = jax.jit(sampler)
        # decode bursts: up to `burst` fused megasteps per host
        # round-trip.  `max_burst` (= the init value) sizes the ring
        # buffers and is static; `self.burst` may be lowered at runtime
        # and is traced, so every K <= max_burst runs one compilation.
        if burst < 1:
            raise ValueError(f"burst must be >= 1, got {burst}")
        self.max_burst = int(burst)
        self.burst = int(burst)
        # request queue (two priority lanes) + in-flight slot map
        self.scheduler = Scheduler()
        self._slots: List[Optional[_Slot]] = [None] * batch_size
        self._cache = None
        self._pos = 0                 # shared aligned decode position
        self._batch_axes = None       # cache pytree of batch-axis indices
        self._lock = threading.Lock()
        self._next_rid = 0
        # completed results, keyed by rid until a wait() collects them;
        # the condition variable wakes concurrent waiters, and the step
        # lock elects exactly one thread at a time to drive step()
        self._results: Dict[int, GenerationResult] = {}
        self._results_cv = threading.Condition()
        self._step_lock = threading.Lock()
        self._adm_seq = 0             # admission order (preemption picks
        #                               the youngest batch-lane slot)
        # optional token-streaming hook: stream_cb(rid, new_tokens) fires
        # whenever generated tokens for a request reach the host (once
        # per slot per burst drain) — the network front door uses it to
        # stream tokens back per-request before the batch completes
        self.stream_cb = None
        # paged-mode state: block pool + per-slot page tables / lengths
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        if share_prefix and not self.paged:
            raise ValueError(
                "share_prefix=True requires paged mode (the dense cache has "
                "no block pool to share)")
        # recurrent state slabs disable prefix sharing: a slab summarizes
        # the whole prefix, so resident KV pages alone cannot seed a joiner
        sharable = not self.paged or bool(
            getattr(model, "supports_prefix_sharing", lambda: True)())
        if share_prefix and not sharable:
            raise ValueError(
                f"share_prefix=True but {type(model).__name__} "
                f"(family={getattr(getattr(model, 'cfg', None), 'family', '?')!r}) "
                "has recurrent layers whose state cannot be shared across "
                "requests: a mamba/xLSTM state slab summarizes its entire "
                "prefix, so mapping resident KV pages cannot reconstruct "
                "it.  Run with share_prefix=False (or leave it on auto).")
        self.share_prefix = (self.paged and sharable) if share_prefix is None \
            else bool(share_prefix)
        # speculative (draft-verify) decoding: a small draft model runs
        # spec_k tokens ahead inside each decode burst round, the target
        # verifies every drafted position in ONE T = spec_k+1 paged
        # step, and accept/reject follows the rejection-sampling rule
        # (see steps.make_paged_spec_burst) — the output distribution is
        # provably the target's, and greedy output is token-identical to
        # non-speculative decode by construction.
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        self.spec_k = int(spec_k)
        self.draft_model = draft_model
        self.draft_params = draft_params
        self._spec = self.spec_k > 0
        if self._spec:
            if draft_model is None or draft_params is None:
                raise ValueError(
                    "spec_k > 0 requires draft_model= and draft_params= "
                    "(a small model sharing the target's vocabulary)")
            if not self.paged:
                raise ValueError(
                    "spec_k > 0 requires paged mode: speculative rollback "
                    "is arithmetic on per-slot lengths, which only the "
                    "block-paged cache tracks")
            if mesh is not None:
                raise NotImplementedError(
                    "speculative decoding under mesh= is not implemented "
                    "yet: the draft pool needs its own sharding specs and "
                    "the accept rule a replicated gather per drafted "
                    "position")
            if prefill_chunk < 2:
                raise ValueError(
                    "spec_k > 0 requires prefill_chunk >= 2: the draft's "
                    "deficit catch-up feeds two tokens through the mixed "
                    f"megastep, got prefill_chunk={prefill_chunk}")
            for role, m in (("target", model), ("draft", draft_model)):
                sup = getattr(m, "supports_speculative", None)
                ok = sup() if sup is not None else not bool(
                    getattr(m, "has_recurrent_state", lambda: False)())
                if not ok:
                    raise ValueError(
                        f"spec_k > 0 but the {role} model "
                        f"{type(m).__name__} (family="
                        f"{getattr(getattr(m, 'cfg', None), 'family', '?')!r}) "
                        "has recurrent layers: rejected tokens roll back by "
                        "arithmetic on per-slot lengths, and a recurrent "
                        "state slab advanced through rejected tokens cannot "
                        "be rolled back.  Serve this family with spec_k=0.")
            tcfg = getattr(model, "cfg", None)
            dcfg = getattr(draft_model, "cfg", None)
            if tcfg is not None and dcfg is not None \
                    and tcfg.vocab_size != dcfg.vocab_size:
                raise ValueError(
                    f"draft/target vocab mismatch: target {tcfg.vocab_size} "
                    f"vs draft {dcfg.vocab_size} — speculative decoding "
                    "requires a shared tokenizer/vocabulary")
            if share_prefix:
                raise ValueError(
                    "share_prefix=True is incompatible with spec_k > 0: the "
                    "draft KV rides the same page tables as the target, but "
                    "COW forks and content registration only cover the "
                    "target pool.  Leave share_prefix on auto (speculative "
                    "mode disables it) or set it False.")
            self.share_prefix = False
        if self._quant:
            if not self.paged:
                raise ValueError(
                    "kv_dtype='int8' requires paged mode: quantized KV "
                    "lives in the shared block pool (the dense per-slot "
                    "cache stays full precision)")
            if self._spec:
                raise ValueError(
                    "kv_dtype='int8' is incompatible with spec_k > 0: the "
                    "draft pool and the greedy verify-identity guarantee "
                    "are not quantization-aware.  Serve quantized without "
                    "speculation (spec_k=0).")
            if mesh is not None:
                raise NotImplementedError(
                    "kv_dtype='int8' under mesh= is not implemented yet: "
                    "the f32 scale pools need audited sharding specs "
                    "before the quantized pool can be distributed")
            sig = inspect.signature(model.init_paged_cache)
            if "kv_dtype" not in sig.parameters:
                raise ValueError(
                    f"kv_dtype='int8' but {type(model).__name__}."
                    "init_paged_cache does not accept kv_dtype= (the model "
                    "does not implement quantized pools)")
        self._pages_per_slot = -(-capacity // block_size)
        if num_blocks is None:
            num_blocks = batch_size * self._pages_per_slot
        self.allocator = BlockAllocator(num_blocks, block_size,
                                        retain_cap=retain_cap,
                                        retain_ttl_s=retain_ttl_s) \
            if self.paged else None
        # recurrent families: per-slot state slabs beside the block pool
        needs_state = self.paged and bool(
            getattr(model, "has_recurrent_state", lambda: False)())
        self.num_state_slots = (batch_size if num_state_slots is None
                                else num_state_slots) if needs_state else 0
        self.state_store = StateStore(self.num_state_slots) \
            if needs_state else None
        self._page_table = np.zeros((batch_size, self._pages_per_slot),
                                    np.int32)
        self._lengths = np.zeros((batch_size,), np.int32)
        self._state_slots = np.zeros((batch_size,), np.int32)
        self._reserved = 0            # lazily-claimable blocks promised out
        copy_fn = getattr(model, "copy_paged_block", _generic_copy_paged_block)
        self._copy_block = jax.jit(copy_fn, donate_argnums=(0,)) \
            if self.paged else None
        # preemption spill/restore: gather pages+slab to host / scatter
        # them back at new physical homes.  Models without the protocol
        # fall back to the generic block-axis convention (attn-only);
        # recurrent stacks without it cannot be preempted.
        self._gather_pages = None
        self._scatter_pages = None
        if self.paged:
            gather = getattr(model, "gather_paged_pages", None)
            scatter = getattr(model, "scatter_paged_pages", None)
            if gather is not None and scatter is not None:
                self._gather_pages = jax.jit(gather)
                self._scatter_pages = jax.jit(scatter, donate_argnums=(0,))
            elif not needs_state:
                self._gather_pages = jax.jit(_generic_gather_pages)
                self._scatter_pages = jax.jit(_generic_scatter_pages,
                                              donate_argnums=(0,))
        # the draft pool spills/restores beside the target pool with its
        # own (draft-shaped) gather/scatter
        self._gather_draft = self._scatter_draft = None
        if self._spec:
            g = getattr(draft_model, "gather_paged_pages", None)
            s = getattr(draft_model, "scatter_paged_pages", None)
            self._gather_draft = jax.jit(g) if g is not None \
                else jax.jit(_generic_gather_pages)
            self._scatter_draft = jax.jit(s, donate_argnums=(0,)) \
                if s is not None \
                else jax.jit(_generic_scatter_pages, donate_argnums=(0,))
        self._paged_cache = None
        self._draft_cache = None
        self._kv_bytes_per_block_cache = None
        # optional per-request logit recording (conformance tests)
        self.trace_logits = trace_logits
        self.logit_trace: Dict[int, List[np.ndarray]] = {}
        # fused megasteps: model step + sampler + slot-state update in
        # one jit, cache AND slot state donated — the pool is rewritten
        # every tick, and without donation XLA copies all
        # num_blocks*block_size K/V per token
        if self.paged and self._spec:
            self._mixed_fn = jax.jit(
                make_paged_spec_mixed_step(model, draft_model, sampler,
                                           eos_id=eos_id,
                                           max_new=max_new_tokens,
                                           capacity=capacity),
                donate_argnums=(2, 3, 4))
            self._burst_fn = jax.jit(
                make_paged_spec_burst(model, draft_model, eos_id=eos_id,
                                      max_new=max_new_tokens,
                                      capacity=capacity,
                                      spec_k=self.spec_k,
                                      k_static=self.max_burst, seed=seed,
                                      greedy=self._greedy,
                                      temperature=temperature or 1.0,
                                      top_k=top_k, trace=trace_logits),
                donate_argnums=(2, 3, 4))
        elif self.paged:
            self._mixed_fn = jax.jit(
                make_paged_mixed_step(model, sampler, eos_id=eos_id,
                                      max_new=max_new_tokens,
                                      capacity=capacity),
                donate_argnums=(1, 2))
            self._burst_fn = jax.jit(
                make_paged_burst(model, sampler, eos_id=eos_id,
                                 max_new=max_new_tokens, capacity=capacity,
                                 k_static=self.max_burst,
                                 trace=trace_logits),
                donate_argnums=(1, 2))
        else:
            self._mixed_fn = None
            self._burst_fn = jax.jit(
                make_dense_burst(model, sampler, eos_id=eos_id,
                                 max_new=max_new_tokens,
                                 k_static=self.max_burst,
                                 trace=trace_logits),
                donate_argnums=(1, 2))
        # device-resident slot state: uploaded only after structural
        # host mutations, otherwise mutated in-jit and adopted back
        # (replicated over the mesh — page tables / lengths / tokens are
        # global control state every device must see in full)
        self._dev = DeviceSlotState(
            put=(lambda v: jax.device_put(np.asarray(v), self._replicated))
            if mesh is not None else None)
        # scheduler counters
        self.n_batches = 0            # prefill launches (back-compat alias)
        self.n_requests = 0
        self.n_prefills = 0
        self.n_joins = 0              # requests admitted mid-decode
        self.n_evictions = 0          # slots freed by eos/max_new
        self.n_prefill_chunks = 0     # paged: bounded prefill steps run
        self.n_prefix_hits = 0        # paged: admissions that mapped blocks
        self.n_shared_tokens = 0      # prompt tokens served from shared blocks
        self.n_cow_forks = 0          # shared blocks forked before a write
        # scheduler counters
        self.n_preemptions = 0        # batch-lane slots spilled to host
        self.n_restores = 0           # preempted requests re-admitted
        self.n_expired = 0            # queued requests past their deadline
        # decode-loop counters (see loop_stats())
        self.n_bursts = 0             # burst launches (>= 1 device step each)
        self.n_device_steps = 0       # fused megasteps executed on device
        self.n_host_syncs = 0         # decode-loop device->host drains
        self.n_burst_early_exits = 0  # bursts cut short by all-done
        # speculative-decode counters (see loop_stats())
        self.n_spec_rounds = 0        # draft+verify rounds executed
        self.n_spec_tokens = 0        # tokens emitted by those rounds
        self.n_draft_proposed = 0     # draft tokens offered to the verifier
        self.n_draft_accepted = 0     # draft tokens the verifier accepted
        # per-round accepted-length histogram: bin a counts rounds that
        # accepted exactly a draft tokens (a in [0, spec_k])
        self.spec_accept_hist = [0] * (self.spec_k + 1) if self._spec else []
        # fault tolerance: injectable fault plan (serving.faults, duck-
        # typed so None costs one check) + bounded-restart accounting for
        # non-attributable step failures
        self.fault_plan = fault_plan
        self.max_restarts = int(max_restarts)
        self.n_step_failures = 0      # step() exceptions caught
        self.n_restarts = 0           # engine pool rebuilds performed
        self.n_cancelled = 0          # requests cancelled via cancel()
        self._consec_failures = 0     # resets on every clean step

    # -- synchronous fixed batch API (kept for benchmarks/back-compat) ------
    def generate_batch(self, prompts: np.ndarray,
                       extra_embeds=None) -> np.ndarray:
        """prompts: (B, S) int32 -> generated (B, max_new_tokens).

        Always decodes greedily (the continuous API carries the seeded
        sampling path)."""
        B, S = prompts.shape
        assert B == self.batch_size, (B, self.batch_size)
        t0 = time.perf_counter()
        with self._sharding_ctx():
            return self._generate_batch_impl(prompts, extra_embeds, t0)

    def _generate_batch_impl(self, prompts, extra_embeds, t0):
        B, S = prompts.shape
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      extra_embeds)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out = [np.asarray(token)]
        pos = S
        for _ in range(self.max_new_tokens - 1):
            token, _, cache = self._decode(self.params, cache, token,
                                           jnp.int32(pos))
            out.append(np.asarray(token))
            pos += 1
        self.n_batches += 1
        self.n_requests += B
        self.last_batch_latency_s = time.perf_counter() - t0
        return np.concatenate(out, axis=1)

    # -- continuous batching ------------------------------------------------
    def submit(self, prompt: np.ndarray, *, lane: str = "interactive",
               deadline: Optional[float] = None, tag: Any = None) -> int:
        """Enqueue a request; returns its request id (thread-safe).

        ``lane`` picks the priority lane (``"interactive"`` admits ahead
        of any queued ``"batch"`` work and may preempt running batch
        slots); ``deadline`` is a relative TTFT budget in seconds — a
        request still queued when it elapses fails with status
        ``"expired"``; ``tag`` is an opaque caller handle carried into
        nothing engine-side (the network layer uses it for routing)."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.shape[0] == 0:
            raise ValueError(f"prompt must be non-empty 1-D, got {prompt.shape}")
        if prompt.shape[0] > self.capacity:
            raise ValueError(
                f"prompt length {prompt.shape[0]} exceeds KV-cache capacity "
                f"{self.capacity}; raise capacity= or truncate the prompt")
        # vocab validation at the gate: an out-of-range token would index
        # past the embedding table inside a jitted megastep, which can
        # poison a whole batch — reject it before it ever owns a slot
        vocab = getattr(getattr(self.model, "cfg", None), "vocab_size", None)
        if vocab is not None and (int(prompt.min()) < 0
                                  or int(prompt.max()) >= int(vocab)):
            raise ValueError(
                f"prompt tokens outside the model vocab [0, {vocab}) "
                f"(min {int(prompt.min())}, max {int(prompt.max())})")
        now = time.monotonic()
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self.scheduler.push(SchedRequest(
                rid, prompt, lane=lane,
                deadline=None if deadline is None else now + deadline,
                tag=tag, t_submit=now))
            self.n_requests += 1
        return rid

    @property
    def n_active(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    @property
    def has_work(self) -> bool:
        with self._lock:
            return self.scheduler.pending or self.n_active > 0

    def _finish(self, res: GenerationResult) -> None:
        """Record a completed result and wake any wait()ers."""
        with self._results_cv:
            self._results[res.request_id] = res
            self._results_cv.notify_all()

    def _make_result(self, slot, now: float) -> GenerationResult:
        return GenerationResult(
            request_id=slot.rid, prompt=slot.prompt,
            tokens=np.asarray(slot.tokens, np.int32),
            latency_s=now - slot.t_submit, status=slot.status,
            ttft_s=None if slot.t_first is None
            else slot.t_first - slot.t_submit)

    def pool_stats(self) -> Optional[Dict[str, int]]:
        """Block-pool occupancy incl. shared vs private split (paged),
        plus state-slab occupancy for recurrent families, plus the pool
        footprint: ``kv_dtype`` (storage precision), ``bytes_per_block``
        (all attn K/V leaves — scales included for int8 — per physical
        block) and ``pool_bytes`` — the numbers the capacity planning in
        the quantization benchmark (``e10_quant``) is driven by."""
        if self.allocator is None:
            return None
        stats = self.allocator.stats()
        stats["n_reserved"] = self._reserved
        stats["kv_dtype"] = self.kv_dtype or {
            "float32": "f32", "bfloat16": "bf16",
        }.get(jnp.dtype(self.cache_dtype).name,
              jnp.dtype(self.cache_dtype).name)
        stats["bytes_per_block"] = self.kv_bytes_per_block()
        stats["pool_bytes"] = \
            stats["bytes_per_block"] * self.allocator.num_blocks
        if self.state_store is not None:
            s = self.state_store.stats()
            stats["num_state_slots"] = s["num_slots"]
            stats["n_state_free"] = s["n_free"]
            stats["n_state_live"] = s["n_live"]
        return stats

    def kv_bytes_per_block(self) -> int:
        """HBM bytes one physical block costs across every attn layer's
        pool leaves (K + V, plus the f32 scale slivers under
        ``kv_dtype='int8'``).  Computed from ``jax.eval_shape`` of the
        model's pool constructor — no pool has to exist yet — and keyed
        on the leaf *names* (k/v/k_scale/v_scale) so recurrent state
        slabs (sized by slots, not blocks) never pollute the figure."""
        if self._kv_bytes_per_block_cache is None:
            if self.allocator is None:
                return 0
            kw = self._paged_cache_kwargs()
            struct = jax.eval_shape(
                lambda: self.model.init_paged_cache(
                    self.allocator.num_blocks, self.block_size,
                    dtype=self.cache_dtype, **kw))
            kv_names = {"k", "v", "k_scale", "v_scale"}

            def leaf_name(path):
                for p in reversed(path):
                    if isinstance(p, jax.tree_util.DictKey):
                        return p.key
                return None

            def nbytes(leaf):
                return int(np.prod(leaf.shape)) * jnp.dtype(
                    leaf.dtype).itemsize

            leaves = jax.tree_util.tree_flatten_with_path(struct)[0]
            tot = sum(nbytes(l) for path, l in leaves
                      if leaf_name(path) in kv_names)
            if tot == 0:    # model without the k/v naming convention
                tot = sum(nbytes(l) for _, l in leaves)
            self._kv_bytes_per_block_cache = tot // self.allocator.num_blocks
        return self._kv_bytes_per_block_cache

    def loop_stats(self) -> Dict[str, int]:
        """Decode-loop efficiency counters: device steps vs host drains
        vs state uploads.  ``n_host_syncs / n_device_steps`` is the
        host-syncs-per-token figure the burst mode drives toward 1/K;
        ``n_state_uploads`` counts host->device slot-state rebuilds
        (structural events only — steady decode adds none)."""
        out = {"burst": self.burst, "max_burst": self.max_burst,
               "n_bursts": self.n_bursts,
               "n_device_steps": self.n_device_steps,
               "n_host_syncs": self.n_host_syncs,
               "n_burst_early_exits": self.n_burst_early_exits,
               "n_state_uploads": self._dev.n_uploads}
        if self._spec:
            out.update(
                spec_k=self.spec_k,
                n_spec_rounds=self.n_spec_rounds,
                n_spec_tokens=self.n_spec_tokens,
                n_draft_proposed=self.n_draft_proposed,
                n_draft_accepted=self.n_draft_accepted,
                spec_accept_hist=list(self.spec_accept_hist),
                spec_accept_rate=self.n_draft_accepted
                / max(1, self.n_draft_proposed))
        return out

    def compile_stats(self) -> Dict[str, int]:
        """Compilation counts of the jitted hot-path functions.  The
        burst megastep must compile exactly once per engine (its K
        bound is traced); the mixed megastep once (T is pinned to
        ``prefill_chunk``).  CI asserts these to catch silent recompile
        regressions."""
        out = {}
        for name, fn in (("megastep_burst", self._burst_fn),
                         ("megastep_mixed", self._mixed_fn),
                         ("prefill", self._prefill)):
            if fn is None:
                continue
            try:
                out[name] = fn._cache_size()
            except AttributeError:      # older jax: no cache introspection
                pass
        return out

    def _sharding_ctx(self):
        """Mesh context for the jitted serving paths.  Tracing under
        ``with mesh:`` is what activates every ``constrain(...)`` inside
        the model / megasteps (they no-op without an active mesh), so
        all entry points that can trigger a jit call enter it."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def step(self) -> List[GenerationResult]:
        """Admit what fits, run one decode burst (or a mixed
        prefill+decode megastep), evict what finished.

        Returns results for requests that completed during this step.

        A step exception is *non-attributable* — there is no way to
        know which resident request poisoned the megastep — so the
        engine restarts: live slots are spilled to host and re-queued
        (the PR 6 preemption path, bit-identical on restore), the
        device pools and allocator are rebuilt, and serving continues.
        Restarts are bounded by ``max_restarts`` *consecutive*
        failures; past that every in-flight and queued request is
        failed and the exception propagates.
        """
        fault = self.fault_plan.fire("engine_step") if self.fault_plan \
            else None
        try:
            if fault is not None and fault.action == "raise":
                raise fault.make_exc()
            with self._sharding_ctx():
                out = self._step_impl()
        except Exception as exc:
            return self._handle_step_failure(exc)
        self._consec_failures = 0
        return out

    def _handle_step_failure(self, exc: Exception) -> List[GenerationResult]:
        """Recover from a non-attributable step exception: bounded
        restart (spill survivors, rebuild pools) or — past the budget —
        fail everything and re-raise."""
        self.n_step_failures += 1
        self._consec_failures += 1
        if self._consec_failures > self.max_restarts:
            now = time.monotonic()
            msg = f"engine wedged after {self.n_restarts} restarts: {exc}"
            with self._lock:
                queued = []
                for req in list(self.scheduler.candidates()):
                    self.scheduler.remove(req)
                    queued.append(req)
            for req in queued:
                self._finish(GenerationResult(
                    request_id=req.rid, prompt=req.prompt,
                    tokens=np.asarray(req.tokens, np.int32),
                    latency_s=now - req.t_submit, status="error", error=msg))
            for i, slot in enumerate(self._slots):
                if slot is None:
                    continue
                self._finish(GenerationResult(
                    request_id=slot.rid, prompt=slot.prompt,
                    tokens=np.asarray(slot.tokens, np.int32),
                    latency_s=now - slot.t_submit, status="error", error=msg))
                self._slots[i] = None
            self._reset_pools()        # nothing leaks even in death
            raise exc
        self.n_restarts += 1
        self._restart()
        return []

    def _restart(self) -> None:
        """Rebuild the serving pools after a step failure.

        Paged mode: every live slot is spilled via the preemption path
        (decode slots gather their pages/slab to host; mid-prefill
        slots simply restart) and re-queued at its lane's front, then
        the device caches, allocator, and state store are rebuilt from
        scratch — donation means the old cache arrays may already be
        deleted, and the content table would advertise garbage over a
        fresh pool either way.  A slot whose spill itself fails (e.g.
        its pages lived in a donated-away buffer) is failed alone with
        status ``"error"``.  Dense mode has no spill path: in-flight
        slots are failed, queued work survives untouched."""
        now = time.monotonic()
        for i, slot in enumerate(self._slots):
            if slot is None:
                continue
            spilled = False
            if self.paged and not slot.done:
                try:
                    with self._sharding_ctx():
                        self._preempt_slot(i)
                    spilled = True
                except Exception:
                    pass               # unsalvageable: fail it below
            if not spilled:
                self._finish(GenerationResult(
                    request_id=slot.rid, prompt=slot.prompt,
                    tokens=np.asarray(slot.tokens, np.int32),
                    latency_s=now - slot.t_submit, status="error",
                    error="lost in engine restart"))
            self._slots[i] = None
        self._reset_pools()

    def _reset_pools(self) -> None:
        """Rebuild device caches + host accounting from scratch (all
        slots must already be empty)."""
        if self.paged:
            old = self.allocator
            self.allocator = BlockAllocator(
                old.num_blocks, old.block_size,
                retain_cap=old.retain_cap, retain_ttl_s=old.retain_ttl_s)
            if self.state_store is not None:
                self.state_store = StateStore(self.num_state_slots)
            self._paged_cache = None
            self._draft_cache = None
        else:
            self._cache = None
            self._pos = 0
        self._reserved = 0
        self._page_table[:, :] = 0
        self._lengths[:] = 0
        self._state_slots[:] = 0
        self._dev.mark_dirty()

    def cancel(self, rid: int, status: str = "cancelled") -> bool:
        """Cancel one request wherever it is — queued, mid-prefill, or
        mid-decode-burst (the drained ring is replayed up to the cancel
        point, so its result carries every token generated before the
        cancel landed).  Its blocks, state slab, and any retained
        content-table registrations are freed.  Returns True if the
        request was live and is now terminal with ``status``; False if
        it was unknown or already finished (the existing result is left
        for its waiter)."""
        with self._results_cv:
            if rid in self._results:
                return False
        self._cancel([rid], status)
        with self._results_cv:
            done = rid in self._results
        if done:
            self.n_cancelled += 1
        return done

    def inflight_rids(self) -> List[int]:
        """Rids with no result yet: queued plus resident in a slot."""
        with self._lock:
            queued = [req.rid for req in self.scheduler.candidates()]
        return queued + [s.rid for s in self._slots if s is not None]

    def _step_impl(self) -> List[GenerationResult]:
        if self.paged:
            return self._step_paged()
        self._admit()
        finished = self._evict()
        if self.n_active == 0:
            return finished
        if self._pos >= self.capacity:
            # cache exhausted: truncate everything still in flight
            for slot in self._slots:
                if slot is not None:
                    slot.done = True
            return finished + self._evict()
        with self._lock:
            pending = self.scheduler.pending
        # queue non-empty -> single-step so the next eviction admits at
        # once; otherwise burst, capped at the cache strip's remainder
        k = 1 if pending else min(self.burst, self.max_burst)
        k = max(1, min(k, self.capacity - self._pos))
        st = self._dev.device(self._dense_state)
        out = self._burst_fn(self.params, self._cache, st,
                             jnp.int32(self._pos), np.int32(k))
        self._cache = out[0]
        self._dev.adopt(out[1])
        self._drain_burst(out[2], out[3],
                          out[4] if self.trace_logits else None,
                          k=k, paged=False)
        return finished + self._evict()

    def serve(self, requests: List[np.ndarray], timeout_s: float = 120.0,
              lane: str = "interactive") -> List[GenerationResult]:
        """Serve via continuous batching; results in request order.

        On timeout the results completed before the deadline are
        returned as-is and every unfinished request is failed with
        status ``"timeout"`` (its tokens so far attached) — nothing is
        dropped and the engine's pool is left clean."""
        rids = [self.submit(r, lane=lane) for r in requests]
        return self.wait(rids, timeout_s=timeout_s)

    def wait(self, rids: List[int],
             timeout_s: Optional[float] = None) -> List[GenerationResult]:
        """Block until every request in ``rids`` has a result, driving
        ``step()`` whenever no other thread is.  Safe to call from
        multiple threads over one engine: all submissions share the
        scheduler, exactly one waiter steps at a time, and each waiter
        collects (and removes) only its own results."""
        deadline = None if timeout_s is None \
            else time.monotonic() + timeout_s
        while True:
            with self._results_cv:
                if all(r in self._results for r in rids):
                    break
                missing = [r for r in rids if r not in self._results]
            if deadline is not None and time.monotonic() >= deadline:
                self._cancel(missing, "timeout")
                break
            if self._step_lock.acquire(blocking=False):
                try:
                    if self.has_work:
                        self.step()
                    else:
                        time.sleep(0.001)
                finally:
                    self._step_lock.release()
            else:
                with self._results_cv:
                    self._results_cv.wait(timeout=0.005)
        with self._results_cv:
            return [self._results.pop(rid) for rid in rids
                    if rid in self._results]

    def _cancel(self, rids: List[int], status: str) -> None:
        """Fail every request in ``rids``: queued ones are popped with
        their (possibly preempted) tokens attached, in-flight ones are
        evicted with whatever they generated so far.  Runs under the
        step lock so no megastep is mid-flight while slots are torn
        down."""
        rids = set(rids)
        if not rids:
            return
        with self._step_lock:
            now = time.monotonic()
            with self._lock:
                popped = [self.scheduler.pop_rid(rid) for rid in rids]
            for req in popped:
                if req is None:
                    continue
                self._finish(GenerationResult(
                    request_id=req.rid, prompt=req.prompt,
                    tokens=np.asarray(req.tokens, np.int32),
                    latency_s=now - req.t_submit, status=status))
            dirty = False
            dead_blocks: List[int] = []
            for slot in self._slots:
                if slot is not None and slot.rid in rids:
                    slot.status = status
                    slot.done = True
                    if self.paged:
                        dead_blocks += list(slot.blocks)
                    dirty = True
            if dirty:
                self._evict_paged() if self.paged else self._evict()
                # a cancelled request's pages must not linger as
                # retained prefix bait: retire any of its blocks that
                # eviction parked on the retained list (blocks still
                # shared with a live slot are untouched)
                for b in dead_blocks:
                    self.allocator.retire(b)

    def as_pipeline_filter(self, *, use_meta: bool = False,
                           on_submit=None, timeout_s: Optional[float] = None):
        """Adapter: (n, S) prompt batch -> (n, max_new_tokens) generations.

        Row order in == row order out, so TensorUnbatcher downstream can
        restore per-request pts/meta.  Rows shorter than max_new (early
        eos) are right-padded with eos_id (or 0).

        With ``use_meta`` the returned callable accepts the per-row meta
        dicts a ``pass_meta`` TensorFilter forwards: each row's
        ``meta["query"]`` may carry ``prompt_len`` (strip transport
        left-padding), ``lane``, ``deadline`` (relative seconds) and
        ``tag``; after serving, ``status`` / ``ttft_s`` / ``n_tokens``
        are written back into the meta for the downstream sink.
        ``on_submit(rid, meta)`` fires immediately after each row is
        submitted — before any token is generated — so a streaming
        front door can route ``stream_cb`` tokens by request id."""
        pad = self.eos_id if self.eos_id is not None else 0

        def fn(prompts, metas=None):
            prompts = np.asarray(prompts, np.int32)
            ms = list(metas) if (use_meta and metas is not None) \
                else [None] * len(prompts)
            rids: List[Optional[int]] = []
            for row, m in zip(prompts, ms):
                q = m.get("query", {}) if isinstance(m, dict) else {}
                plen = int(q.get("prompt_len", 0)) or row.shape[0]
                # per-row isolation: a poison prompt (bad shape, vocab
                # overflow, injected "submit" fault) fails only its own
                # row — the rest of the batch is served normally
                try:
                    f = self.fault_plan.fire("submit") if self.fault_plan \
                        else None
                    if f is not None and f.action == "raise":
                        raise f.make_exc()
                    rid = self.submit(row[row.shape[0] - plen:],
                                      lane=q.get("lane", "interactive"),
                                      deadline=q.get("deadline"),
                                      tag=q.get("tag"))
                except Exception as exc:
                    rids.append(None)
                    if isinstance(m, dict):
                        m.update(status="error", error=str(exc), n_tokens=0)
                    continue
                rids.append(rid)
                if isinstance(m, dict):
                    m["rid"] = rid
                if on_submit is not None:
                    on_submit(rid, m)
            live = [r for r in rids if r is not None]
            err = None
            try:
                f = self.fault_plan.fire("worker") if self.fault_plan \
                    else None
                if f is not None and f.action == "raise":
                    raise f.make_exc()
                results = self.wait(live, timeout_s=timeout_s)
            except Exception as exc:
                # worker-level failure after submission: fail exactly
                # this batch's requests (with a clean two-pool free) and
                # surface the message — other workers' requests and the
                # engine itself keep going
                err = str(exc)
                self._cancel(live, "error")
                with self._results_cv:
                    results = [self._results.pop(r) for r in live
                               if r in self._results]
            by_id = {r.request_id: r for r in results}
            out = np.full((len(rids), self.max_new_tokens), pad, np.int32)
            for i, rid in enumerate(rids):
                if rid is None:
                    continue          # failed at submit; meta already set
                r = by_id.get(rid)
                if r is None:
                    if isinstance(ms[i], dict):
                        ms[i].update(status="error", n_tokens=0,
                                     error=err or "request lost")
                    continue
                out[i, : len(r.tokens)] = r.tokens
                if isinstance(ms[i], dict):
                    ms[i].update(status=r.status, ttft_s=r.ttft_s,
                                 n_tokens=int(len(r.tokens)))
                    if r.status == "error":
                        ms[i]["error"] = r.error or err or "request failed"
            return out
        return fn

    # -- sampling -----------------------------------------------------------
    def _sample_rows(self, logits, rids: np.ndarray,
                     steps: np.ndarray) -> np.ndarray:
        """Draw one token per batch row through the shared sampler
        (admission path only — the decode loop samples inside the fused
        megastep).  ``rids``/``steps`` are (B,) int32 vectors; the
        per-row key is derived from them inside the jit, so a slot's
        draw is a pure function of (seed, request, step) —
        serving-mode independent.  Idle rows carry (0, 0); callers only
        consume rows they populated (greedy ignores them entirely)."""
        return np.asarray(self._sample(jnp.asarray(logits),
                                       jnp.asarray(rids, dtype=jnp.int32),
                                       jnp.asarray(steps, dtype=jnp.int32)))

    # -- device-resident slot state -----------------------------------------
    def _dense_state(self) -> Dict[str, np.ndarray]:
        """Host rebuild of the dense-mode device state (dirty path)."""
        B = self.batch_size
        tokens = np.zeros((B,), np.int32)
        rids = np.zeros((B,), np.int32)
        steps = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            rids[i] = s.rid
            steps[i] = len(s.tokens)
            if s.tokens:
                tokens[i] = s.tokens[-1]
            active[i] = not s.done
        return {"tokens": tokens, "rids": rids, "steps": steps,
                "active": active}

    def _paged_state(self) -> Dict[str, np.ndarray]:
        """Host rebuild of the paged-mode device state (dirty path)."""
        B = self.batch_size
        tokens = np.zeros((B,), np.int32)
        rids = np.zeros((B,), np.int32)
        steps = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            rids[i] = s.rid
            steps[i] = len(s.tokens)
            if s.tokens:
                tokens[i] = s.tokens[-1]
            # decoding = prefill complete, first token sampled, not done,
            # cache strip not exhausted (the burst body writes at
            # `lengths` before its own done check, so an active row must
            # always have room for one token)
            active[i] = (not s.done and s.prefill_off >= len(s.prompt)
                         and len(s.tokens) > 0
                         and int(self._lengths[i]) < self.capacity)
        out = {"tokens": tokens, "rids": rids, "steps": steps,
               "active": active, "page_table": self._page_table,
               "lengths": self._lengths, "state_slots": self._state_slots}
        if self._spec:
            rounds = np.zeros((B,), np.int32)
            deficit = np.zeros((B,), np.int32)
            prev = np.zeros((B,), np.int32)
            for i, s in enumerate(self._slots):
                if s is None:
                    continue
                rounds[i] = s.spec_rounds
                deficit[i] = s.spec_deficit
                prev[i] = s.spec_prev
            out.update(spec_rounds=rounds, spec_deficit=deficit,
                       spec_prev=prev)
        return out

    def _drain_burst(self, tok_buf, val_buf, logit_buf, *, k: int,
                     paged: bool) -> None:
        """One host sync per burst: fetch the token ring buffer, append
        tokens to their slots, and replay the in-jit done rule (eos /
        max_new / cache exhausted) so the host mirror stays coherent
        with the device's ``active`` flags."""
        bufs = (tok_buf, val_buf) if logit_buf is None \
            else (tok_buf, val_buf, logit_buf)
        got = jax.device_get(bufs)
        self.n_host_syncs += 1
        toks, valid = got[0], got[1]
        logits = got[2] if logit_buf is not None else None
        n_steps = int(valid.any(axis=1).sum())
        self.n_bursts += 1
        self.n_device_steps += n_steps
        if n_steps < k:
            self.n_burst_early_exits += 1
        fresh: Dict[int, List[int]] = {}
        for kstep in range(n_steps):
            for i, slot in enumerate(self._slots):
                if slot is None or not valid[kstep, i]:
                    continue
                if logits is not None:
                    self.logit_trace.setdefault(slot.rid, []).append(
                        logits[kstep, i].copy())
                slot.tokens.append(int(toks[kstep, i]))
                fresh.setdefault(i, []).append(slot.tokens[-1])
                if paged:
                    self._lengths[i] += 1
                if ((self.eos_id is not None
                     and slot.tokens[-1] == self.eos_id)
                        or len(slot.tokens) >= self.max_new_tokens
                        or (paged
                            and int(self._lengths[i]) >= self.capacity)):
                    slot.done = True
        if not paged:
            self._pos += n_steps
        now = time.monotonic()
        for i, new_toks in fresh.items():
            slot = self._slots[i]
            if slot.t_first is None:
                slot.t_first = now
            if self.stream_cb is not None:
                self.stream_cb(slot.rid, new_toks)

    def _drain_spec_burst(self, tok_buf, val_buf, logit_buf, *,
                          k: int) -> None:
        """Speculative-burst drain: the rings are ``(k, B, spec_k+1)``
        — round ``r`` emitted slot ``b``'s tokens at the valid
        positions, always a contiguous prefix (accepted drafts, then
        one replacement/bonus token, truncated at eos).  Replays the
        in-jit done rule per token and the spec-field update
        (``spec_rounds``/``spec_deficit``/``spec_prev``) per round so
        the host mirror can rebuild device state after any structural
        event, and accumulates the acceptance statistics."""
        bufs = (tok_buf, val_buf) if logit_buf is None \
            else (tok_buf, val_buf, logit_buf)
        got = jax.device_get(bufs)
        self.n_host_syncs += 1
        toks, valid = got[0], got[1]
        logits = got[2] if logit_buf is not None else None
        n_rounds = int(valid.any(axis=(1, 2)).sum())
        self.n_bursts += 1
        self.n_device_steps += n_rounds
        if n_rounds < k:
            self.n_burst_early_exits += 1
        fresh: Dict[int, List[int]] = {}
        for r in range(n_rounds):
            for i, slot in enumerate(self._slots):
                if slot is None or not valid[r, i].any():
                    continue
                # per-round draft budget, recomputed from the
                # *pre-round* host mirrors (same formula as in-jit)
                gb = max(0, min(self.max_new_tokens - len(slot.tokens) - 1,
                                self.capacity - int(self._lengths[i]) - 1,
                                self.spec_k))
                m = int(valid[r, i].sum())
                for j in range(m):
                    if logits is not None:
                        self.logit_trace.setdefault(slot.rid, []).append(
                            logits[r, i, j].copy())
                    slot.tokens.append(int(toks[r, i, j]))
                    fresh.setdefault(i, []).append(slot.tokens[-1])
                    self._lengths[i] += 1
                    if ((self.eos_id is not None
                         and slot.tokens[-1] == self.eos_id)
                            or len(slot.tokens) >= self.max_new_tokens
                            or int(self._lengths[i]) >= self.capacity):
                        slot.done = True
                slot.spec_rounds += 1
                slot.spec_deficit = 1 if m == gb + 1 else 0
                slot.spec_prev = self._seq_tokens(
                    slot, int(self._lengths[i]) - 1,
                    int(self._lengths[i]))[0]
                self.n_spec_rounds += 1
                self.n_spec_tokens += m
                self.n_draft_proposed += gb
                # the round's last emitted token is the replacement /
                # bonus draw, everything before it an accepted draft
                # (a round cut short by an eos *inside* the drafted
                # prefix under-counts by one; the slot finishes then,
                # so the drift is at most 1 per request)
                self.n_draft_accepted += m - 1
                self.spec_accept_hist[min(m - 1, self.spec_k)] += 1
        now = time.monotonic()
        for i, new_toks in fresh.items():
            slot = self._slots[i]
            if slot.t_first is None:
                slot.t_first = now
            if self.stream_cb is not None:
                self.stream_cb(slot.rid, new_toks)

    # -- scheduler internals ------------------------------------------------
    def _expire_queued(self) -> None:
        """Fail queued requests whose TTFT deadline has passed."""
        now = time.monotonic()
        with self._lock:
            dead = self.scheduler.expire(now)
        for req in dead:
            self.n_expired += 1
            self._finish(GenerationResult(
                request_id=req.rid, prompt=req.prompt,
                tokens=np.asarray(req.tokens, np.int32),
                latency_s=now - req.t_submit, status="expired"))

    def _admit(self) -> None:
        self._expire_queued()
        free = [i for i, s in enumerate(self._slots) if s is None]
        if not free:
            return
        with self._lock:
            if not self.scheduler.pending:
                return
            if self.n_active == 0:
                # batch drained: re-anchor with a fresh prefill wave,
                # taking candidates in lane-priority order
                self._cache = None
                take = list(self.scheduler.candidates())[:len(free)]
                for req in take:
                    self.scheduler.remove(req)
                joins = list(zip(free, take))
                fresh = True
            elif self._pos >= self.capacity:
                # cache exhausted: in-flight slots are about to be
                # truncated; hold newcomers for the fresh re-anchor
                return
            else:
                # mid-decode join: only prompts that fit the current
                # position (scans the whole queue — a long prompt can
                # never block a short one queued behind it)
                joins = []
                for req in self.scheduler.candidates():
                    if len(joins) < len(free) \
                            and req.prompt.shape[0] <= self._pos:
                        self.scheduler.remove(req)
                        joins.append((free[len(joins)], req))
                fresh = False
        if not joins:
            return
        B = self.batch_size
        if fresh:
            maxlen = max(req.prompt.shape[0] for _, req in joins)
            self._pos = maxlen
        batch = np.zeros((B, self._pos), np.int32)
        for slot_i, req in joins:
            batch[slot_i, self._pos - req.prompt.shape[0]:] = req.prompt
        logits, cache = self._prefill(self.params, jnp.asarray(batch), None)
        if self._greedy:
            first_np = np.asarray(jnp.argmax(logits, axis=-1)
                                  .astype(jnp.int32))
        else:
            rids = np.zeros((B,), np.int32)
            for slot_i, req in joins:
                rids[slot_i] = req.rid
            first_np = self._sample_rows(logits, rids, np.zeros((B,), np.int32))
        self.n_prefills += 1
        self.n_batches += 1
        if fresh:
            self._cache = cache
        else:
            slot_ids = [slot_i for slot_i, _ in joins]
            self._cache = self._splice_cache(self._cache, cache, slot_ids)
            self.n_joins += len(joins)
        logits_np = np.asarray(logits) if self.trace_logits else None
        now = time.monotonic()
        for slot_i, req in joins:
            if self.trace_logits:
                self.logit_trace.setdefault(req.rid, []).append(
                    logits_np[slot_i].copy())
            slot = _Slot(req, first_np[slot_i], self.eos_id,
                         self.max_new_tokens)
            slot.t_first = now
            slot.adm_seq = self._adm_seq
            self._adm_seq += 1
            self._slots[slot_i] = slot
            if self.stream_cb is not None:
                self.stream_cb(slot.rid, [slot.tokens[-1]])
        self._dev.mark_dirty()

    def _evict(self) -> List[GenerationResult]:
        out: List[GenerationResult] = []
        now = time.monotonic()
        for i, slot in enumerate(self._slots):
            if slot is None or not slot.done:
                continue
            res = self._make_result(slot, now)
            out.append(res)
            self._finish(res)
            self._slots[i] = None
            self.n_evictions += 1
        return out

    # -- paged scheduler ----------------------------------------------------
    def _step_paged(self) -> List[GenerationResult]:
        """One engine tick in paged mode.

        While any slot is still consuming its prompt, one batched
        *mixed* megastep advances every busy slot: decoding slots feed
        their last token (t_valid=1), prefilling slots feed their next
        ``prefill_chunk`` prompt tokens, idle slots ride along masked
        out (t_valid=0).  Once the batch is pure decode, the engine
        runs *bursts* instead: up to ``burst`` fused device steps per
        host round-trip (K=1 whenever requests are queued, so the next
        eviction admits immediately).  T therefore buckets to just two
        shapes — 1 (burst body) and ``prefill_chunk`` — and the burst
        bound is traced, so each megastep compiles exactly once.
        Before any step, shared blocks in the coming write range are
        forked (COW) and page tables pre-extended to cover it; after
        it, newly completed pages are published to the content table
        for future joiners.
        """
        # periodic retention sweep: TTL expiry must not depend on
        # allocation traffic — an idle server still ticks through here,
        # so expired prefix blocks are retired even with no admissions
        # or completions in flight (no-op without retain_ttl_s)
        self.allocator.sweep()
        self._admit_paged()
        finished = self._evict_paged()
        busy = [(i, s) for i, s in enumerate(self._slots) if s is not None]
        if not busy:
            return finished
        self._ensure_paged_cache()
        if any(s.prefill_off < len(s.prompt) for _, s in busy):
            self._step_paged_mixed(busy)
        else:
            self._step_paged_burst(busy)
        if self.share_prefix:
            for i, slot in busy:
                self._register_full_pages(i, slot)
        return finished + self._evict_paged()

    def _step_paged_mixed(self, busy) -> None:
        """One mixed prefill+decode megastep (T = ``prefill_chunk``)."""
        T = self.prefill_chunk
        tokens = np.zeros((self.batch_size, T), np.int32)
        t_valid = np.zeros((self.batch_size,), np.int32)
        emit = np.zeros((self.batch_size,), bool)
        for i, slot in busy:
            if slot.done:
                continue
            if slot.prefill_off < len(slot.prompt):
                n = min(T, len(slot.prompt) - slot.prefill_off)
                tokens[i, :n] = slot.prompt[slot.prefill_off:
                                            slot.prefill_off + n]
                t_valid[i] = n
                emit[i] = slot.prefill_off + n >= len(slot.prompt)
            elif self._lengths[i] >= self.capacity:
                slot.done = True      # cache strip exhausted: truncate
            else:
                tokens[i, 0] = slot.tokens[-1]
                t_valid[i] = 1
                emit[i] = True
        if not t_valid.any():
            return
        for i, slot in busy:
            if t_valid[i]:
                self._cow_write_range(i, slot, int(self._lengths[i]),
                                      int(t_valid[i]))
                self._extend_blocks(i, slot,
                                    int(self._lengths[i]) + int(t_valid[i]))
        st = self._dev.device(self._paged_state)
        if self._spec:
            cache, dcache, st, sampled, logits = self._mixed_fn(
                self.params, self.draft_params, self._paged_cache,
                self._draft_cache, st, jnp.asarray(tokens),
                jnp.asarray(t_valid), jnp.asarray(emit))
            self._draft_cache = dcache
        else:
            cache, st, sampled, logits = self._mixed_fn(
                self.params, self._paged_cache, st, jnp.asarray(tokens),
                jnp.asarray(t_valid), jnp.asarray(emit))
        self._paged_cache = cache
        self._dev.adopt(st)
        self.n_prefill_chunks += 1
        self.n_device_steps += 1
        if self.trace_logits:
            sampled_np, logits_np = jax.device_get((sampled, logits))
        else:
            sampled_np, logits_np = np.asarray(sampled), None
        self.n_host_syncs += 1
        for i, slot in busy:
            if not t_valid[i]:
                continue
            was_prefilling = slot.prefill_off < len(slot.prompt)
            self._lengths[i] += t_valid[i]
            if self._spec:
                # replay of the in-jit spec-field update: consuming any
                # chunk catches the draft cache up (deficit 0) and the
                # chunk's last token sits at position lengths-1
                slot.spec_deficit = 0
                slot.spec_prev = int(tokens[i, int(t_valid[i]) - 1])
            if was_prefilling:
                slot.prefill_off += int(t_valid[i])
                if slot.prefill_off < len(slot.prompt):
                    continue          # more chunks to go; no token yet
                self.n_prefills += 1
                self.n_batches += 1
            if self.trace_logits:
                self.logit_trace.setdefault(slot.rid, []).append(
                    logits_np[i].copy())
            slot.tokens.append(int(sampled_np[i]))
            if slot.t_first is None:
                slot.t_first = time.monotonic()
            if self.stream_cb is not None:
                self.stream_cb(slot.rid, [slot.tokens[-1]])
            # replay of the megastep's in-jit done rule
            if ((self.eos_id is not None and slot.tokens[-1] == self.eos_id)
                    or len(slot.tokens) >= self.max_new_tokens
                    or int(self._lengths[i]) >= self.capacity):
                slot.done = True

    def _step_paged_burst(self, busy) -> None:
        """Up to ``burst`` pure-decode megasteps in one device loop.

        Before launching, every active slot's page table is extended to
        cover the burst's worst-case write range (drawn from the
        admission-time reservation, so this can never fail) and any
        shared block in that range is COW-forked — the loop then never
        needs the host until its ring buffer is drained."""
        with self._lock:
            pending = self.scheduler.pending
        k = 1 if pending else min(self.burst, self.max_burst)
        k = max(1, k)
        any_active = False
        for i, slot in busy:
            if slot.done:
                continue
            L = int(self._lengths[i])
            if L >= self.capacity:
                slot.done = True      # cache strip exhausted: truncate
                continue
            # a plain burst writes at most k tokens; a speculative one
            # writes up to spec_k+1 positions per round (even rejected
            # drafts are written, then rolled back by arithmetic).
            # Both stop at max_new (final length = prompt + max_new - 1,
            # and the per-round draft budget keeps every *write* under
            # that too) and at capacity.
            span = (self.spec_k + 1) if self._spec else 1
            target = min(L + k * span,
                         len(slot.prompt) + self.max_new_tokens - 1,
                         self.capacity)
            if target > L:
                self._cow_write_range(i, slot, L, target - L)
                self._extend_blocks(i, slot, target)
            any_active = True
        if not any_active:
            return
        st = self._dev.device(self._paged_state)
        if self._spec:
            out = self._burst_fn(self.params, self.draft_params,
                                 self._paged_cache, self._draft_cache, st,
                                 np.int32(k))
            self._paged_cache, self._draft_cache = out[0], out[1]
            self._dev.adopt(out[2])
            self._drain_spec_burst(out[3], out[4],
                                   out[5] if self.trace_logits else None,
                                   k=k)
            return
        out = self._burst_fn(self.params, self._paged_cache, st, np.int32(k))
        self._paged_cache = out[0]
        self._dev.adopt(out[1])
        self._drain_burst(out[2], out[3],
                          out[4] if self.trace_logits else None,
                          k=k, paged=True)

    def _match_prefix(self, prompt: np.ndarray) \
            -> Tuple[List[int], List[bytes], int]:
        """Longest resident chain matching the prompt.

        Returns ``(mapped, digests, matched)``: physical blocks to map
        at pages ``0..len(mapped)-1``, chain digests of the pages fully
        covered by ``matched``, and the number of prompt tokens those
        blocks serve.  Matching walks full pages by chain digest, then
        tries to land the final partial page on another sequence's
        completed block (``lookup_tail``).  ``matched`` is capped at
        ``len(prompt) - 1`` so at least one prompt token always runs
        through the model — the joiner's first sampled token needs
        logits — which may leave the write cursor inside a shared block;
        the COW fork at write time keeps that sound.
        """
        if not self.share_prefix:
            return [], [], 0
        bs = self.block_size
        L = len(prompt)
        parent = ROOT_DIGEST
        mapped: List[int] = []
        digests: List[bytes] = []
        off = 0
        while off + bs <= L:
            toks = tuple(int(t) for t in prompt[off:off + bs])
            block = self.allocator.lookup(parent, toks)
            if block is None:
                break
            parent = chain_digest(parent, toks)
            mapped.append(block)
            digests.append(parent)
            off += bs
        if 2 <= L - off < bs:
            # a 1-token tail is pure overhead: its only token would be
            # re-run (and fork the block) anyway, so require >= 2
            tail = self.allocator.lookup_tail(
                parent, tuple(int(t) for t in prompt[off:L]))
            if tail is not None:
                mapped.append(tail)
                off = L
        matched = min(off, L - 1)
        return mapped, digests[:matched // bs], matched

    def _match_prefix_cached(self, req: SchedRequest):
        """Memoized match for a queued request.  Blocks only enter or
        leave the content table through register/unregister, each of
        which bumps the allocator's ``epoch`` — so while the epoch is
        unchanged a cached match is still valid and a blocked request
        costs O(1) per admission scan instead of re-hashing its whole
        prompt."""
        if req.match is None or req.match_epoch != self.allocator.epoch:
            req.match = self._match_prefix(req.prompt)
            req.match_epoch = self.allocator.epoch
        return req.match

    def _admit_paged(self) -> None:
        """Admit queued requests into free slots, in lane-priority order
        (interactive first, FIFO within a lane).

        A request needs a slot plus a worst-case *private*-block
        reservation: the pages its matched prefix shares forever are
        discounted, everything else (fresh prompt pages, decode
        extensions, possible COW forks in the write range) is budgeted
        up front, so mid-decode allocation never fails.  Recurrent
        families additionally need one free state slab — checked before
        anything is taken, so admission stays all-or-nothing across
        both pools.  The scan is *size-aware*: a candidate that does
        not fit stays queued and the scan moves on, so a too-large
        request can never head-of-line-block a smaller one behind it.
        If an interactive candidate is blocked on resources while
        batch-lane slots are running, the youngest batch slot is
        preempted (spilled to host memory, re-queued at its lane's
        front) and the scan retries."""
        self._expire_queued()
        while True:
            blocked_interactive = self._admit_paged_scan()
            if blocked_interactive and self._preempt_for_interactive():
                continue
            return

    def _admit_paged_scan(self) -> bool:
        """One admission pass; returns True if an interactive candidate
        was left queued for lack of resources."""
        free = [i for i, s in enumerate(self._slots) if s is None]
        mid_decode = self.n_active > 0
        joins = []
        blocked_interactive = False
        with self._lock:
            for req in self.scheduler.candidates():
                if blocked_interactive and req.lane == "batch":
                    # strict priority: batch work must not slip past a
                    # resource-blocked interactive candidate (it would
                    # be preempted right back — livelock)
                    continue
                if not free:
                    if req.lane == "interactive":
                        blocked_interactive = True
                    break
                try:
                    fit = self._restore_fit(req, free) if req.preempted \
                        else self._fresh_fit(req, free)
                except CacheFullError:
                    # transient allocator storm (real or injected): the
                    # candidate stays queued, never oom-failed
                    continue
                except Exception as exc:
                    # attributable to this candidate alone: fail it,
                    # keep scanning — one bad request must not block
                    # the queue or poison its neighbours
                    self.scheduler.remove(req)
                    self._finish(GenerationResult(
                        request_id=req.rid, prompt=req.prompt,
                        tokens=np.asarray(req.tokens, np.int32),
                        latency_s=time.monotonic() - req.t_submit,
                        status="error", error=f"admission failed: {exc}"))
                    continue
                if fit is None:
                    if self.allocator.n_live == 0 and self._reserved == 0 \
                            and (self.state_store is None
                                 or self.state_store.n_live == 0):
                        # does not fit an *empty* pool: it never will —
                        # fail it instead of wedging the queue forever
                        self.scheduler.remove(req)
                        self._finish(GenerationResult(
                            request_id=req.rid, prompt=req.prompt,
                            tokens=np.asarray(req.tokens, np.int32),
                            latency_s=time.monotonic() - req.t_submit,
                            status="oom"))
                        continue
                    if req.lane == "interactive":
                        blocked_interactive = True
                    continue           # size-aware: scan past this one
                self.scheduler.remove(req)
                joins.append(fit)
        for join in joins:
            kind, slot_i, req = join[0], join[1], join[2]
            slot = self._build_restore_slot(join) if kind == "restore" \
                else self._build_fresh_slot(join, mid_decode)
            slot.adm_seq = self._adm_seq
            self._adm_seq += 1
            self._slots[slot_i] = slot
        if joins:
            self._dev.mark_dirty()
        return blocked_interactive

    def _fresh_fit(self, req: SchedRequest, free: List[int]):
        """Try to take resources for a fresh admission (all-or-nothing);
        None if the request does not fit right now."""
        f = self.fault_plan.fire("admit") if self.fault_plan else None
        if f is not None and f.action == "raise":
            raise f.make_exc()         # before anything is taken
        plen = req.prompt.shape[0]
        mapped, digests, matched = self._match_prefix_cached(req)
        total = self.allocator.blocks_for(
            min(plen + self.max_new_tokens, self.capacity))
        # pages below matched // block_size are never written by this
        # slot, so they stay shared for its whole lifetime
        needed = total - matched // self.block_size
        # retained mapped blocks are resurrected off the free list by
        # share() below — they consume free-list entries on top of the
        # private budget, so the fit check must count them
        n_resurrect = sum(1 for b in mapped if self.allocator.ref(b) == 0)
        if needed + n_resurrect > self.allocator.n_free - self._reserved:
            return None
        if self.state_store is not None and self.state_store.n_free == 0:
            return None                # state slabs exhausted: stay queued
        # share (and resurrect) the mapped prefix *before* acquiring
        # fresh blocks — acquire recycles retained blocks and must never
        # recycle one this very admission is about to map
        self.allocator.share(mapped)
        n_fresh = self.allocator.blocks_for(plen) - len(mapped)
        try:
            fresh = self.allocator.acquire(n_fresh)
        except CacheFullError:           # unreachable given the check above
            self.allocator.release(mapped)
            return None
        blocks = mapped + fresh
        self._reserved += needed - n_fresh
        slab = 0
        if self.state_store is not None:
            slab = self.state_store.admit(req.rid)
            # the slab's previous state is zeroed by the model's first
            # step for this slot (lengths == 0 blanking)
            self.state_store.mark_reset(slab)
        return ("fresh", free.pop(0), req, blocks, needed - n_fresh,
                matched, digests, slab)

    def _build_fresh_slot(self, join, mid_decode: bool) -> "_PagedSlot":
        _, slot_i, req, blocks, reserve, matched, digests, slab = join
        if mid_decode:
            self.n_joins += 1
        if matched:
            self.n_prefix_hits += 1
            self.n_shared_tokens += matched
        slot = _PagedSlot(req, blocks, reserve, prefill_off=matched,
                          digests=list(digests))
        self._page_table[slot_i, :] = 0
        self._page_table[slot_i, :len(blocks)] = blocks
        self._lengths[slot_i] = matched
        self._state_slots[slot_i] = slab
        return slot

    def _restore_fit(self, req: SchedRequest, free: List[int]):
        """Try to take resources to re-admit a preempted request.  No
        prefix-share discount: every page is acquired private and the
        spilled KV/state is scattered back, so the restored slot is
        bit-identical to never having been preempted."""
        plen = req.prompt.shape[0]
        total = self.allocator.blocks_for(
            min(plen + self.max_new_tokens, self.capacity))
        if total > self.allocator.n_free - self._reserved:
            return None
        if self.state_store is not None and self.state_store.n_free == 0:
            return None
        n_now = self.allocator.blocks_for(max(req.length, 1))
        blocks = self.allocator.acquire(n_now)
        self._reserved += total - n_now
        slab = 0
        if self.state_store is not None:
            slab = self.state_store.admit(req.rid)
            self.state_store.mark_reset(slab)   # scatter overwrites it
        return ("restore", free.pop(0), req, blocks, total - n_now, slab)

    def _build_restore_slot(self, join) -> "_PagedSlot":
        """Scatter a preempted request's spilled pages/slab into its new
        physical homes and rebuild the slot mid-sequence.  Attention
        reads go through the page table and sampling keys are a pure
        function of (request, step), so decode resumes bit-identically
        regardless of where the pages landed."""
        _, slot_i, req, blocks, reserve, slab = join
        self._ensure_paged_cache()
        if req.spill is not None:
            spill = req.spill["target"] if self._spec else req.spill
            self._paged_cache = self._scatter_pages(
                self._paged_cache, spill,
                jnp.asarray(blocks, jnp.int32), jnp.int32(slab))
            if self._spec:
                self._draft_cache = self._scatter_draft(
                    self._draft_cache, req.spill["draft"],
                    jnp.asarray(blocks, jnp.int32), jnp.int32(0))
        slot = _PagedSlot(req, blocks, reserve,
                          prefill_off=len(req.prompt),
                          digests=list(req.digests))
        slot.tokens = list(req.tokens)
        if self._spec and req.spec is not None:
            slot.spec_rounds = int(req.spec["rounds"])
            slot.spec_deficit = int(req.spec["deficit"])
            slot.spec_prev = int(req.spec["prev"])
        self._page_table[slot_i, :] = 0
        self._page_table[slot_i, :len(blocks)] = blocks
        self._lengths[slot_i] = req.length
        self._state_slots[slot_i] = slab
        self.n_restores += 1
        if self.n_active > 0:
            self.n_joins += 1
        return slot

    def _paged_cache_shardings(self):
        """NamedSharding pytree for the paged pool (mesh mode only):
        block/slot axes replicated, feature dims on "model"."""
        from jax.sharding import NamedSharding
        from ..models.sharding import paged_cache_specs
        kw = self._paged_cache_kwargs()
        struct = jax.eval_shape(
            lambda: self.model.init_paged_cache(
                self.allocator.num_blocks, self.block_size,
                dtype=self.cache_dtype, **kw))
        axis_sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        specs = paged_cache_specs(struct, axis_sizes=axis_sizes)
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs)

    def _paged_cache_kwargs(self):
        """Keyword args for ``model.init_paged_cache`` beyond the block
        geometry: state-slab provisioning, and the int8 switch."""
        kw = {"num_state_slots": self.num_state_slots} \
            if self.state_store is not None else {}
        if self._quant:
            kw["kv_dtype"] = "int8"
        return kw

    def _ensure_paged_cache(self) -> None:
        if self._paged_cache is None:
            kw = self._paged_cache_kwargs()
            shardings = None
            if self.mesh is not None:
                shardings = self._paged_cache_shardings()
                sig = inspect.signature(self.model.init_paged_cache)
                if "shardings" in sig.parameters:
                    kw["shardings"], shardings = shardings, None
            cache = self.model.init_paged_cache(
                self.allocator.num_blocks, self.block_size,
                dtype=self.cache_dtype, **kw)
            if shardings is not None:   # model without creation-time placement
                cache = jax.device_put(cache, shardings)
            self._paged_cache = cache
        if self._spec and self._draft_cache is None:
            # the draft pool shadows the target pool one-to-one: same
            # block count / block size / page tables, draft-model dims
            self._draft_cache = self.draft_model.init_paged_cache(
                self.allocator.num_blocks, self.block_size,
                dtype=self.cache_dtype)

    # -- preemption ---------------------------------------------------------
    def preempt(self, rid: int) -> bool:
        """Spill the slot serving ``rid`` to host memory and re-queue it
        at the front of its lane (operator / test hook; the scheduler
        calls the same path automatically for blocked interactive
        work).  Returns False if ``rid`` is not in a slot."""
        if not self.paged:
            raise ValueError("preemption requires paged mode")
        for i, slot in enumerate(self._slots):
            if slot is not None and slot.rid == rid and not slot.done:
                with self._sharding_ctx():
                    self._preempt_slot(i)
                return True
        return False

    def _preempt_for_interactive(self) -> bool:
        """Spill the youngest running batch-lane slot (least cached work
        lost) to make room for a blocked interactive candidate."""
        victims = [(slot.adm_seq, i)
                   for i, slot in enumerate(self._slots)
                   if slot is not None and slot.lane == "batch"
                   and not slot.done
                   and (self._gather_pages is not None
                        or slot.prefill_off < len(slot.prompt)
                        or not slot.tokens)]
        if not victims:
            return False
        self._preempt_slot(max(victims)[1])
        return True

    def _preempt_slot(self, slot_i: int) -> None:
        """Evict slot ``slot_i`` mid-flight, keeping its work: decode
        slots get their used KV pages (and recurrent state slab)
        gathered to host memory for a bit-identical restore; a slot
        still mid-prefill (no token emitted yet) is simply restarted —
        re-prefilling is deterministic, so nothing observable is lost.
        The request re-enters the *front* of its lane."""
        slot = self._slots[slot_i]
        req = SchedRequest(rid=slot.rid, prompt=slot.prompt, lane=slot.lane,
                           deadline=slot.deadline, tag=slot.tag,
                           t_submit=slot.t_submit)
        if slot.tokens and slot.prefill_off >= len(slot.prompt):
            if self._gather_pages is None:
                raise RuntimeError(
                    f"{type(self.model).__name__} has recurrent state but "
                    "no gather_paged_pages/scatter_paged_pages: cannot "
                    "preempt a decoding slot")
            L = int(self._lengths[slot_i])
            n_pages = self.allocator.blocks_for(L)
            payload = self._gather_pages(
                self._paged_cache,
                jnp.asarray(slot.blocks[:n_pages], jnp.int32),
                jnp.int32(self._state_slots[slot_i]))
            if self._spec:
                # spill the draft pool's view of the same pages, plus
                # the spec mirrors, so restore resumes the identical
                # draft state and PRNG stream
                dpayload = self._gather_draft(
                    self._draft_cache,
                    jnp.asarray(slot.blocks[:n_pages], jnp.int32),
                    jnp.int32(0))
                req.spill = {"target": jax.device_get(payload),
                             "draft": jax.device_get(dpayload)}
                req.spec = {"rounds": slot.spec_rounds,
                            "deficit": slot.spec_deficit,
                            "prev": slot.spec_prev}
            else:
                req.spill = jax.device_get(payload)
            req.length = L
            req.tokens = list(slot.tokens)
            req.digests = list(slot.digests)
        self.allocator.release(slot.blocks)
        if self.state_store is not None:
            self.state_store.evict(slot.rid)
        self._reserved -= slot.reserve_left
        self._page_table[slot_i, :] = 0
        self._lengths[slot_i] = 0
        self._slots[slot_i] = None
        self._dev.mark_dirty()
        self.n_preemptions += 1
        with self._lock:
            self.scheduler.push(req, front=True)

    def _extend_blocks(self, slot_i: int, slot: _PagedSlot,
                       n_tokens: int) -> None:
        """Grow a slot's page list to cover ``n_tokens`` cached tokens,
        drawing on its admission-time reservation (never fails)."""
        need = -(-n_tokens // self.block_size)
        while len(slot.blocks) < need:
            assert slot.reserve_left > 0, "reservation under-counted"
            (bid,) = self.allocator.acquire(1)
            slot.blocks.append(bid)
            slot.reserve_left -= 1
            self._reserved -= 1
            self._page_table[slot_i, len(slot.blocks) - 1] = bid
            self._dev.mark_dirty()

    def _cow_write_range(self, slot_i: int, slot: _PagedSlot, start: int,
                         n_new: int) -> None:
        """Copy-on-write: fork every *shared* block in the page range
        the coming ``paged_scatter`` will touch, so the write can never
        leak into another slot's view of the pool."""
        bs = self.block_size
        first = start // bs
        last = (start + n_new - 1) // bs
        for p in range(first, min(last + 1, len(slot.blocks))):
            # fork if shared — or still registered: a resurrected block
            # can be held at refcount 1, but the content table still
            # advertises its KV, so writing in place would corrupt what
            # future joiners map
            if self.allocator.ref(slot.blocks[p]) > 1 \
                    or self.allocator.is_registered(slot.blocks[p]):
                self._fork_block(slot_i, slot, p)

    def _fork_block(self, slot_i: int, slot: _PagedSlot, p: int) -> None:
        """Give the slot a private copy of page ``p``: acquire a block
        from the slot's reservation, copy the page's KV across every
        layer, swap the page-table entry, and drop our reference to the
        shared original (its other holders keep it alive)."""
        old = slot.blocks[p]
        assert slot.reserve_left > 0, "COW fork not covered by reservation"
        (new,) = self.allocator.acquire(1)
        slot.reserve_left -= 1
        self._reserved -= 1
        self._paged_cache = self._copy_block(self._paged_cache, old, new)
        self.allocator.release([old])
        slot.blocks[p] = new
        self._page_table[slot_i, p] = new
        self._dev.mark_dirty()
        self.n_cow_forks += 1

    def _seq_tokens(self, slot: _PagedSlot, start: int,
                    stop: int) -> Tuple[int, ...]:
        """Tokens at cache positions [start, stop): prompt, then the
        generated stream (token ``g`` was written at ``len(prompt)+g``)."""
        L = len(slot.prompt)
        return tuple(int(slot.prompt[p]) if p < L
                     else int(slot.tokens[p - L])
                     for p in range(start, stop))

    def _register_full_pages(self, slot_i: int, slot: _PagedSlot) -> None:
        """Publish every newly completed page to the content table so
        later joiners can map it instead of re-prefilling."""
        bs = self.block_size
        length = int(self._lengths[slot_i])
        while (len(slot.digests) + 1) * bs <= length:
            p = len(slot.digests)
            toks = self._seq_tokens(slot, p * bs, (p + 1) * bs)
            parent = slot.digests[-1] if slot.digests else ROOT_DIGEST
            self.allocator.register(slot.blocks[p], parent, toks)
            slot.digests.append(chain_digest(parent, toks))

    def _evict_paged(self) -> List[GenerationResult]:
        out: List[GenerationResult] = []
        now = time.monotonic()
        for i, slot in enumerate(self._slots):
            if slot is None or not slot.done:
                continue
            res = self._make_result(slot, now)
            out.append(res)
            self._finish(res)
            # refcounted release: shared blocks stay resident (and
            # content-addressable) as long as any other slot maps them;
            # registered blocks at refcount 0 are *retained* — the next
            # identical prompt maps them instead of re-prefilling
            self.allocator.release(slot.blocks)
            if self.state_store is not None:
                self.state_store.evict(slot.rid)
            self._reserved -= slot.reserve_left
            self._page_table[i, :] = 0
            self._lengths[i] = 0
            self._slots[i] = None
            self._dev.mark_dirty()
            self.n_evictions += 1
        return out

    # -- cache splicing -----------------------------------------------------
    def _discover_batch_axes(self, seq_len: int):
        """Which axis of each cache leaf is the batch axis?  Compare
        cache shapes for batch B vs B+1 (eval_shape: no compilation)."""
        def shapes(batch):
            tokens = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
            return jax.eval_shape(self._prefill, self.params, tokens, None)[1]

        def axis(a, b):
            for i, (p, q) in enumerate(zip(a.shape, b.shape)):
                if p != q:
                    return i
            return -1  # leaf independent of batch
        return jax.tree.map(axis, shapes(self.batch_size),
                            shapes(self.batch_size + 1))

    def _splice_cache(self, live, fresh, slot_ids: List[int]):
        if self._batch_axes is None:
            self._batch_axes = self._discover_batch_axes(max(self._pos, 1))
        sel = jnp.asarray(slot_ids, jnp.int32)

        def merge(old, new, ax):
            if ax < 0:
                return old
            idx = [slice(None)] * old.ndim
            idx[ax] = sel
            return old.at[tuple(idx)].set(new[tuple(idx)])
        return jax.tree.map(merge, live, fresh, self._batch_axes)


def _generic_copy_paged_block(cache, src: int, dst: int):
    """Fallback COW copy for models without ``copy_paged_block``: every
    paged-cache leaf is a ``(num_blocks, block_size, ...)`` store,
    optionally stacked under a leading scan-over-layers axis, so the
    block axis is ``ndim - 4``."""
    def cp(leaf):
        idx = [slice(None)] * (leaf.ndim - 4)
        return leaf.at[tuple(idx + [dst])].set(leaf[tuple(idx + [src])])
    return jax.tree.map(cp, cache)


def _generic_gather_pages(cache, blocks, slab):
    """Fallback spill gather for attn-only models without
    ``gather_paged_pages`` (same block-axis convention as the COW
    fallback; ``slab`` is unused — recurrent stacks must implement the
    model-level protocol)."""
    del slab

    def take(leaf):
        idx = [slice(None)] * (leaf.ndim - 4)
        return leaf[tuple(idx + [blocks])]
    return jax.tree.map(take, cache)


def _generic_scatter_pages(cache, payload, blocks, slab):
    """Fallback spill scatter for attn-only models (inverse of
    ``_generic_gather_pages``)."""
    del slab

    def put(leaf, p):
        idx = [slice(None)] * (leaf.ndim - 4)
        return leaf.at[tuple(idx + [blocks])].set(p)
    return jax.tree.map(put, cache, payload)
