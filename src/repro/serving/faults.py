"""Fault injection for the serving stack (chaos harness).

A :class:`FaultPlan` is a declarative list of :class:`Fault` points the
serving seams consult at runtime: the network writer loop before each
socket send, the engine at the top of each step, the admission fit
check, and the pipeline worker after a batch's requests have entered
the engine.  Every seam is behind a no-op default (``plan=None`` or a
plan with no matching fault costs one ``None`` check), so production
paths pay nothing; the chaos suite (``tests/test_faults.py``) and the
chaos benchmark (``benchmarks/e11_chaos.py``) thread plans through
``ServeEngine(fault_plan=)`` / ``TensorQueryServer(fault_plan=)`` to
prove the stack degrades request-by-request instead of wedging.

Fault points (the ``point`` strings the seams fire):

``server_send``
    In ``QueryConnection``'s writer thread, per outbound frame.
    Actions: ``close`` (socket torn down mid-conversation), ``stall``
    (writer sleeps ``stall_s`` — a consumer that stopped reading),
    ``partial`` (``cut_at`` bytes of the frame hit the wire, then the
    socket dies — the client sees a desynced/truncated stream).
``engine_step``
    Top of ``ServeEngine.step()``.  Action ``raise`` throws ``exc`` —
    a *non-attributable* failure: the engine must spill survivors,
    restart its pools, and keep serving (bounded restarts).
``admit``
    Top of the per-request fit check.  Action ``raise`` with
    ``CacheFullError`` simulates an allocator storm: the candidate
    stays queued (never failed) until the storm passes.
``worker``
    In the pipeline filter, after a batch's rows were submitted to the
    engine.  Action ``raise`` kills that worker's batch — request-level
    isolation must fail exactly those rows with ERROR frames and free
    their pool resources.
``submit``
    In the pipeline filter, per row, before ``engine.submit`` — a
    malformed/poison request; only that row may fail.

Counting: each ``Fault`` fires on its ``nth`` arrival at its point
(1-based) and keeps firing for ``times`` consecutive arrivals; with
``every=k`` it instead fires on every k-th arrival forever (rate-style
injection for the chaos benchmark).  Counters are per (plan, point)
and thread-safe.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence

__all__ = ["Fault", "FaultPlan"]


@dataclasses.dataclass
class Fault:
    """One injectable fault: where, when, and what happens."""
    point: str                       # seam name (see module docstring)
    nth: int = 1                     # fire on the nth arrival (1-based)
    times: int = 1                   # consecutive arrivals that fire
    every: int = 0                   # alternative: fire on every k-th arrival
    action: str = "raise"            # "raise" | "close" | "stall" | "partial"
    exc: type = RuntimeError         # exception type for action="raise"
    msg: str = "injected fault"      # exception message
    stall_s: float = 0.0             # action="stall": writer sleep
    cut_at: int = 4                  # action="partial": bytes sent before cut

    def hits(self, n: int) -> bool:
        """Does this fault fire on the ``n``-th arrival at its point?"""
        if self.every > 0:
            return n % self.every == 0
        return self.nth <= n < self.nth + self.times

    def make_exc(self) -> BaseException:
        return self.exc(self.msg)


class FaultPlan:
    """Thread-safe fault schedule consulted by the serving seams.

    ``fire(point)`` bumps the point's arrival counter and returns the
    matching :class:`Fault` (or None).  Seams interpret the returned
    action themselves — the plan never raises, so a seam can honour
    only the actions that make sense for it.  ``n_fired`` counts the
    faults actually delivered (for benchmark reporting)."""

    def __init__(self, faults: Sequence[Fault] = ()):
        self.faults: List[Fault] = list(faults)
        self._counts: Dict[str, int] = {}
        self._lock = threading.Lock()
        self.n_fired = 0

    def add(self, fault: Fault) -> "FaultPlan":
        self.faults.append(fault)
        return self

    def fire(self, point: str) -> Optional[Fault]:
        with self._lock:
            n = self._counts.get(point, 0) + 1
            self._counts[point] = n
            for f in self.faults:
                if f.point == point and f.hits(n):
                    self.n_fired += 1
                    return f
        return None

    def arrivals(self, point: str) -> int:
        with self._lock:
            return self._counts.get(point, 0)

    def reset(self) -> None:
        with self._lock:
            self._counts.clear()
            self.n_fired = 0

    def __repr__(self) -> str:
        return f"FaultPlan({self.faults!r})"


def fire(plan: Optional[FaultPlan], point: str) -> Optional[Fault]:
    """No-op-safe firing helper: seams call this with a possibly-None
    plan so the production path is a single ``is None`` check."""
    if plan is None:
        return None
    return plan.fire(point)
