"""Block-paged KV cache: allocator + pure-jnp page table primitives.

The serving engine's dense cache gave every slot a contiguous
``(capacity, ...)`` strip, so admission cost one full-position prefill
and memory scaled as ``batch_size * capacity`` even when most slots
held short sequences.  The paged layout (cf. vLLM / the PIE backend)
instead carves one shared pool of ``num_blocks`` fixed-size blocks:

  * ``BlockAllocator`` — host-side free list.  Slots allocate blocks
    for their prompt at admission, extend one block at a time as decode
    crosses a block boundary, and free everything on eviction.  A
    request that does not fit raises ``CacheFullError`` (the engine
    catches the *admission* case and leaves the request queued).
  * ``paged_scatter`` / ``paged_gather`` — jit-friendly primitives
    mapping logical token positions to physical block rows through a
    per-slot page table.  They live with the attention math in
    ``models/attention.py`` (the models layer must not depend on
    serving) and are re-exported here as the cache-layout API.

Layout convention: storage is ``(num_blocks, block_size, ...)``; a page
table row ``page_table[b]`` lists the physical block of each logical
page of slot ``b`` (unused entries may hold any valid block id — reads
beyond a slot's true length are masked by the attention kernel, so
stale pointers are harmless).  Logical position ``l`` of slot ``b``
lives at flat row ``page_table[b, l // block_size] * block_size +
l % block_size``.
"""
from __future__ import annotations

import collections
from typing import Iterable, List

from ..models.attention import paged_gather, paged_scatter  # noqa: F401

__all__ = ["BlockAllocator", "CacheFullError", "paged_gather",
           "paged_scatter"]


class CacheFullError(RuntimeError):
    """Raised by ``BlockAllocator.alloc`` when the pool cannot satisfy
    the request.  The allocator state is unchanged (all-or-nothing)."""


class BlockAllocator:
    """Free-list allocator over a pool of fixed-size KV blocks."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        # FIFO reuse keeps physical placement deterministic for tests
        self._free: collections.deque = collections.deque(range(num_blocks))
        self._live: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return len(self._live)

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` (at least one)."""
        return max(1, -(-int(n_tokens) // self.block_size))

    def alloc(self, n: int = 1) -> List[int]:
        """Take ``n`` blocks off the free list (all-or-nothing)."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            raise CacheFullError(
                f"need {n} blocks, only {len(self._free)}/{self.num_blocks} free")
        out = [self._free.popleft() for _ in range(n)]
        self._live.update(out)
        return out

    def free(self, blocks: Iterable[int]) -> None:
        """Return blocks to the pool; double/foreign frees raise."""
        for b in blocks:
            if b not in self._live:
                raise ValueError(f"block {b} is not allocated (double free?)")
            self._live.remove(b)
            self._free.append(b)
