"""Block-paged KV cache: refcounted allocator + pure-jnp page primitives.

The serving engine's dense cache gave every slot a contiguous
``(capacity, ...)`` strip, so admission cost one full-position prefill
and memory scaled as ``batch_size * capacity`` even when most slots
held short sequences.  The paged layout (cf. vLLM / the PIE backend)
instead carves one shared pool of ``num_blocks`` fixed-size blocks:

  * ``BlockAllocator`` — host-side free list with **per-block
    refcounts** and a **content-hash table** over full blocks.  Slots
    ``acquire`` private blocks, ``share`` already-resident ones
    (refcount + 1), and ``release`` everything on eviction; a block
    returns to the free list only when its refcount reaches zero.  The
    content table maps ``(parent chain digest, block tokens)`` to the
    physical block holding that prefix's KV, which is what lets
    ``ServeEngine`` map a joiner's common prompt prefix straight into
    its page table instead of re-prefilling it.  A *registered* block
    whose refcount drops to zero is **retained**: it stays in the
    content table on an LRU free list (its KV is still resident and
    valid — nothing has written over it), so an identical prompt
    arriving right after its twin finished maps the whole prefix
    instead of re-prefilling from scratch.  ``share`` resurrects such a
    block off the free list; ``acquire`` recycles retained blocks
    (oldest first, unregistering at that moment) only after the plain
    free list is exhausted — a table hit therefore always points at
    valid KV.
  * ``paged_scatter`` / ``paged_gather`` — jit-friendly primitives
    mapping logical token positions to physical block rows through a
    per-slot page table.  They live with the attention math in
    ``models/attention.py`` (the models layer must not depend on
    serving) and are re-exported here as the cache-layout API.

Layout convention: storage is ``(num_blocks, block_size, ...)``; a page
table row ``page_table[b]`` lists the physical block of each logical
page of slot ``b`` (unused entries may hold any valid block id — reads
beyond a slot's true length are masked by the attention kernel, so
stale pointers are harmless).  Logical position ``l`` of slot ``b``
lives at flat row ``page_table[b, l // block_size] * block_size +
l % block_size``.

Content addressing uses *chain digests*: the key of block ``p`` in a
sequence is ``sha256(digest(p-1) || tokens of page p)`` with a fixed
root digest, so a match on page ``p`` certifies the entire token prefix
``0 .. (p+1)*block_size`` — not just the page's own tokens.  Sharing a
matched chain is therefore exact, never probabilistic-by-suffix.
"""
from __future__ import annotations

import collections
import hashlib
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, \
    Tuple

import numpy as np

from ..models.attention import paged_gather, paged_scatter  # noqa: F401

__all__ = ["BlockAllocator", "CacheFullError", "DeviceSlotState",
           "ROOT_DIGEST", "SPEC_STATE_KEYS", "StateStore", "chain_digest",
           "paged_gather", "paged_scatter"]

# Chain root: the digest "before" a sequence's first page.
ROOT_DIGEST = hashlib.sha256(b"repro.kv_cache.root").digest()


def chain_digest(parent: bytes, tokens: Sequence[int]) -> bytes:
    """Digest of a token chain extended by one full page of tokens."""
    h = hashlib.sha256(parent)
    h.update(np.asarray(tokens, np.int64).tobytes())
    return h.digest()


class CacheFullError(RuntimeError):
    """Raised by ``BlockAllocator.acquire`` when the pool cannot satisfy
    the request.  The allocator state is unchanged (all-or-nothing)."""


# Slot-state keys that exist only when speculative decoding is enabled
# (see ``steps.make_paged_spec_burst``).  They ride the same
# ``DeviceSlotState`` coherence protocol as the core keys: rebuilt from
# the host mirror on structural events, mutated in-jit otherwise.  The
# draft model's KV cache itself needs *no* extra bookkeeping here — it
# is a second cache pytree indexed by the **same** page tables, lengths
# and block allocator as the target cache (one logical position maps to
# one physical block id in both pools), so admission reservation,
# extension, COW-free sharing gates and eviction all apply to the pair
# atomically.
SPEC_STATE_KEYS = ("spec_rounds", "spec_deficit", "spec_prev")


class DeviceSlotState:
    """Device-resident mirror of the engine's per-slot decode state.

    The serving engine keeps two views of its slot arrays (page tables,
    lengths, last tokens, sampling counters, done flags):

      * **host mirror** — numpy arrays plus slot bookkeeping, mutated at
        *structural* events only (admission, eviction, block extension,
        COW fork);
      * **device view** — a dict of jax arrays mutated exclusively
        *in-jit* by the fused megastep/burst functions, donated through
        every call.

    This class owns the device view and the coherence protocol between
    the two.  ``mark_dirty`` records a structural host mutation; the
    next ``device(build)`` rebuilds the view from the host (one upload)
    and clears the flag.  While clean, ``device`` returns the arrays
    adopted from the last in-jit update (``adopt``) — **zero uploads on
    the steady decode path**, which is what removes the per-token
    ``jnp.asarray(page_table/lengths/...)`` re-upload the per-step host
    loop paid.  ``n_uploads`` counts rebuilds so benchmarks and tests
    can pin the no-re-upload property.

    Speculative serving adds the ``SPEC_STATE_KEYS`` entries
    (``spec_rounds`` / ``spec_deficit`` / ``spec_prev``) to the same
    dict: they follow the identical dirty/adopt/rebuild protocol, so
    draft-cache coherence costs no extra uploads.
    """

    def __init__(self, put: Optional[Callable[[np.ndarray], "object"]] = None):
        self._dev: Optional[Dict[str, "object"]] = None
        self._dirty = True
        self.n_uploads = 0
        # placement hook for rebuilds: host array -> device array.  The
        # engine overrides it under a mesh so every slot array lands
        # *replicated* (page tables / lengths / tokens are global control
        # state — each device must see all of them).  Defaults to a plain
        # single-device upload.
        self.put = put

    def _upload(self, v: np.ndarray):
        if self.put is not None:
            return self.put(v)
        import jax.numpy as jnp
        return jnp.asarray(v)

    @property
    def dirty(self) -> bool:
        return self._dirty

    def mark_dirty(self) -> None:
        """Host mirror changed structurally: the device view is stale."""
        self._dirty = True

    def adopt(self, dev: Dict[str, "object"]) -> None:
        """Adopt the state dict returned by an in-jit mutation as the
        current device view (the previous view's buffers were donated
        into that call and are dead)."""
        self._dev = dev

    def device(self, build: Callable[[], Dict[str, np.ndarray]]):
        """Current device view; rebuilds from ``build()`` iff dirty."""
        if self._dirty or self._dev is None:
            self._dev = {k: self._upload(v) for k, v in build().items()}
            self._dirty = False
            self.n_uploads += 1
        return self._dev


class StateStore:
    """Fixed-capacity pool of recurrent-state slabs, keyed by request.

    Recurrent layers (mamba conv/ssm, xLSTM matrix/scalar memory) carry
    constant-size per-sequence state that page tables cannot address: a
    slab is a running summary of the *entire* prefix, so — unlike KV
    pages — it can never be shared between slots or grown lazily.  The
    store therefore mirrors only ``BlockAllocator``'s *lifecycle*
    semantics, not its refcounting: ``admit`` hands a request exclusive
    ownership of one slab (all-or-nothing — a full store raises
    ``CacheFullError`` with the store unchanged, so the engine keeps the
    request queued), ``evict`` frees the slab on eos.

    The device arrays live in the model's paged cache (leading
    ``num_slots`` axis per recurrent layer leaf); this class is the
    host-side source of truth for who owns which slab and which slabs
    still hold a *previous* occupant's state.  A recycled slab is
    ``stale`` until its new owner's first step zeroes it (the model's
    paged step blanks rows whose ``lengths == 0``); the engine marks
    that handoff via ``mark_reset`` at admission.  The property suite
    checks exactly these invariants: no slab is ever owned twice, no
    slab leaks, and stale state is never handed to a new owner
    unreset.
    """

    def __init__(self, num_slots: int):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.num_slots = int(num_slots)
        # FIFO reuse keeps slab placement deterministic for tests
        self._free: collections.deque = collections.deque(range(num_slots))
        self._slab_of: Dict[int, int] = {}       # request id -> slab
        self._owner: Dict[int, int] = {}         # slab -> request id
        self._stale: Set[int] = set()            # freed slabs, state resident

    # -- occupancy ----------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return len(self._owner)

    def slab_of(self, rid: int) -> Optional[int]:
        """Slab owned by request ``rid`` (None if not admitted)."""
        return self._slab_of.get(rid)

    def owner_of(self, slab: int) -> Optional[int]:
        """Request owning ``slab`` (None if free)."""
        return self._owner.get(slab)

    def is_stale(self, slab: int) -> bool:
        """True while a previous occupant's state is still resident."""
        return slab in self._stale

    def stats(self) -> Dict[str, int]:
        return {"num_slots": self.num_slots, "n_free": self.n_free,
                "n_live": self.n_live}

    # -- lifecycle ----------------------------------------------------------
    def admit(self, rid: int) -> int:
        """Give request ``rid`` exclusive ownership of one slab,
        all-or-nothing."""
        if rid in self._slab_of:
            raise ValueError(f"request {rid} already holds slab "
                             f"{self._slab_of[rid]}")
        if not self._free:
            raise CacheFullError(
                f"no state slab free (0/{self.num_slots}) for request {rid}")
        slab = self._free.popleft()
        self._slab_of[rid] = slab
        self._owner[slab] = rid
        return slab

    def mark_reset(self, slab: int) -> None:
        """Record that ``slab``'s resident state has been (or is about
        to be, on the owner's first step) zeroed for its new owner."""
        if slab not in self._owner:
            raise ValueError(f"cannot reset free slab {slab}")
        self._stale.discard(slab)

    def evict(self, rid: int) -> int:
        """Free request ``rid``'s slab (eos / truncation).  The slab
        returns to the pool but keeps the evictee's state until the next
        owner resets it — hence it becomes ``stale``."""
        slab = self._slab_of.pop(rid, None)
        if slab is None:
            raise ValueError(
                f"request {rid} holds no state slab (double evict?)")
        del self._owner[slab]
        self._stale.add(slab)
        self._free.append(slab)
        return slab


class BlockAllocator:
    """Refcounted free-list allocator with a full-block content table.

    ``retain_cap`` bounds how many refcount-0 registered blocks stay
    parked on the retained (prefix-reuse) list; beyond it the oldest are
    retired to the plain free list and unregistered, so retention can
    never crowd the content table with stale chains under churn.
    ``retain_ttl_s`` optionally expires retained blocks by age (time
    since their last reference dropped), swept at every allocator
    mutation.  Neither affects ``n_free``: retained blocks were already
    reusable — the cap/TTL only bound how long their *content* stays
    addressable.
    """

    def __init__(self, num_blocks: int, block_size: int, *,
                 retain_cap: Optional[int] = None,
                 retain_ttl_s: Optional[float] = None,
                 clock: Optional[Callable[[], float]] = None):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if retain_cap is not None and retain_cap < 0:
            raise ValueError(f"retain_cap must be >= 0, got {retain_cap}")
        if retain_ttl_s is not None and retain_ttl_s <= 0:
            raise ValueError(f"retain_ttl_s must be > 0, got {retain_ttl_s}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.retain_cap = None if retain_cap is None else int(retain_cap)
        self.retain_ttl_s = retain_ttl_s
        self._clock = clock if clock is not None else time.monotonic
        self.n_retain_evictions = 0
        # FIFO reuse keeps physical placement deterministic for tests
        self._free: collections.deque = collections.deque(range(num_blocks))
        # retained: registered blocks at refcount 0, LRU order (dicts
        # preserve insertion order; oldest entry is recycled first),
        # valued by the time their last reference dropped (TTL sweeps)
        self._retained: Dict[int, float] = {}
        self._ref: Dict[int, int] = {}
        # content table: parent digest -> {page tokens -> block id}, plus
        # the reverse index used to unregister a block when it is recycled
        self._table: Dict[bytes, Dict[Tuple[int, ...], int]] = {}
        self._key_of: Dict[int, Tuple[bytes, Tuple[int, ...]]] = {}
        # bumped whenever the content table changes (register/unregister):
        # prefix matches memoized against an unchanged epoch stay valid
        self.epoch = 0

    # -- occupancy ----------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free) + len(self._retained)

    @property
    def n_retained(self) -> int:
        """Free blocks still addressable through the content table."""
        return len(self._retained)

    @property
    def n_live(self) -> int:
        return len(self._ref)

    @property
    def n_shared(self) -> int:
        """Live blocks referenced by more than one slot."""
        return sum(1 for r in self._ref.values() if r > 1)

    @property
    def n_table(self) -> int:
        """Content-table entries (always <= n_live)."""
        return len(self._key_of)

    def ref(self, block: int) -> int:
        """Current refcount of ``block`` (0 if free)."""
        return self._ref.get(block, 0)

    def registered_blocks(self) -> Set[int]:
        """Blocks currently addressable through the content table."""
        return set(self._key_of)

    def retained_blocks(self) -> Set[int]:
        """Registered blocks at refcount 0 (on the LRU retained list)."""
        return set(self._retained)

    def is_registered(self, block: int) -> bool:
        """True while ``block`` is addressable through the content
        table.  A writer must COW-fork such a block even at refcount 1
        (post-resurrection): overwriting it would silently corrupt the
        KV the table still advertises."""
        return block in self._key_of

    def stats(self) -> Dict[str, int]:
        shared = self.n_shared
        return {"num_blocks": self.num_blocks, "n_free": self.n_free,
                "n_live": self.n_live, "n_shared": shared,
                "n_private": self.n_live - shared, "n_table": self.n_table,
                "n_retained": self.n_retained}

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` (at least one)."""
        return max(1, -(-int(n_tokens) // self.block_size))

    # -- lifecycle ----------------------------------------------------------
    def acquire(self, n: int = 1) -> List[int]:
        """Take ``n`` private blocks (refcount 1) off the free list,
        all-or-nothing.  Plain (unregistered) free blocks are handed out
        first; retained blocks are recycled oldest-first and leave the
        content table only at that moment."""
        if n < 0:
            raise ValueError(f"cannot acquire {n} blocks")
        self._sweep_ttl()
        if n > self.n_free:
            raise CacheFullError(
                f"need {n} blocks, only {self.n_free}/{self.num_blocks} free")
        out: List[int] = []
        while len(out) < n and self._free:
            out.append(self._free.popleft())
        while len(out) < n:
            b = next(iter(self._retained))     # LRU: oldest insertion
            del self._retained[b]
            self._unregister(b)
            out.append(b)
        for b in out:
            self._ref[b] = 1
        return out

    def share(self, blocks: Iterable[int]) -> None:
        """Add a reference to already-live blocks (prefix sharing).  A
        *retained* block (registered, refcount 0) is resurrected off the
        free list with refcount 1 — this is the post-eviction prefix-hit
        path.  Sharing an unregistered free block raises."""
        blocks = list(blocks)
        for b in blocks:
            if b not in self._ref and b not in self._retained:
                raise ValueError(f"cannot share free block {b}")
        for b in blocks:
            if b in self._ref:
                self._ref[b] += 1
            else:
                del self._retained[b]
                self._ref[b] = 1

    def release(self, blocks: Iterable[int]) -> None:
        """Drop one reference per block; a block returns to a free list
        only at refcount zero — the LRU retained list if it is in the
        content table (its KV stays addressable for future prefix hits),
        the plain free list otherwise.  Releasing a free/foreign block
        raises."""
        for b in blocks:
            r = self._ref.get(b, 0)
            if r <= 0:
                raise ValueError(f"block {b} is not allocated (double free?)")
            if r == 1:
                del self._ref[b]
                if b in self._key_of:
                    self._retained[b] = self._clock()
                    self._trim_retained()
                else:
                    self._free.append(b)
            else:
                self._ref[b] = r - 1
        self._sweep_ttl()

    def sweep(self) -> int:
        """Expire retained blocks whose TTL has lapsed, *now*.

        ``_sweep_ttl`` only runs inside ``acquire``/``release``, so an
        idle server — no admissions, no completions — would pin expired
        prefix blocks and their content-table entries forever.  The
        engine calls this from ``step()``'s periodic path so wall-clock
        expiry happens even when no allocation traffic does.  Returns
        the number of blocks retired by this call.
        """
        before = self.n_retain_evictions
        self._sweep_ttl()
        return self.n_retain_evictions - before

    def retire(self, block: int) -> bool:
        """Retire one *specific* retained block: drop its content-table
        entry and move it to the plain free list.  Used when a block's
        resident KV is known to be garbage (e.g. its request was
        cancelled mid-page or the pool was rebuilt) so a future prefix
        hit can never map stale content.  Returns True if the block was
        retained (and is now plain-free); no-op False otherwise."""
        if block not in self._retained:
            return False
        del self._retained[block]
        self._unregister(block)
        self._free.append(block)
        self.n_retain_evictions += 1
        return True

    def clear_registry(self) -> None:
        """Forget every content-table entry and retire all retained
        blocks to the plain free list.  Called on engine restart: the
        device pool was reinitialised, so every registered block now
        advertises KV that no longer exists."""
        while self._retained:
            self._retire_oldest_retained()
        # live blocks may also be registered; their entries are equally
        # stale after a pool rebuild
        for b in list(self._key_of):
            self._unregister(b)

    def _retire_oldest_retained(self) -> None:
        """Move the oldest retained block to the plain free list and
        drop its content-table entry (it is no longer addressable)."""
        b = next(iter(self._retained))
        del self._retained[b]
        self._unregister(b)
        self._free.append(b)
        self.n_retain_evictions += 1

    def _trim_retained(self) -> None:
        if self.retain_cap is None:
            return
        while len(self._retained) > self.retain_cap:
            self._retire_oldest_retained()

    def _sweep_ttl(self) -> None:
        if self.retain_ttl_s is None or not self._retained:
            return
        now = self._clock()
        while self._retained:
            b = next(iter(self._retained))     # oldest retire time first
            if now - self._retained[b] < self.retain_ttl_s:
                break
            self._retire_oldest_retained()

    # -- content addressing -------------------------------------------------
    def register(self, block: int, parent: bytes,
                 tokens: Sequence[int]) -> None:
        """Publish a *full* block as the KV of chain ``parent`` extended
        by ``tokens``.  First writer wins: re-registering the same chain
        (e.g. a COW fork re-completing a page) is a no-op, so a table
        entry always points at the block that originally computed it."""
        if block not in self._ref:
            raise ValueError(f"cannot register free block {block}")
        if len(tokens) != self.block_size:
            raise ValueError(
                f"only full blocks are addressable: got {len(tokens)} tokens, "
                f"block_size={self.block_size}")
        if block in self._key_of:
            return
        kids = self._table.setdefault(parent, {})
        key = tuple(int(t) for t in tokens)
        if key in kids:
            return                      # identical content already resident
        kids[key] = block
        self._key_of[block] = (parent, key)
        self.epoch += 1

    def lookup(self, parent: bytes,
               tokens: Sequence[int]) -> Optional[int]:
        """Block holding exactly chain ``parent`` + full page ``tokens``."""
        return self._table.get(parent, {}).get(tuple(int(t) for t in tokens))

    def lookup_tail(self, parent: bytes,
                    prefix: Sequence[int]) -> Optional[int]:
        """A resident full block whose page *starts with* ``prefix``
        under chain ``parent`` — lets a joiner map its final partial
        page onto another sequence's completed block (rows past the
        joiner's length are masked by attention, so the stranger's
        suffix in the same block is never read)."""
        prefix = tuple(int(t) for t in prefix)
        if not prefix or len(prefix) >= self.block_size:
            return None
        for key, block in self._table.get(parent, {}).items():
            if key[:len(prefix)] == prefix:
                return block
        return None

    def _unregister(self, block: int) -> None:
        key = self._key_of.pop(block, None)
        if key is None:
            return
        parent, tokens = key
        kids = self._table.get(parent)
        if kids is not None and kids.get(tokens) == block:
            del kids[tokens]
            if not kids:
                del self._table[parent]
        self.epoch += 1
