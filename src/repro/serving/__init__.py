from .engine import ServeEngine, GenerationResult
from .kv_cache import (BlockAllocator, CacheFullError, paged_gather,
                       paged_scatter)
from .steps import make_prefill_step, make_decode_step

__all__ = ["ServeEngine", "GenerationResult", "BlockAllocator",
           "CacheFullError", "paged_gather", "paged_scatter",
           "make_prefill_step", "make_decode_step"]
