from .engine import ServeEngine, GenerationResult
from .faults import Fault, FaultPlan
from .kv_cache import (BlockAllocator, CacheFullError, DeviceSlotState,
                       ROOT_DIGEST, SPEC_STATE_KEYS, StateStore, chain_digest,
                       paged_gather, paged_scatter)
from .net import TensorQueryClient, TensorQueryServer
from .scheduler import LANES, SchedRequest, Scheduler
from .steps import (logits_to_probs, make_prefill_step, make_decode_step,
                    make_dense_burst, make_paged_burst, make_paged_mixed_step,
                    make_paged_spec_burst, make_paged_spec_mixed_step,
                    make_sampler_core, make_slot_sampler, sample_logits,
                    spec_accept)

__all__ = ["ServeEngine", "GenerationResult", "Fault", "FaultPlan",
           "BlockAllocator",
           "CacheFullError", "DeviceSlotState", "ROOT_DIGEST",
           "SPEC_STATE_KEYS", "StateStore",
           "chain_digest", "paged_gather", "paged_scatter",
           "LANES", "SchedRequest", "Scheduler",
           "TensorQueryClient", "TensorQueryServer",
           "logits_to_probs", "make_prefill_step", "make_decode_step",
           "make_dense_burst", "make_paged_burst", "make_paged_mixed_step",
           "make_paged_spec_burst", "make_paged_spec_mixed_step",
           "make_sampler_core", "make_slot_sampler", "sample_logits",
           "spec_accept"]
