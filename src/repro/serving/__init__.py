from .engine import ServeEngine, GenerationResult
from .steps import make_prefill_step, make_decode_step

__all__ = ["ServeEngine", "GenerationResult", "make_prefill_step",
           "make_decode_step"]
