"""Jit-able serving step functions (also used by the dry-run).

Sampling is one shared primitive, ``sample_logits``: greedy argmax when
``greedy`` (or ``temperature == 0``), otherwise temperature / top-k
categorical sampling with a **per-row PRNG key** ``(B, 2) uint32``.
Per-row keys are what make sampling reproducible across serving modes:
the engine derives slot ``b``'s key from its request id and decode step
only, so the same request draws the same tokens whether it is served by
the dense or the block-paged engine, in whatever batch composition.

The **megastep** builders fuse one whole engine tick into a single
jitted function: model step + sampler + token/length/step/done-flag
update, all operating on a dict of persistent device arrays the engine
never rebuilds from Python between steps (see ``DeviceSlotState`` in
``kv_cache.py``).  The *burst* variants run up to ``k_max`` fused
decode steps per host round-trip inside one ``lax.while_loop`` with an
all-done early-out, writing sampled tokens into a ``(k_static, B)``
ring buffer the host drains once per burst.  ``k_max`` is a *traced*
scalar, so one compilation serves every burst length — K = 1 and
K = 8 run the identical compiled loop body, which is what makes burst
output bit-identical to single-stepping by construction.

The megasteps are **cache-dtype agnostic**: the cache pytree is donated
and threaded opaquely through ``model.paged_step``, so the int8
block-quantized pool (``kv_dtype="int8"`` — int8 ``k``/``v`` leaves
plus f32 ``k_scale``/``v_scale`` scale pools riding the same dict)
serves through the identical compiled megasteps with no changes here;
quantize/dequantize live entirely inside the model's attention step.

Slot-state dict contract (all arrays device-resident, donated through
every megastep call):

  ``tokens (B,) int32``   last sampled token per slot (next decode input)
  ``rids (B,) int32``     request id per slot (sampling key derivation)
  ``steps (B,) int32``    tokens generated so far per slot
  ``active (B,) bool``    slot is decoding (not idle / prefilling / done)
  paged only:
  ``page_table (B,P)``    logical page -> physical block per slot
  ``lengths (B,) int32``  tokens cached per slot (true position)
  ``state_slots (B,)``    recurrent state slab per slot
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..models.sharding import constrain


def _replicated_logits(logits):
    """Under a mesh, gather the (B, V) logits replicated before the
    sampler: the model's head leaves them vocab-sharded on the model
    axis, and sampling on a full replica keeps every device's slot
    state bitwise in lockstep (it is also how production TP samplers
    work — the allgather is tiny next to a model step).  No-op without
    a mesh context."""
    return constrain(logits, None, None)


def make_prefill_step(model, capacity: int, cache_dtype=jnp.bfloat16):
    def prefill_step(params, tokens, extra_embeds=None):
        return model.prefill(params, tokens, capacity=capacity,
                             extra_embeds=extra_embeds,
                             cache_dtype=cache_dtype)
    return prefill_step


def sample_logits(logits, rng=None, *, greedy: bool = True,
                  temperature: float = 1.0, top_k: Optional[int] = None):
    """logits (B, V), rng (B, 2) uint32 per-row keys -> tokens (B,) int32.

    ``greedy`` or ``temperature == 0`` is exact argmax (no rng needed);
    otherwise each row is drawn from ``softmax(logits / temperature)``
    restricted to its ``top_k`` highest logits (ties at the k-th value
    are kept).  Rows are sampled with *independent* keys so one row's
    draw never depends on the batch around it.
    """
    if greedy or temperature == 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if rng is None:
        raise ValueError("sampling (greedy=False, temperature>0) needs rng")
    l = logits.astype(jnp.float32) / jnp.float32(temperature)
    if top_k is not None and 0 < top_k < l.shape[-1]:
        kth = jax.lax.top_k(l, top_k)[0][..., -1:]
        l = jnp.where(l < kth, -jnp.inf, l)
    draw = lambda key, row: jax.random.categorical(key, row)
    return jax.vmap(draw)(rng, l).astype(jnp.int32)


def make_sampler_core(seed: int = 0, *, greedy: bool = True,
                      temperature: float = 1.0,
                      top_k: Optional[int] = None):
    """Traceable ``(logits, rids, steps) -> tokens`` — the sampler the
    megasteps inline.  Row ``b``'s key — ``fold_in(fold_in(
    PRNGKey(seed), rids[b]), steps[b])`` — is derived *inside* the
    caller's jit, so the hot decode loop ships two small int32 vectors
    instead of doing per-slot ``fold_in`` dispatches and device->host
    key syncs each token.  Greedy (= temperature 0) is the same
    function with the rng path compiled out."""
    if greedy:
        return lambda logits, rids, steps: \
            jnp.argmax(logits, axis=-1).astype(jnp.int32)
    base = jax.random.PRNGKey(seed)

    def sample(logits, rids, steps):
        fold = lambda r, t: jax.random.fold_in(jax.random.fold_in(base, r), t)
        keys = jax.vmap(fold)(rids, steps)
        return sample_logits(logits, keys, greedy=False,
                             temperature=temperature, top_k=top_k)
    return sample


def make_slot_sampler(seed: int = 0, *, greedy: bool = True,
                      temperature: float = 1.0,
                      top_k: Optional[int] = None):
    """Jitted standalone ``(logits, rids, steps) -> tokens`` (the
    engine's admission path; the decode loop samples inside the
    megastep instead).  Both serving modes draw through the same core,
    which is what makes paged and dense token streams match for the
    same seed."""
    return jax.jit(make_sampler_core(seed, greedy=greedy,
                                     temperature=temperature, top_k=top_k))


def make_decode_step(model, *, greedy: bool = True, temperature: float = 1.0,
                     top_k: Optional[int] = None):
    def decode_step(params, cache, token, pos, rng=None):
        """token: (B,1), rng: (B,2) per-row keys (ignored when greedy)
        -> (next_token (B,1), logits, cache)."""
        logits, cache = model.decode_step(params, cache, token, pos)
        nxt = sample_logits(logits, rng, greedy=greedy,
                            temperature=temperature, top_k=top_k)
        return nxt[:, None], logits, cache
    return decode_step


# ---------------------------------------------------------------------------
# fused megasteps: model step + sampler + slot-state update in one jit
# ---------------------------------------------------------------------------

def _advance(st, nxt, emit, t_valid, *, eos, max_new, capacity=None):
    """Shared slot-state transition: fold one step's sampled tokens into
    the device-resident state dict.  ``emit`` marks rows that produce a
    token this step (decoding rows, or rows whose prefill completes);
    ``t_valid`` is how many cache positions each row consumed.  The
    done rule — eos hit, ``max_new`` generated, or (paged) the cache
    strip exhausted — is evaluated *in-jit* so the host never has to
    sync to learn a slot finished; the host replays the identical rule
    on the drained tokens to keep its mirror coherent."""
    steps = st["steps"] + emit.astype(jnp.int32)
    done = (nxt == eos) | (steps >= max_new)
    new = dict(st, tokens=jnp.where(emit, nxt, st["tokens"]), steps=steps)
    if "lengths" in st:
        lengths = st["lengths"] + t_valid
        new["lengths"] = lengths
        if capacity is not None:
            done = done | (lengths >= capacity)
    new["active"] = (st["active"] | emit) & ~(emit & done)
    return new


def make_paged_mixed_step(model, sampler, *, eos_id, max_new, capacity):
    """Fused tick for mixed prefill+decode phases: ``tokens (B,T)`` /
    ``t_valid`` / ``emit`` are host-built (prompt chunks are host
    data), everything else lives in the donated state dict."""
    eos = -1 if eos_id is None else int(eos_id)

    def mixed_step(params, cache, st, tokens, t_valid, emit):
        logits, cache = model.paged_step(
            params, cache, tokens, st["page_table"], st["lengths"], t_valid,
            st["state_slots"])
        logits = _replicated_logits(logits)
        nxt = sampler(logits, st["rids"], st["steps"])
        st = _advance(st, nxt, emit, t_valid, eos=eos, max_new=max_new,
                      capacity=capacity)
        return cache, st, nxt, logits
    return mixed_step


def _run_burst(cache, st, k_max, k_static, trace_aval, body_step):
    """Shared burst scaffolding: run ``body_step(st, cache, i, emit) ->
    (st, cache, nxt, logits)`` up to ``k_max`` (traced) times in one
    ``lax.while_loop`` with the all-done early-out, ring-buffering
    (token, valid[, logits]) per step.  Returns ``(cache, st, tok_buf,
    val_buf[, logit_buf])``; ``tok_buf[k, b]`` is slot ``b``'s token
    from burst step ``k`` (-1 and ``val_buf`` False where the slot
    emitted nothing)."""
    B = st["tokens"].shape[0]
    carry = (jnp.int32(0), st, cache,
             jnp.full((k_static, B), -1, jnp.int32),
             jnp.zeros((k_static, B), bool))
    if trace_aval is not None:
        carry += (jnp.zeros((k_static,) + trace_aval.shape,
                            trace_aval.dtype),)

    def cond(c):
        return (c[0] < k_max) & jnp.any(c[1]["active"])

    def body(c):
        i, st, cache = c[0], c[1], c[2]
        emit = st["active"]
        st, cache, nxt, logits = body_step(st, cache, i, emit)
        out = (i + 1, st, cache,
               c[3].at[i].set(jnp.where(emit, nxt, -1)),
               c[4].at[i].set(emit))
        if trace_aval is not None:
            out += (c[5].at[i].set(logits),)
        return out

    out = jax.lax.while_loop(cond, body, carry)
    return (out[2], out[1]) + out[3:]


# ---------------------------------------------------------------------------
# speculative (draft-verify) decoding
# ---------------------------------------------------------------------------

# Speculative draws fold a dedicated tag into the seed before the
# request id, so the draft / accept / resample key streams can never
# collide with the decode sampler's ``fold_in(fold_in(seed, rid), step)``
# stream above.
_SPEC_TAG = 0x5BEC
_DRAFT_TAG, _ACCEPT_TAG, _RESAMPLE_TAG = 1, 2, 3


def logits_to_probs(logits, *, temperature: float = 1.0,
                    top_k: Optional[int] = None):
    """``(..., V)`` logits -> the probability vector ``sample_logits``
    draws from: same f32 cast, temperature divide, and top-k mask (ties
    at the k-th value kept), then softmax.  ``temperature == 0``
    degenerates to a one-hot at the argmax — the distribution greedy
    decoding "samples" from — which is what lets the speculative accept
    rule run greedy and seeded sampling through one code path."""
    l = logits.astype(jnp.float32)
    if temperature == 0:
        return jax.nn.one_hot(jnp.argmax(l, axis=-1), l.shape[-1],
                              dtype=jnp.float32)
    l = l / jnp.float32(temperature)
    if top_k is not None and 0 < top_k < l.shape[-1]:
        kth = jax.lax.top_k(l, top_k)[0][..., -1:]
        l = jnp.where(l < kth, -jnp.inf, l)
    return jax.nn.softmax(l, axis=-1)


def spec_accept(draft_tokens, draft_probs, target_probs, budget, keys, *,
                greedy: bool = False):
    """Vectorised rejection-sampling accept rule (the standard
    speculative-decoding rule, e.g. Leviathan et al. 2023).

    ``draft_tokens (B, G) int32`` and ``draft_probs (B, G, V)`` are the
    draft's proposals; ``target_probs (B, G+1, V)`` are the target's
    distributions at every drafted position plus the bonus row;
    ``budget (B,) int32`` in ``[0, G]`` caps how many proposals each row
    may accept (rows beyond a row's budget hold garbage and are
    ignored); ``keys (B, 2) uint32`` are per-row PRNG keys.

    Draft token ``d_j`` is accepted iff ``u_j * q_j(d_j) < p_j(d_j)``
    (``p`` target, ``q`` draft, ``u ~ U[0,1)``); the first rejected
    position resamples from ``norm(max(p - q, 0))``, and full
    acceptance draws the bonus token from the target's extra row
    directly.  The emitted prefix is therefore distributed exactly as
    ``p`` — and because greedy distributions are one-hots and ``u < 1``
    strictly, the same arithmetic reduces to "accept iff the draft
    matched the target argmax", making greedy speculative decode
    token-identical to non-speculative greedy by construction.

    Returns ``(emit (B, G+1) int32, n_acc (B,) int32)``: row ``b``'s
    emitted continuation is ``emit[b, :n_acc[b] + 1]`` (accepted drafts
    plus one replacement/bonus token); positions past that are garbage.
    """
    B, G = draft_tokens.shape
    u = jax.vmap(lambda k: jax.random.uniform(
        jax.random.fold_in(k, _ACCEPT_TAG), (G,)))(keys)
    p_d = jnp.take_along_axis(target_probs[:, :G], draft_tokens[..., None],
                              axis=-1)[..., 0]                  # (B, G)
    q_d = jnp.take_along_axis(draft_probs, draft_tokens[..., None],
                              axis=-1)[..., 0]                  # (B, G)
    ok = (u * q_d < p_d) & (jnp.arange(G)[None, :] < budget[:, None])
    n_acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)

    # Replacement row: target minus draft mass at the first rejection;
    # on full acceptance (n_acc == budget) the draft proposed nothing at
    # that position, so the draw is from the target row alone.
    p_row = jnp.take_along_axis(target_probs, n_acc[:, None, None],
                                axis=1)[:, 0]                   # (B, V)
    q_pad = jnp.concatenate(
        [draft_probs, jnp.zeros((B, 1) + draft_probs.shape[2:],
                                draft_probs.dtype)], axis=1)
    q_row = jnp.take_along_axis(q_pad, n_acc[:, None, None], axis=1)[:, 0]
    q_row = jnp.where((n_acc < budget)[:, None], q_row, 0.0)
    resid = jnp.maximum(p_row - q_row, 0.0)
    # Float edge: if the residual mass cancels to exactly zero, fall
    # back to the target row — still a valid sample of p.
    resid = jnp.where(jnp.sum(resid, axis=-1, keepdims=True) > 0,
                      resid, p_row)
    if greedy:
        repl = jnp.argmax(resid, axis=-1).astype(jnp.int32)
    else:
        rkeys = jax.vmap(lambda k: jax.random.fold_in(k, _RESAMPLE_TAG))(keys)
        repl = jax.vmap(lambda k, r: jax.random.categorical(k, jnp.log(r)))(
            rkeys, resid).astype(jnp.int32)

    d_pad = jnp.concatenate([draft_tokens, jnp.zeros((B, 1), jnp.int32)],
                            axis=1)
    pos = jnp.arange(G + 1)[None, :]
    emit = jnp.where(pos < n_acc[:, None], d_pad, repl[:, None])
    return emit, n_acc


def make_paged_spec_mixed_step(model, draft_model, sampler, *, eos_id,
                               max_new, capacity):
    """Spec-enabled variant of ``make_paged_mixed_step``: the target
    step is unchanged (admission/prefill sampling stays bitwise
    identical to non-speculative serving), but the draft model consumes
    the *same* ``(tokens, t_valid)`` chunks so its KV cache tracks the
    target's through prefill and single-step phases.  Rows carrying a
    draft-cache deficit (see ``make_paged_spec_burst``) prepend
    ``spec_prev`` to catch the draft up — which is why speculative mode
    requires ``prefill_chunk >= 2``."""
    eos = -1 if eos_id is None else int(eos_id)

    def mixed_step(params, dparams, cache, dcache, st, tokens, t_valid,
                   emit):
        logits, cache = model.paged_step(
            params, cache, tokens, st["page_table"], st["lengths"], t_valid,
            st["state_slots"])
        logits = _replicated_logits(logits)
        nxt = sampler(logits, st["rids"], st["steps"])

        deficit, prev = st["spec_deficit"], st["spec_prev"]
        d_tokens = jnp.where(
            (deficit > 0)[:, None],
            jnp.concatenate([prev[:, None], tokens[:, :-1]], axis=1),
            tokens)
        tv_d = jnp.where(t_valid > 0, t_valid + deficit, 0)
        _, dcache = draft_model.paged_step(
            dparams, dcache, d_tokens, st["page_table"],
            st["lengths"] - deficit, tv_d, None)

        st = _advance(st, nxt, emit, t_valid, eos=eos, max_new=max_new,
                      capacity=capacity)
        prev_new = jnp.take_along_axis(
            tokens, jnp.clip(t_valid - 1, 0, None)[:, None], axis=1)[:, 0]
        st = dict(st,
                  spec_deficit=jnp.where(t_valid > 0, 0, deficit),
                  spec_prev=jnp.where(t_valid > 0, prev_new, prev))
        return cache, dcache, st, nxt, logits
    return mixed_step


def make_paged_spec_burst(model, draft_model, *, eos_id, max_new, capacity,
                          spec_k: int, k_static: int, seed: int,
                          greedy: bool, temperature: float = 1.0,
                          top_k: Optional[int] = None, trace: bool = False):
    """Speculative decode burst: each of up to ``k_max`` rounds runs the
    draft model ``spec_k`` tokens ahead (T=1 steps, plus a T=2 catch-up
    step when the slot carries a draft-cache deficit), verifies all
    drafted positions with **one** target ``paged_step(all_logits=True)``
    of T = spec_k + 1, and folds the accepted prefix + one
    replacement/bonus token into the slot state via ``spec_accept``.

    Rollback is arithmetic: ``lengths`` advances by the emitted count
    ``m`` only, so rejected positions — though written to the paged KV
    — sit past the new length and are never attended again (the next
    round's scatter rewrites them before any gather can see them).

    Per-row draft budget ``gb = clip(min(max_new - steps - 1,
    capacity - lengths - 1), 0, spec_k)`` keeps every write inside the
    admission-time page reservation; a ``gb == 0`` row necessarily
    finishes this round, so its draft steps are masked entirely.

    Slot-state extras (beyond the contract at the top of this module):

      ``spec_rounds (B,) int32``   rounds this request has run (PRNG)
      ``spec_deficit (B,) int32``  target len minus draft-correct len (0/1)
      ``spec_prev (B,) int32``     token at position ``lengths - 1``

    Ring contract: ``tok_ring (k_static, B, spec_k+1)`` /
    ``val_ring`` bools; round ``r`` slot ``b`` emitted
    ``tok_ring[r, b, j]`` where ``val_ring[r, b, j]``.  With ``trace``,
    ``trace_ring[r, b, j]`` is the target logits row that produced
    emitted token ``j``."""
    eos = -1 if eos_id is None else int(eos_id)
    G = int(spec_k)
    base = jax.random.fold_in(jax.random.PRNGKey(seed), _SPEC_TAG)
    probs = (lambda l: logits_to_probs(l, temperature=0.0)) if greedy else \
        (lambda l: logits_to_probs(l, temperature=temperature, top_k=top_k))

    def round_keys(rids, rounds):
        fold = lambda r, n: jax.random.fold_in(jax.random.fold_in(base, r), n)
        return jax.vmap(fold)(rids, rounds)

    def burst(params, dparams, cache, dcache, st, k_max):
        B = st["tokens"].shape[0]
        trace_aval = jax.eval_shape(
            model.paged_step, params, cache, jnp.zeros((B, G + 1), jnp.int32),
            st["page_table"], st["lengths"], st["active"].astype(jnp.int32),
            st["state_slots"], all_logits=True)[0] if trace else None

        carry = (jnp.int32(0), st, cache, dcache,
                 jnp.full((k_static, B, G + 1), -1, jnp.int32),
                 jnp.zeros((k_static, B, G + 1), bool))
        if trace_aval is not None:
            carry += (jnp.zeros((k_static,) + trace_aval.shape,
                                trace_aval.dtype),)

        def cond(c):
            return (c[0] < k_max) & jnp.any(c[1]["active"])

        def body(c):
            i, st, cache, dcache = c[0], c[1], c[2], c[3]
            active = st["active"]
            L, steps, x = st["lengths"], st["steps"], st["tokens"]
            d, prev = st["spec_deficit"], st["spec_prev"]
            gb = jnp.clip(jnp.minimum(max_new - steps - 1,
                                      capacity - L - 1), 0, G)
            gb = jnp.where(active, gb, 0)
            keys = round_keys(st["rids"], st["spec_rounds"])

            # --- draft G tokens ahead (step 0 is the T=2 catch-up) ---
            tok0 = jnp.stack([jnp.where(d > 0, prev, x),
                              jnp.where(d > 0, x, 0)], axis=1)
            tv0 = jnp.where(active & (gb > 0), 1 + d, 0)
            dlogits, dcache = draft_model.paged_step(
                dparams, dcache, tok0, st["page_table"],
                jnp.where(tv0 > 0, L - d, 0), tv0, None)
            drafts, dprobs, cur = [], [], None
            for j in range(G):
                if j > 0:
                    tv_j = (active & (j < gb)).astype(jnp.int32)
                    dlogits, dcache = draft_model.paged_step(
                        dparams, dcache, cur[:, None], st["page_table"],
                        jnp.where(tv_j > 0, L + j, 0), tv_j, None)
                p_j = probs(_replicated_logits(dlogits))
                if greedy:
                    cur = jnp.argmax(p_j, axis=-1).astype(jnp.int32)
                else:
                    kj = jax.vmap(lambda k: jax.random.fold_in(
                        jax.random.fold_in(k, _DRAFT_TAG), j))(keys)
                    cur = jax.vmap(
                        lambda k, p: jax.random.categorical(k, jnp.log(p)))(
                        kj, p_j).astype(jnp.int32)
                drafts.append(cur)
                dprobs.append(p_j)
            D = jnp.stack(drafts, axis=1)                  # (B, G)
            P = jnp.stack(dprobs, axis=1)                  # (B, G, V)

            # --- verify every drafted position in one target step ---
            tokens_v = jnp.concatenate([x[:, None], D], axis=1)
            tv_v = jnp.where(active, gb + 1, 0)
            qlogits, cache = model.paged_step(
                params, cache, tokens_v, st["page_table"], L, tv_v,
                st["state_slots"], all_logits=True)
            qlogits = _replicated_logits(qlogits)
            emit_full, n_acc = spec_accept(D, P, probs(qlogits), gb, keys,
                                           greedy=greedy)

            # --- fold accepted prefix + replacement into slot state ---
            pos = jnp.arange(G + 1)[None, :]
            is_eos = emit_full == eos
            keep = (pos <= n_acc[:, None]) \
                & (jnp.cumsum(is_eos, axis=1) - is_eos == 0) \
                & active[:, None]
            m = jnp.sum(keep.astype(jnp.int32), axis=1)
            L2, steps2 = L + m, steps + m
            take = lambda idx: jnp.take_along_axis(
                emit_full, jnp.clip(idx, 0, None)[:, None], axis=1)[:, 0]
            x2 = jnp.where(m > 0, take(m - 1), x)
            done = jnp.any(is_eos & keep, axis=1) \
                | (steps2 >= max_new) | (L2 >= capacity)
            prev2 = jnp.where(m >= 2, take(m - 2),
                              jnp.where(m > 0, x, prev))
            st = dict(st, tokens=x2, steps=steps2, lengths=L2,
                      active=active & ~done,
                      spec_deficit=jnp.where(
                          m > 0, (m == gb + 1).astype(jnp.int32), d),
                      spec_prev=prev2,
                      spec_rounds=st["spec_rounds"]
                      + (m > 0).astype(jnp.int32))
            out = (i + 1, st, cache, dcache,
                   c[4].at[i].set(jnp.where(keep, emit_full, -1)),
                   c[5].at[i].set(keep))
            if trace_aval is not None:
                out += (c[6].at[i].set(qlogits),)
            return out

        out = jax.lax.while_loop(cond, body, carry)
        return (out[2], out[3], out[1]) + out[4:]
    return burst


def make_paged_burst(model, sampler, *, eos_id, max_new, capacity,
                     k_static: int, trace: bool = False):
    """Device-resident decode burst through the paged cache: up to
    ``k_max`` fused (paged_step + sample + state update) iterations per
    host round-trip, in one ``lax.while_loop`` with an all-done
    early-out.  The host must have pre-extended every active slot's
    page table to cover ``lengths + k_max`` writes (drawing on the
    admission-time reservation) and COW-forked any shared block in that
    range before calling.  Output contract: see ``_run_burst``."""
    eos = -1 if eos_id is None else int(eos_id)

    def burst(params, cache, st, k_max):
        trace_aval = jax.eval_shape(
            model.paged_step, params, cache, st["tokens"][:, None],
            st["page_table"], st["lengths"],
            st["active"].astype(jnp.int32), st["state_slots"])[0] \
            if trace else None

        def body_step(st, cache, i, emit):
            t_valid = emit.astype(jnp.int32)
            logits, cache = model.paged_step(
                params, cache, st["tokens"][:, None], st["page_table"],
                st["lengths"], t_valid, st["state_slots"])
            logits = _replicated_logits(logits)
            nxt = sampler(logits, st["rids"], st["steps"])
            st = _advance(st, nxt, emit, t_valid, eos=eos, max_new=max_new,
                          capacity=capacity)
            return st, cache, nxt, logits

        return _run_burst(cache, st, k_max, k_static, trace_aval, body_step)
    return burst


def make_dense_burst(model, sampler, *, eos_id, max_new,
                     k_static: int, trace: bool = False):
    """Dense-cache decode burst: all slots share one scalar position
    ``pos`` (the host advances its mirror by the number of steps the
    loop actually ran).  The host caps ``k_max`` at ``capacity - pos``
    so the loop can never write past the cache strip.  Output
    contract: see ``_run_burst``."""
    eos = -1 if eos_id is None else int(eos_id)

    def burst(params, cache, st, pos, k_max):
        trace_aval = jax.eval_shape(model.decode_step, params, cache,
                                    st["tokens"][:, None], pos)[0] \
            if trace else None

        def body_step(st, cache, i, emit):
            logits, cache = model.decode_step(params, cache,
                                              st["tokens"][:, None], pos + i)
            logits = _replicated_logits(logits)
            nxt = sampler(logits, st["rids"], st["steps"])
            st = _advance(st, nxt, emit, emit.astype(jnp.int32),
                          eos=eos, max_new=max_new)
            return st, cache, nxt, logits

        return _run_burst(cache, st, k_max, k_static, trace_aval, body_step)
    return burst
