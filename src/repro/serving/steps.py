"""Jit-able serving step functions (also used by the dry-run).

Sampling is one shared primitive, ``sample_logits``: greedy argmax when
``greedy`` (or ``temperature == 0``), otherwise temperature / top-k
categorical sampling with a **per-row PRNG key** ``(B, 2) uint32``.
Per-row keys are what make sampling reproducible across serving modes:
the engine derives slot ``b``'s key from its request id and decode step
only, so the same request draws the same tokens whether it is served by
the dense or the block-paged engine, in whatever batch composition.

The **megastep** builders fuse one whole engine tick into a single
jitted function: model step + sampler + token/length/step/done-flag
update, all operating on a dict of persistent device arrays the engine
never rebuilds from Python between steps (see ``DeviceSlotState`` in
``kv_cache.py``).  The *burst* variants run up to ``k_max`` fused
decode steps per host round-trip inside one ``lax.while_loop`` with an
all-done early-out, writing sampled tokens into a ``(k_static, B)``
ring buffer the host drains once per burst.  ``k_max`` is a *traced*
scalar, so one compilation serves every burst length — K = 1 and
K = 8 run the identical compiled loop body, which is what makes burst
output bit-identical to single-stepping by construction.

Slot-state dict contract (all arrays device-resident, donated through
every megastep call):

  ``tokens (B,) int32``   last sampled token per slot (next decode input)
  ``rids (B,) int32``     request id per slot (sampling key derivation)
  ``steps (B,) int32``    tokens generated so far per slot
  ``active (B,) bool``    slot is decoding (not idle / prefilling / done)
  paged only:
  ``page_table (B,P)``    logical page -> physical block per slot
  ``lengths (B,) int32``  tokens cached per slot (true position)
  ``state_slots (B,)``    recurrent state slab per slot
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..models.sharding import constrain


def _replicated_logits(logits):
    """Under a mesh, gather the (B, V) logits replicated before the
    sampler: the model's head leaves them vocab-sharded on the model
    axis, and sampling on a full replica keeps every device's slot
    state bitwise in lockstep (it is also how production TP samplers
    work — the allgather is tiny next to a model step).  No-op without
    a mesh context."""
    return constrain(logits, None, None)


def make_prefill_step(model, capacity: int, cache_dtype=jnp.bfloat16):
    def prefill_step(params, tokens, extra_embeds=None):
        return model.prefill(params, tokens, capacity=capacity,
                             extra_embeds=extra_embeds,
                             cache_dtype=cache_dtype)
    return prefill_step


def sample_logits(logits, rng=None, *, greedy: bool = True,
                  temperature: float = 1.0, top_k: Optional[int] = None):
    """logits (B, V), rng (B, 2) uint32 per-row keys -> tokens (B,) int32.

    ``greedy`` or ``temperature == 0`` is exact argmax (no rng needed);
    otherwise each row is drawn from ``softmax(logits / temperature)``
    restricted to its ``top_k`` highest logits (ties at the k-th value
    are kept).  Rows are sampled with *independent* keys so one row's
    draw never depends on the batch around it.
    """
    if greedy or temperature == 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if rng is None:
        raise ValueError("sampling (greedy=False, temperature>0) needs rng")
    l = logits.astype(jnp.float32) / jnp.float32(temperature)
    if top_k is not None and 0 < top_k < l.shape[-1]:
        kth = jax.lax.top_k(l, top_k)[0][..., -1:]
        l = jnp.where(l < kth, -jnp.inf, l)
    draw = lambda key, row: jax.random.categorical(key, row)
    return jax.vmap(draw)(rng, l).astype(jnp.int32)


def make_sampler_core(seed: int = 0, *, greedy: bool = True,
                      temperature: float = 1.0,
                      top_k: Optional[int] = None):
    """Traceable ``(logits, rids, steps) -> tokens`` — the sampler the
    megasteps inline.  Row ``b``'s key — ``fold_in(fold_in(
    PRNGKey(seed), rids[b]), steps[b])`` — is derived *inside* the
    caller's jit, so the hot decode loop ships two small int32 vectors
    instead of doing per-slot ``fold_in`` dispatches and device->host
    key syncs each token.  Greedy (= temperature 0) is the same
    function with the rng path compiled out."""
    if greedy:
        return lambda logits, rids, steps: \
            jnp.argmax(logits, axis=-1).astype(jnp.int32)
    base = jax.random.PRNGKey(seed)

    def sample(logits, rids, steps):
        fold = lambda r, t: jax.random.fold_in(jax.random.fold_in(base, r), t)
        keys = jax.vmap(fold)(rids, steps)
        return sample_logits(logits, keys, greedy=False,
                             temperature=temperature, top_k=top_k)
    return sample


def make_slot_sampler(seed: int = 0, *, greedy: bool = True,
                      temperature: float = 1.0,
                      top_k: Optional[int] = None):
    """Jitted standalone ``(logits, rids, steps) -> tokens`` (the
    engine's admission path; the decode loop samples inside the
    megastep instead).  Both serving modes draw through the same core,
    which is what makes paged and dense token streams match for the
    same seed."""
    return jax.jit(make_sampler_core(seed, greedy=greedy,
                                     temperature=temperature, top_k=top_k))


def make_decode_step(model, *, greedy: bool = True, temperature: float = 1.0,
                     top_k: Optional[int] = None):
    def decode_step(params, cache, token, pos, rng=None):
        """token: (B,1), rng: (B,2) per-row keys (ignored when greedy)
        -> (next_token (B,1), logits, cache)."""
        logits, cache = model.decode_step(params, cache, token, pos)
        nxt = sample_logits(logits, rng, greedy=greedy,
                            temperature=temperature, top_k=top_k)
        return nxt[:, None], logits, cache
    return decode_step


# ---------------------------------------------------------------------------
# fused megasteps: model step + sampler + slot-state update in one jit
# ---------------------------------------------------------------------------

def _advance(st, nxt, emit, t_valid, *, eos, max_new, capacity=None):
    """Shared slot-state transition: fold one step's sampled tokens into
    the device-resident state dict.  ``emit`` marks rows that produce a
    token this step (decoding rows, or rows whose prefill completes);
    ``t_valid`` is how many cache positions each row consumed.  The
    done rule — eos hit, ``max_new`` generated, or (paged) the cache
    strip exhausted — is evaluated *in-jit* so the host never has to
    sync to learn a slot finished; the host replays the identical rule
    on the drained tokens to keep its mirror coherent."""
    steps = st["steps"] + emit.astype(jnp.int32)
    done = (nxt == eos) | (steps >= max_new)
    new = dict(st, tokens=jnp.where(emit, nxt, st["tokens"]), steps=steps)
    if "lengths" in st:
        lengths = st["lengths"] + t_valid
        new["lengths"] = lengths
        if capacity is not None:
            done = done | (lengths >= capacity)
    new["active"] = (st["active"] | emit) & ~(emit & done)
    return new


def make_paged_mixed_step(model, sampler, *, eos_id, max_new, capacity):
    """Fused tick for mixed prefill+decode phases: ``tokens (B,T)`` /
    ``t_valid`` / ``emit`` are host-built (prompt chunks are host
    data), everything else lives in the donated state dict."""
    eos = -1 if eos_id is None else int(eos_id)

    def mixed_step(params, cache, st, tokens, t_valid, emit):
        logits, cache = model.paged_step(
            params, cache, tokens, st["page_table"], st["lengths"], t_valid,
            st["state_slots"])
        logits = _replicated_logits(logits)
        nxt = sampler(logits, st["rids"], st["steps"])
        st = _advance(st, nxt, emit, t_valid, eos=eos, max_new=max_new,
                      capacity=capacity)
        return cache, st, nxt, logits
    return mixed_step


def _run_burst(cache, st, k_max, k_static, trace_aval, body_step):
    """Shared burst scaffolding: run ``body_step(st, cache, i, emit) ->
    (st, cache, nxt, logits)`` up to ``k_max`` (traced) times in one
    ``lax.while_loop`` with the all-done early-out, ring-buffering
    (token, valid[, logits]) per step.  Returns ``(cache, st, tok_buf,
    val_buf[, logit_buf])``; ``tok_buf[k, b]`` is slot ``b``'s token
    from burst step ``k`` (-1 and ``val_buf`` False where the slot
    emitted nothing)."""
    B = st["tokens"].shape[0]
    carry = (jnp.int32(0), st, cache,
             jnp.full((k_static, B), -1, jnp.int32),
             jnp.zeros((k_static, B), bool))
    if trace_aval is not None:
        carry += (jnp.zeros((k_static,) + trace_aval.shape,
                            trace_aval.dtype),)

    def cond(c):
        return (c[0] < k_max) & jnp.any(c[1]["active"])

    def body(c):
        i, st, cache = c[0], c[1], c[2]
        emit = st["active"]
        st, cache, nxt, logits = body_step(st, cache, i, emit)
        out = (i + 1, st, cache,
               c[3].at[i].set(jnp.where(emit, nxt, -1)),
               c[4].at[i].set(emit))
        if trace_aval is not None:
            out += (c[5].at[i].set(logits),)
        return out

    out = jax.lax.while_loop(cond, body, carry)
    return (out[2], out[1]) + out[3:]


def make_paged_burst(model, sampler, *, eos_id, max_new, capacity,
                     k_static: int, trace: bool = False):
    """Device-resident decode burst through the paged cache: up to
    ``k_max`` fused (paged_step + sample + state update) iterations per
    host round-trip, in one ``lax.while_loop`` with an all-done
    early-out.  The host must have pre-extended every active slot's
    page table to cover ``lengths + k_max`` writes (drawing on the
    admission-time reservation) and COW-forked any shared block in that
    range before calling.  Output contract: see ``_run_burst``."""
    eos = -1 if eos_id is None else int(eos_id)

    def burst(params, cache, st, k_max):
        trace_aval = jax.eval_shape(
            model.paged_step, params, cache, st["tokens"][:, None],
            st["page_table"], st["lengths"],
            st["active"].astype(jnp.int32), st["state_slots"])[0] \
            if trace else None

        def body_step(st, cache, i, emit):
            t_valid = emit.astype(jnp.int32)
            logits, cache = model.paged_step(
                params, cache, st["tokens"][:, None], st["page_table"],
                st["lengths"], t_valid, st["state_slots"])
            logits = _replicated_logits(logits)
            nxt = sampler(logits, st["rids"], st["steps"])
            st = _advance(st, nxt, emit, t_valid, eos=eos, max_new=max_new,
                          capacity=capacity)
            return st, cache, nxt, logits

        return _run_burst(cache, st, k_max, k_static, trace_aval, body_step)
    return burst


def make_dense_burst(model, sampler, *, eos_id, max_new,
                     k_static: int, trace: bool = False):
    """Dense-cache decode burst: all slots share one scalar position
    ``pos`` (the host advances its mirror by the number of steps the
    loop actually ran).  The host caps ``k_max`` at ``capacity - pos``
    so the loop can never write past the cache strip.  Output
    contract: see ``_run_burst``."""
    eos = -1 if eos_id is None else int(eos_id)

    def burst(params, cache, st, pos, k_max):
        trace_aval = jax.eval_shape(model.decode_step, params, cache,
                                    st["tokens"][:, None], pos)[0] \
            if trace else None

        def body_step(st, cache, i, emit):
            logits, cache = model.decode_step(params, cache,
                                              st["tokens"][:, None], pos + i)
            logits = _replicated_logits(logits)
            nxt = sampler(logits, st["rids"], st["steps"])
            st = _advance(st, nxt, emit, emit.astype(jnp.int32),
                          eos=eos, max_new=max_new)
            return st, cache, nxt, logits

        return _run_burst(cache, st, k_max, k_static, trace_aval, body_step)
    return burst
