"""Jit-able serving step functions (also used by the dry-run).

Sampling is one shared primitive, ``sample_logits``: greedy argmax when
``greedy`` (or ``temperature == 0``), otherwise temperature / top-k
categorical sampling with a **per-row PRNG key** ``(B, 2) uint32``.
Per-row keys are what make sampling reproducible across serving modes:
the engine derives slot ``b``'s key from its request id and decode step
only, so the same request draws the same tokens whether it is served by
the dense or the block-paged engine, in whatever batch composition.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def make_prefill_step(model, capacity: int, cache_dtype=jnp.bfloat16):
    def prefill_step(params, tokens, extra_embeds=None):
        return model.prefill(params, tokens, capacity=capacity,
                             extra_embeds=extra_embeds,
                             cache_dtype=cache_dtype)
    return prefill_step


def sample_logits(logits, rng=None, *, greedy: bool = True,
                  temperature: float = 1.0, top_k: Optional[int] = None):
    """logits (B, V), rng (B, 2) uint32 per-row keys -> tokens (B,) int32.

    ``greedy`` or ``temperature == 0`` is exact argmax (no rng needed);
    otherwise each row is drawn from ``softmax(logits / temperature)``
    restricted to its ``top_k`` highest logits (ties at the k-th value
    are kept).  Rows are sampled with *independent* keys so one row's
    draw never depends on the batch around it.
    """
    if greedy or temperature == 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if rng is None:
        raise ValueError("sampling (greedy=False, temperature>0) needs rng")
    l = logits.astype(jnp.float32) / jnp.float32(temperature)
    if top_k is not None and 0 < top_k < l.shape[-1]:
        kth = jax.lax.top_k(l, top_k)[0][..., -1:]
        l = jnp.where(l < kth, -jnp.inf, l)
    draw = lambda key, row: jax.random.categorical(key, row)
    return jax.vmap(draw)(rng, l).astype(jnp.int32)


def make_slot_sampler(seed: int = 0, *, greedy: bool = True,
                      temperature: float = 1.0,
                      top_k: Optional[int] = None):
    """Jitted ``(logits, rids, steps) -> tokens`` used by the engine.

    Row ``b``'s key — ``fold_in(fold_in(PRNGKey(seed), rids[b]),
    steps[b])`` — is derived *inside* the jit, so the hot decode loop
    ships two small int32 vectors instead of doing per-slot ``fold_in``
    dispatches and device->host key syncs each token.  Both serving
    modes draw through one of these, which is what makes paged and
    dense token streams match for the same seed."""
    if greedy:
        return jax.jit(lambda logits, rids, steps:
                       jnp.argmax(logits, axis=-1).astype(jnp.int32))
    base = jax.random.PRNGKey(seed)

    def sample(logits, rids, steps):
        fold = lambda r, t: jax.random.fold_in(jax.random.fold_in(base, r), t)
        keys = jax.vmap(fold)(rids, steps)
        return sample_logits(logits, keys, greedy=False,
                             temperature=temperature, top_k=top_k)
    return jax.jit(sample)


def make_decode_step(model, *, greedy: bool = True, temperature: float = 1.0,
                     top_k: Optional[int] = None):
    def decode_step(params, cache, token, pos, rng=None):
        """token: (B,1), rng: (B,2) per-row keys (ignored when greedy)
        -> (next_token (B,1), logits, cache)."""
        logits, cache = model.decode_step(params, cache, token, pos)
        nxt = sample_logits(logits, rng, greedy=greedy,
                            temperature=temperature, top_k=top_k)
        return nxt[:, None], logits, cache
    return decode_step
