"""Jit-able serving step functions (also used by the dry-run)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def make_prefill_step(model, capacity: int, cache_dtype=jnp.bfloat16):
    def prefill_step(params, tokens, extra_embeds=None):
        return model.prefill(params, tokens, capacity=capacity,
                             extra_embeds=extra_embeds,
                             cache_dtype=cache_dtype)
    return prefill_step


def make_decode_step(model, *, greedy: bool = True, temperature: float = 1.0):
    def decode_step(params, cache, token, pos, rng=None):
        """token: (B,1) -> (next_token (B,1), logits, cache)."""
        logits, cache = model.decode_step(params, cache, token, pos)
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(rng, logits / temperature).astype(jnp.int32)
        return nxt[:, None], logits, cache
    return decode_step
