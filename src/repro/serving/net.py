"""Tensor-query networking: the serving stack's front door.

``TensorQueryServer`` mounts a :class:`~repro.serving.engine.ServeEngine`
behind the ``tensor_query_serversrc`` / ``tensor_query_serversink``
pipeline elements (wire format in :mod:`repro.core.elements.query`,
re-exported here):

    serversrc ! tensor_batcher ! queue(workers=N) !
        tensor_filter(pass_meta, engine.as_pipeline_filter) !
        tensor_unbatcher ! serversink

The batcher closes a micro-batch on size or ``max_wait_ms``; the
multi-worker queue lets several batches block inside the engine filter
*concurrently* (the engine's ``wait`` protocol elects one stepping
thread among them), which is what allows an interactive request to be
submitted — and to preempt batch-lane slots — while earlier batches are
still generating.  Tokens stream back per-request through the engine's
``stream_cb`` as they are drained from the decode burst ring buffer;
the DONE frame from the serversink carries the authoritative full
sequence plus terminal status, so a TOKENS delta lost to the
registration race (a token emitted between ``submit`` and the
``on_submit`` route registration) costs an increment, never data.

``TensorQueryClient`` is the matching client: ``submit`` returns a
connection-scoped query id immediately; a reader thread folds TOKENS
deltas into per-request state (recording time-to-first-token on
arrival) and ``result(qid)`` blocks for the DONE frame.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

from ..core.elements.query import (HDR, LANE_CODES, LANE_NAMES, MAGIC,
                                   MSG_DONE, MSG_ERROR, MSG_REQUEST,
                                   MSG_TOKENS, STATUS_CODES, STATUS_NAMES,
                                   VERSION, pack_frame, pack_tensor,
                                   read_frame, unpack_tensor)

__all__ = ["TensorQueryClient", "TensorQueryServer",
           "HDR", "MAGIC", "VERSION", "MSG_REQUEST", "MSG_TOKENS",
           "MSG_DONE", "MSG_ERROR", "LANE_CODES", "LANE_NAMES",
           "STATUS_CODES", "STATUS_NAMES",
           "pack_frame", "pack_tensor", "read_frame", "unpack_tensor"]


class QueryResult:
    """Client-side per-request state, filled in by the reader thread."""

    def __init__(self, qid: int):
        self.qid = qid
        self.t_submit = time.monotonic()
        self.t_first: Optional[float] = None    # first TOKENS/DONE arrival
        self.t_done: Optional[float] = None
        self.stream: List[int] = []             # TOKENS deltas (best-effort)
        self.tokens: Optional[np.ndarray] = None  # authoritative, from DONE
        self.status: Optional[str] = None
        self.error: Optional[str] = None
        self.done = threading.Event()

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.t_first is None else self.t_first - self.t_submit

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit


class TensorQueryClient:
    """Blocking client for one tensor-query server connection."""

    def __init__(self, host: str, port: int, connect_timeout: float = 10.0):
        import socket
        self.sock = socket.create_connection((host, port),
                                             timeout=connect_timeout)
        self.sock.settimeout(None)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._send_lock = threading.Lock()
        self._lock = threading.Lock()
        self._next_qid = 0
        self._requests: Dict[int, QueryResult] = {}
        self._collected: set = set()    # qids result() already returned
        self._closed = False            # close() was called
        self._broken = False            # reader thread exited: socket dead
        self._reader = threading.Thread(target=self._read_loop,
                                        name="tq-client-reader", daemon=True)
        self._reader.start()

    # -- submission ---------------------------------------------------------
    def submit(self, prompt, lane: str = "interactive",
               deadline: Optional[float] = None) -> int:
        """Send one prompt; returns its query id without blocking.
        Raises ``ConnectionError`` if the connection is closed or the
        socket is dead (instead of surfacing an opaque OS error)."""
        if self._closed or self._broken:
            raise ConnectionError(
                "tensor_query client is closed — cannot submit new queries"
                if self._closed else
                "tensor_query connection is dead (reader thread exited) — "
                "cannot submit new queries")
        arr = np.asarray(prompt, np.int32).reshape(-1)
        with self._lock:
            qid = self._next_qid
            self._next_qid += 1
            self._requests[qid] = QueryResult(qid)
        frame = pack_frame(MSG_REQUEST, qid, pack_tensor(arr),
                           lane=LANE_CODES[lane],
                           deadline=0.0 if deadline is None else float(deadline))
        try:
            with self._send_lock:
                self.sock.sendall(frame)
        except OSError as exc:
            with self._lock:
                self._requests.pop(qid, None)   # never submitted
            raise ConnectionError(
                f"tensor_query connection is closed or broken, cannot "
                f"submit query {qid}: {exc}") from exc
        return qid

    def result(self, qid: int,
               timeout: Optional[float] = 60.0) -> QueryResult:
        """Block until ``qid``'s DONE/ERROR frame arrives.  Raises
        ``ValueError`` for a qid this connection never submitted.

        Each ``QueryResult`` is returned exactly once: collecting it
        drops the client's own reference (a long-lived connection would
        otherwise retain every result's token arrays forever), leaving
        a tombstone so a second collection attempt is a clear
        ``ValueError`` rather than a silent unknown-qid one.  A timeout
        does *not* collect — the query can still be retrieved once it
        finishes."""
        with self._lock:
            res = self._requests.get(qid)
            if res is None and qid in self._collected:
                raise ValueError(
                    f"query id {qid} already collected: result() returns "
                    "each query exactly once — keep the returned "
                    "QueryResult if you need it again")
        if res is None:
            raise ValueError(
                f"unknown query id {qid}: not submitted on this connection")
        if not res.done.wait(timeout=timeout):
            raise TimeoutError(f"query {qid} not finished in {timeout}s")
        with self._lock:
            self._requests.pop(qid, None)
            self._collected.add(qid)
        return res

    # -- reader -------------------------------------------------------------
    def _read_loop(self) -> None:
        try:
            while not self._closed:
                frame = read_frame(self.sock)
                if frame is None:
                    break
                msg_type, qid, _lane, status, _deadline, payload = frame
                with self._lock:
                    res = self._requests.get(qid)
                if res is None:
                    continue
                now = time.monotonic()
                if msg_type == MSG_TOKENS:
                    if res.t_first is None:
                        res.t_first = now
                    res.stream.extend(
                        int(t) for t in unpack_tensor(payload).reshape(-1))
                elif msg_type == MSG_DONE:
                    if res.t_first is None:
                        res.t_first = now
                    res.t_done = now
                    res.tokens = np.asarray(unpack_tensor(payload), np.int32)
                    res.status = STATUS_NAMES.get(status, "error")
                    res.done.set()
                elif msg_type == MSG_ERROR:
                    # ERROR is as terminal as DONE: stamp both
                    # timestamps so ttft_s/latency_s stay measurable
                    # for failed queries (percentile aggregation must
                    # count them, not silently drop them)
                    if res.t_first is None:
                        res.t_first = now
                    res.t_done = now
                    res.status = "error"
                    res.error = payload.decode("utf-8", "replace")
                    res.done.set()
        except (OSError, ConnectionError, ValueError):
            pass
        # The reader exiting — server EOF, socket error, or close() —
        # means the connection is unusable: mark the client broken so
        # submit() fails fast instead of sendall-ing into a half-dead
        # socket, then fail everything still in flight with both
        # timestamps stamped (connection death is a terminal path too).
        self._broken = True
        now = time.monotonic()
        with self._lock:
            pending = [r for r in self._requests.values() if not r.done.is_set()]
        for res in pending:
            if res.t_first is None:
                res.t_first = now
            res.t_done = now
            res.status = "error"
            res.error = res.error or "connection closed"
            res.done.set()

    def close(self) -> None:
        self._closed = True
        try:
            self.sock.close()
        except OSError:
            pass
        self._reader.join(timeout=2.0)


class TensorQueryServer:
    """Serve a ``ServeEngine`` over TCP through the stream pipeline."""

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 0,
                 max_batch: Optional[int] = None, max_wait_ms: float = 5.0,
                 pad_to: Optional[int] = None, workers: int = 4,
                 queue_size: int = 64, stream: bool = True,
                 filter_timeout_s: Optional[float] = None):
        from ..core import elements as E
        from ..core.pipeline import Pipeline
        self.engine = engine
        if max_batch is None:
            max_batch = engine.batch_size
        if pad_to is None:
            pad_to = max(8, engine.capacity - engine.max_new_tokens)
        self.stream = bool(stream)
        self._routes: Dict[int, tuple] = {}     # engine rid -> (conn, qid)
        self._routes_lock = threading.Lock()

        self.src = E.TensorQueryServerSrc("qsrc", host=host, port=port,
                                          pad_to=pad_to)
        batcher = E.TensorBatcher("batch", max_batch=max_batch,
                                  max_wait_ms=max_wait_ms)
        q = E.Queue("dispatch", max_size=queue_size, workers=workers)
        filt = E.TensorFilter(
            "llm", framework="python", max_batch=max_batch, pass_meta=True,
            fn=engine.as_pipeline_filter(use_meta=True,
                                         on_submit=self._register,
                                         timeout_s=filter_timeout_s))
        unbatch = E.TensorUnbatcher("unbatch")
        self.sink = E.TensorQueryServerSink("qsink", on_done=self._unroute)
        self.pipeline = (Pipeline("tensor-query-server")
                         .add(self.src, batcher, q, filt, unbatch, self.sink)
                         .link("qsrc", "batch", "dispatch", "llm",
                               "unbatch", "qsink"))

    # -- routing ------------------------------------------------------------
    def _register(self, rid: int, meta) -> None:
        q = meta.get("query") if isinstance(meta, dict) else None
        if isinstance(q, dict) and q.get("conn") is not None:
            with self._routes_lock:
                self._routes[rid] = (q["conn"], int(q["qid"]))

    def _unroute(self, meta) -> None:
        """Drop a request's route once its terminal frame was sent (or
        its connection died) — routes must never outlive the request."""
        rid = meta.get("rid") if isinstance(meta, dict) else None
        if rid is not None:
            with self._routes_lock:
                self._routes.pop(int(rid), None)

    def _on_tokens(self, rid: int, new_tokens) -> None:
        with self._routes_lock:
            route = self._routes.get(rid)
        if route is None:
            return
        conn, qid = route
        # enqueue-only (the connection's writer thread does the socket
        # I/O) so a stalled client cannot block the engine's drain path
        conn.send_frame(MSG_TOKENS, qid,
                        pack_tensor(np.asarray(new_tokens, np.int32)))
        if not conn.alive:
            with self._routes_lock:
                self._routes.pop(rid, None)

    # -- lifecycle ----------------------------------------------------------
    @property
    def port(self) -> int:
        return self.src.port

    def start(self) -> "TensorQueryServer":
        if self.stream:
            self.engine.stream_cb = self._on_tokens
        self.pipeline.start()
        return self

    def stop(self) -> None:
        self.pipeline.stop()
        if self.engine.stream_cb == self._on_tokens:
            self.engine.stream_cb = None
        with self._routes_lock:
            self._routes.clear()
