"""Tensor-query networking: the serving stack's front door.

``TensorQueryServer`` mounts a :class:`~repro.serving.engine.ServeEngine`
behind the ``tensor_query_serversrc`` / ``tensor_query_serversink``
pipeline elements (wire format in :mod:`repro.core.elements.query`,
re-exported here):

    serversrc ! tensor_batcher ! queue(workers=N) !
        tensor_filter(pass_meta, engine.as_pipeline_filter) !
        tensor_unbatcher ! serversink

The batcher closes a micro-batch on size or ``max_wait_ms``; the
multi-worker queue lets several batches block inside the engine filter
*concurrently* (the engine's ``wait`` protocol elects one stepping
thread among them), which is what allows an interactive request to be
submitted — and to preempt batch-lane slots — while earlier batches are
still generating.  Tokens stream back per-request through the engine's
``stream_cb`` as they are drained from the decode burst ring buffer;
the DONE frame from the serversink carries the authoritative full
sequence plus terminal status, so a TOKENS delta lost to the
registration race (a token emitted between ``submit`` and the
``on_submit`` route registration) costs an increment, never data.

Fault tolerance (protocol v2): the server resolves MSG_CANCEL frames to
engine request ids (including cancels racing the batcher — they are
parked and land the moment the request registers) and kills
credit-starved routes with ``status="overrun"`` off the drain path; the
client can ``cancel(qid)``, grant flow-control credit, and — with
``reconnect=True`` — survive a dropped socket by reconnecting with
exponential backoff + jitter and idempotently resubmitting every query
the server never started streaming.  ``TensorQueryServer.drain`` stops
admission and sees every in-flight request to a terminal frame, which
is what the launcher's SIGTERM handler calls.

``TensorQueryClient`` is the matching client: ``submit`` returns a
connection-scoped query id immediately; a reader thread folds TOKENS
deltas into per-request state (recording time-to-first-token on
arrival) and ``result(qid)`` blocks for the DONE frame.
"""
from __future__ import annotations

import random
import socket
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

from ..core.elements.query import (CONN_QID, HDR, LANE_CODES, LANE_NAMES,
                                   MAGIC, MSG_CANCEL, MSG_CREDIT, MSG_DONE,
                                   MSG_ERROR, MSG_REQUEST, MSG_TOKENS,
                                   STATUS_CODES, STATUS_NAMES, VERSION,
                                   ProtocolError, pack_credit, pack_frame,
                                   pack_tensor, read_frame, unpack_tensor)

__all__ = ["TensorQueryClient", "TensorQueryServer",
           "HDR", "MAGIC", "VERSION", "CONN_QID",
           "MSG_REQUEST", "MSG_TOKENS", "MSG_DONE", "MSG_ERROR",
           "MSG_CANCEL", "MSG_CREDIT", "LANE_CODES", "LANE_NAMES",
           "STATUS_CODES", "STATUS_NAMES", "ProtocolError",
           "pack_frame", "pack_tensor", "pack_credit",
           "read_frame", "unpack_tensor"]


class QueryResult:
    """Client-side per-request state, filled in by the reader thread.

    The submission parameters (prompt/lane/deadline/credit) are kept so
    a reconnecting client can idempotently resubmit a query the server
    never started streaming."""

    def __init__(self, qid: int, prompt: Optional[np.ndarray] = None,
                 lane: str = "interactive", deadline: Optional[float] = None,
                 credit: Optional[int] = None):
        self.qid = qid
        self.prompt = prompt
        self.lane = lane
        self.deadline = deadline
        self.credit = credit
        self.t_submit = time.monotonic()
        self.t_first: Optional[float] = None    # first TOKENS/DONE arrival
        self.t_done: Optional[float] = None
        self.stream: List[int] = []             # TOKENS deltas (best-effort)
        self.tokens: Optional[np.ndarray] = None  # authoritative, from DONE
        self.status: Optional[str] = None
        self.error: Optional[str] = None
        self.done = threading.Event()

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.t_first is None else self.t_first - self.t_submit

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit


class TensorQueryClient:
    """Blocking client for one tensor-query server connection.

    ``retries``/``backoff``/``reconnect`` make the client survive a
    dropped socket: with ``reconnect=True`` a dead connection is redialed
    up to ``retries`` times with exponential backoff (base ``backoff``
    seconds, full jitter), and every query the server never *started*
    (no TOKENS/DONE received) is resubmitted idempotently under its
    original qid; queries already mid-stream fail with a connection
    error — replaying half a stream would double tokens."""

    def __init__(self, host: str, port: int, connect_timeout: float = 10.0,
                 retries: int = 3, backoff: float = 0.05,
                 reconnect: bool = False):
        self.host, self.port = host, int(port)
        self.connect_timeout = float(connect_timeout)
        self.retries = max(1, int(retries))
        self.backoff = float(backoff)
        self.reconnect = bool(reconnect)
        self.n_reconnects = 0
        self.n_resubmitted = 0
        self.sock = self._dial()
        self._send_lock = threading.Lock()
        self._lock = threading.Lock()
        self._reconnect_lock = threading.Lock()
        self._next_qid = 0
        self._requests: Dict[int, QueryResult] = {}
        self._collected: set = set()    # qids result() already returned
        self._closed = False            # close() was called
        self._broken = False            # reader thread exited: socket dead
        self._conn_error: Optional[str] = None  # connection-scoped ERROR text
        self._reader = threading.Thread(target=self._read_loop,
                                        name="tq-client-reader", daemon=True)
        self._reader.start()

    def _dial(self) -> socket.socket:
        sock = socket.create_connection((self.host, self.port),
                                        timeout=self.connect_timeout)
        sock.settimeout(None)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    # -- submission ---------------------------------------------------------
    def submit(self, prompt, lane: str = "interactive",
               deadline: Optional[float] = None,
               credit: Optional[int] = None) -> int:
        """Send one prompt; returns its query id without blocking.

        ``credit`` switches the query's token stream to credited flow
        control: the server will send at most ``credit`` TOKENS frames
        until :meth:`grant` refills (pausing, not dropping, at zero).
        Raises ``ConnectionError`` if the connection is closed or the
        socket is dead (instead of surfacing an opaque OS error)."""
        self._ensure_usable()
        arr = np.asarray(prompt, np.int32).reshape(-1)
        with self._lock:
            qid = self._next_qid
            self._next_qid += 1
            self._requests[qid] = QueryResult(qid, prompt=arr, lane=lane,
                                              deadline=deadline, credit=credit)
        try:
            self._send_request(qid, arr, lane, deadline, credit)
        except OSError as exc:
            if self.reconnect and not self._closed:
                # the resubmission path owns this query now: reconnect
                # replays every not-yet-started query, this one included
                self._broken = True
                try:
                    self._reconnect()
                    return qid
                except ConnectionError:
                    pass
            with self._lock:
                self._requests.pop(qid, None)   # never submitted
            raise ConnectionError(
                f"tensor_query connection is closed or broken, cannot "
                f"submit query {qid}: {exc}") from exc
        return qid

    def _ensure_usable(self) -> None:
        if self._closed:
            raise ConnectionError(
                "tensor_query client is closed — cannot submit new queries")
        if self._broken:
            if self.reconnect:
                self._reconnect()       # raises ConnectionError on failure
            else:
                raise ConnectionError(
                    "tensor_query connection is dead (socket closed or "
                    "broken, reader thread exited) — cannot submit new "
                    "queries")

    def _send_request(self, qid: int, arr: np.ndarray, lane: str,
                      deadline: Optional[float],
                      credit: Optional[int]) -> None:
        frame = pack_frame(MSG_REQUEST, qid, pack_tensor(arr),
                           lane=LANE_CODES[lane],
                           deadline=0.0 if deadline is None
                           else float(deadline))
        if credit is not None:
            frame += pack_frame(MSG_CREDIT, qid, pack_credit(credit))
        with self._send_lock:
            self.sock.sendall(frame)

    def cancel(self, qid: int) -> None:
        """Ask the server to abandon ``qid``.  Its terminal frame will
        be ``DONE(status="cancelled")`` carrying whatever tokens were
        generated before the cancel landed — keep waiting on
        :meth:`result` to collect it."""
        with self._lock:
            if qid not in self._requests and qid not in self._collected:
                raise ValueError(
                    f"unknown query id {qid}: not submitted on this "
                    "connection")
        try:
            with self._send_lock:
                self.sock.sendall(pack_frame(MSG_CANCEL, qid))
        except OSError as exc:
            raise ConnectionError(
                f"cannot send CANCEL for query {qid}: {exc}") from exc

    def grant(self, qid: int, n: int) -> None:
        """Grant the server ``n`` more TOKENS frames for ``qid``
        (credit-based flow control; see ``submit(credit=)``)."""
        try:
            with self._send_lock:
                self.sock.sendall(pack_frame(MSG_CREDIT, qid,
                                             pack_credit(n)))
        except OSError as exc:
            raise ConnectionError(
                f"cannot send CREDIT for query {qid}: {exc}") from exc

    def result(self, qid: int, timeout: Optional[float] = 60.0,
               cancel_on_timeout: bool = False) -> QueryResult:
        """Block until ``qid``'s DONE/ERROR frame arrives.  Raises
        ``ValueError`` for a qid this connection never submitted.

        Each ``QueryResult`` is returned exactly once: collecting it
        drops the client's own reference (a long-lived connection would
        otherwise retain every result's token arrays forever), leaving
        a tombstone so a second collection attempt is a clear
        ``ValueError`` rather than a silent unknown-qid one.  A timeout
        does *not* collect — the query can still be retrieved once it
        finishes — unless ``cancel_on_timeout`` is set, in which case
        the deadline is enforced *server-side*: a CANCEL is sent and the
        terminal ``DONE(cancelled)`` (with partial tokens) is returned
        instead of raising."""
        with self._lock:
            res = self._requests.get(qid)
            if res is None and qid in self._collected:
                raise ValueError(
                    f"query id {qid} already collected: result() returns "
                    "each query exactly once — keep the returned "
                    "QueryResult if you need it again")
        if res is None:
            raise ValueError(
                f"unknown query id {qid}: not submitted on this connection")
        if not res.done.wait(timeout=timeout):
            if cancel_on_timeout and not (self._closed or self._broken):
                try:
                    self.cancel(qid)
                except ConnectionError:
                    pass
                else:
                    grace = 5.0 if timeout is None \
                        else max(0.5, min(5.0, timeout))
                    if res.done.wait(timeout=grace):
                        with self._lock:
                            self._requests.pop(qid, None)
                            self._collected.add(qid)
                        return res
            raise TimeoutError(f"query {qid} not finished in {timeout}s")
        with self._lock:
            self._requests.pop(qid, None)
            self._collected.add(qid)
        return res

    # -- reader -------------------------------------------------------------
    def _read_loop(self) -> None:
        try:
            while not self._closed:
                frame = read_frame(self.sock)
                if frame is None:
                    break
                msg_type, qid, _lane, status, _deadline, payload = frame
                if qid == CONN_QID and msg_type == MSG_ERROR:
                    # connection-scoped failure (protocol desync, version
                    # mismatch): the server closes right after — record
                    # why so pending queries fail with the real reason
                    self._conn_error = payload.decode("utf-8", "replace")
                    continue
                with self._lock:
                    res = self._requests.get(qid)
                if res is None or res.done.is_set():
                    continue            # unknown, or duplicate terminal
                now = time.monotonic()
                if msg_type == MSG_TOKENS:
                    if res.t_first is None:
                        res.t_first = now
                    res.stream.extend(
                        int(t) for t in unpack_tensor(payload).reshape(-1))
                elif msg_type == MSG_DONE:
                    if res.t_first is None:
                        res.t_first = now
                    res.t_done = now
                    res.tokens = np.asarray(unpack_tensor(payload), np.int32)
                    res.status = STATUS_NAMES.get(status, "error")
                    res.done.set()
                elif msg_type == MSG_ERROR:
                    # ERROR is as terminal as DONE: stamp both
                    # timestamps so ttft_s/latency_s stay measurable
                    # for failed queries (percentile aggregation must
                    # count them, not silently drop them)
                    if res.t_first is None:
                        res.t_first = now
                    res.t_done = now
                    res.status = "error"
                    res.error = payload.decode("utf-8", "replace")
                    res.done.set()
        except (OSError, ConnectionError, ValueError):
            pass
        self._on_disconnect()

    def _on_disconnect(self) -> None:
        """The reader exited — server EOF, socket error, or close().
        With ``reconnect`` enabled (and no explicit close) try to
        resurrect the connection first: success resubmits every
        not-yet-started query and a fresh reader takes over.  Otherwise
        mark the client broken so ``submit`` fails fast, and complete
        everything still in flight with a connection error (connection
        death is a terminal path too — waiters must never sit out their
        full timeout)."""
        self._broken = True
        if self.reconnect and not self._closed:
            try:
                self._reconnect()
                return
            except ConnectionError:
                pass
        self._fail_pending(self._conn_error or "connection closed")

    def _fail_pending(self, msg: str) -> None:
        now = time.monotonic()
        with self._lock:
            pending = [r for r in self._requests.values()
                       if not r.done.is_set()]
        for res in pending:
            if res.t_first is None:
                res.t_first = now
            res.t_done = now
            res.status = "error"
            res.error = res.error or msg
            res.done.set()

    # -- reconnection -------------------------------------------------------
    def _reconnect(self) -> None:
        """Redial with exponential backoff + full jitter; on success,
        restart the reader and resubmit every not-yet-started query.
        Raises ``ConnectionError`` after ``retries`` failed dials."""
        with self._reconnect_lock:
            if self._closed:
                raise ConnectionError("tensor_query client is closed")
            if not self._broken:
                return                  # another thread already redialed
            delay = max(0.001, self.backoff)
            last: Optional[Exception] = None
            for attempt in range(self.retries):
                try:
                    sock = self._dial()
                except OSError as exc:
                    last = exc
                    time.sleep(delay * (1.0 + random.random()))
                    delay = min(delay * 2.0, 2.0)
                    continue
                old, self.sock = self.sock, sock
                try:
                    old.close()
                except OSError:
                    pass
                self._broken = False
                self.n_reconnects += 1
                # fresh reader BEFORE resubmitting, so replies on the
                # new socket are consumed from the first frame
                self._reader = threading.Thread(
                    target=self._read_loop, name="tq-client-reader",
                    daemon=True)
                self._reader.start()
                self._resubmit_unstarted()
                return
            self._fail_pending(f"reconnect to {self.host}:{self.port} "
                               f"failed after {self.retries} attempts: {last}")
            raise ConnectionError(
                f"reconnect to {self.host}:{self.port} failed after "
                f"{self.retries} attempts: {last}") from last

    def _resubmit_unstarted(self) -> None:
        """Replay queries the dead connection never started streaming
        (idempotent: the server never saw — or never admitted — them
        under this socket, and qids keep their values).  Queries already
        mid-stream cannot be replayed without double-counting tokens:
        they fail with a connection error."""
        with self._lock:
            pending = [r for r in self._requests.values()
                       if not r.done.is_set()]
        unstarted = [r for r in pending
                     if r.t_first is None and r.prompt is not None]
        started = [r for r in pending if r not in unstarted]
        now = time.monotonic()
        for res in started:
            if res.t_first is None:
                res.t_first = now
            res.t_done = now
            res.status = "error"
            res.error = res.error or "connection lost mid-stream"
            res.done.set()
        for res in unstarted:
            try:
                self._send_request(res.qid, res.prompt, res.lane,
                                   res.deadline, res.credit)
                self.n_resubmitted += 1
            except OSError:
                return    # fresh socket died; its reader handles the rest

    def close(self) -> None:
        """Close the connection.  Every outstanding query is completed
        immediately with a connection error — a waiter blocked in
        ``result()`` returns now, not after its full timeout."""
        self._closed = True
        try:
            # shutdown (not just close) unblocks a reader parked in
            # recv(); without it the reader — and every waiter — would
            # hang until the OS noticed the dead fd
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass
        if self._reader is not threading.current_thread():
            self._reader.join(timeout=2.0)
        # belt and braces: even a wedged reader must not leave waiters
        # blocked past close()
        self._fail_pending("connection closed")


class TensorQueryServer:
    """Serve a ``ServeEngine`` over TCP through the stream pipeline.

    ``pause_limit`` bounds each credited route's paused-TOKENS buffer
    (overflow kills the request with ``status="overrun"``);
    ``fault_plan`` threads a :class:`repro.serving.faults.FaultPlan`
    into the per-connection writer loops (``server_send`` seam)."""

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 0,
                 max_batch: Optional[int] = None, max_wait_ms: float = 5.0,
                 pad_to: Optional[int] = None, workers: int = 4,
                 queue_size: int = 64, stream: bool = True,
                 filter_timeout_s: Optional[float] = None,
                 pause_limit: int = 64, fault_plan=None):
        from ..core import elements as E
        from ..core.pipeline import Pipeline
        self.engine = engine
        if max_batch is None:
            max_batch = engine.batch_size
        if pad_to is None:
            pad_to = max(8, engine.capacity - engine.max_new_tokens)
        self.stream = bool(stream)
        self._routes: Dict[int, tuple] = {}     # engine rid -> (conn, qid)
        self._rev: Dict[tuple, int] = {}        # (id(conn), qid) -> rid
        self._pending_cancels: Dict[tuple, float] = {}  # arrived pre-register
        self._killing: set = set()              # rids with an async kill out
        self._routes_lock = threading.Lock()
        self.n_overrun_kills = 0

        self.src = E.TensorQueryServerSrc("qsrc", host=host, port=port,
                                          pad_to=pad_to,
                                          on_cancel=self._on_cancel,
                                          pause_limit=pause_limit,
                                          fault_plan=fault_plan)
        batcher = E.TensorBatcher("batch", max_batch=max_batch,
                                  max_wait_ms=max_wait_ms)
        q = E.Queue("dispatch", max_size=queue_size, workers=workers)
        filt = E.TensorFilter(
            "llm", framework="python", max_batch=max_batch, pass_meta=True,
            fn=engine.as_pipeline_filter(use_meta=True,
                                         on_submit=self._register,
                                         timeout_s=filter_timeout_s))
        unbatch = E.TensorUnbatcher("unbatch")
        self.sink = E.TensorQueryServerSink("qsink", on_done=self._unroute)
        self.pipeline = (Pipeline("tensor-query-server")
                         .add(self.src, batcher, q, filt, unbatch, self.sink)
                         .link("qsrc", "batch", "dispatch", "llm",
                               "unbatch", "qsink"))

    # -- routing ------------------------------------------------------------
    def _register(self, rid: int, meta) -> None:
        q = meta.get("query") if isinstance(meta, dict) else None
        if not (isinstance(q, dict) and q.get("conn") is not None):
            return
        key = (id(q["conn"]), int(q["qid"]))
        now = time.monotonic()
        with self._routes_lock:
            self._routes[rid] = (q["conn"], int(q["qid"]))
            self._rev[key] = rid
            cancelled = self._pending_cancels.pop(key, None) is not None
            # bound the parking lot: a CANCEL whose REQUEST never
            # arrives (bogus qid) must not pin memory forever
            stale = [k for k, t in self._pending_cancels.items()
                     if now - t > 60.0]
            for k in stale:
                del self._pending_cancels[k]
        if cancelled:
            # the cancel raced the batcher and lost: land it now that
            # the request exists engine-side
            self.engine.cancel(rid)

    def _unroute(self, meta) -> None:
        """Drop a request's route once its terminal frame was sent (or
        its connection died) — routes must never outlive the request."""
        rid = meta.get("rid") if isinstance(meta, dict) else None
        q = meta.get("query") if isinstance(meta, dict) else None
        with self._routes_lock:
            if rid is not None:
                self._routes.pop(int(rid), None)
            if isinstance(q, dict) and q.get("conn") is not None:
                self._rev.pop((id(q["conn"]), int(q["qid"])), None)

    def _on_cancel(self, conn, qid: int) -> None:
        """A MSG_CANCEL arrived on ``conn``.  Resolve it to an engine
        rid and cancel; a cancel racing the batcher (REQUEST pushed but
        not yet submitted) is parked and lands at registration.  A qid
        the server has never seen gets an immediate empty
        DONE(cancelled) so the client always receives a terminal
        frame."""
        key = (id(conn), qid)
        with self._routes_lock:
            rid = self._rev.get(key)
            if rid is None:
                self._pending_cancels[key] = time.monotonic()
        if rid is not None:
            self.engine.cancel(rid)
        else:
            # either mid-batcher (the parked cancel lands at register,
            # which then answers through the pipeline) or unknown/already
            # finished — answer directly so the client never hangs;
            # duplicate terminal frames are ignored client-side
            conn.send_frame(MSG_DONE, qid,
                            pack_tensor(np.zeros((0,), np.int32)),
                            status=STATUS_CODES["cancelled"])

    def _on_tokens(self, rid: int, new_tokens) -> None:
        with self._routes_lock:
            route = self._routes.get(rid)
        if route is None:
            return
        conn, qid = route
        # enqueue-only (the connection's writer thread does the socket
        # I/O) so a stalled client cannot block the engine's drain path
        outcome = conn.send_tokens(
            qid, pack_tensor(np.asarray(new_tokens, np.int32)))
        if outcome == "overrun":
            # the client never refilled this route's credit and its
            # pause buffer is full: kill the request.  Deferred to a
            # helper thread because this callback fires from inside the
            # stepping thread, which holds the step lock cancel() needs.
            self._kill_async(rid, "overrun")
        if not conn.alive:
            with self._routes_lock:
                self._routes.pop(rid, None)

    def _kill_async(self, rid: int, status: str) -> None:
        with self._routes_lock:
            if rid in self._killing:
                return
            self._killing.add(rid)
        self.n_overrun_kills += 1

        def kill() -> None:
            try:
                self.engine.cancel(rid, status)
            finally:
                with self._routes_lock:
                    self._killing.discard(rid)
        threading.Thread(target=kill, name=f"tq-kill:{rid}",
                         daemon=True).start()

    # -- lifecycle ----------------------------------------------------------
    @property
    def port(self) -> int:
        return self.src.port

    def start(self) -> "TensorQueryServer":
        if self.stream:
            self.engine.stream_cb = self._on_tokens
        self.pipeline.start()
        return self

    def drain(self, timeout: float = 30.0) -> bool:
        """Graceful drain: stop admitting (listener closed, further
        REQUESTs rejected with an ERROR frame), then wait for every
        in-flight request to reach a terminal frame.  Past ``timeout``
        whatever is left is cancelled with ``status="timeout"`` so no
        client is ever left without an answer.  Returns True if
        everything finished naturally.  Call :meth:`stop` afterwards to
        tear the pipeline down."""
        self.src.stop_accepting()
        deadline = time.monotonic() + max(0.0, timeout)
        settled = 0
        while time.monotonic() < deadline:
            with self._routes_lock:
                n_routes = len(self._routes)
            if n_routes == 0 and not self.engine.has_work:
                # require the quiet state to hold across a few polls:
                # a request can sit in the batcher/queue where neither
                # the route table nor the engine sees it yet
                settled += 1
                if settled >= 3:
                    return True
            else:
                settled = 0
            time.sleep(0.05)
        for rid in self.engine.inflight_rids():
            self.engine.cancel(rid, "timeout")
        flush_deadline = time.monotonic() + 2.0
        while time.monotonic() < flush_deadline:
            with self._routes_lock:
                if not self._routes:
                    break
            time.sleep(0.02)
        return False

    def stop(self) -> None:
        self.pipeline.stop()
        if self.engine.stream_cb == self._on_tokens:
            self.engine.stream_cb = None
        with self._routes_lock:
            self._routes.clear()
            self._rev.clear()
            self._pending_cancels.clear()
